"""Quickstart: the IntersectX stream ISA in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


from repro.core import isa, make_stream, to_host, s_nestinter
from repro.graph import build_csr, neighbors_stream
from repro.graph.generators import erdos_renyi
from repro.mining import apps

# --- streams are first-class: Table I instructions as library calls -------
a = make_stream([1, 3, 5, 7, 9], values=[1., 2., 3., 4., 5.])
b = make_stream([3, 4, 5, 9, 11], values=[10., 20., 30., 40., 50.])
print("S_INTER    :", to_host(isa.s_inter(a, b)))          # [3 5 9]
print("S_INTER R3 :", to_host(isa.s_inter(a, b, bound=6)))  # early termination
print("S_SUB      :", to_host(isa.s_sub(a, b)))
print("S_VINTER   :", float(isa.s_vinter(a, b, op="mac")))  # sparse dot
print("S_FETCH EOS:", int(isa.s_fetch(a, 99)))              # 2^31-1

# --- a graph is a CSR of streams; S_NESTINTER is the mining inner loop ----
g = build_csr(erdos_renyi(500, 3000, seed=0), 500)
n0 = neighbors_stream(g, 0)
print("S_NESTINTER(N(0)) =", int(s_nestinter(g, n0)))

# --- the seven applications --------------------------------------------------
print("triangles          :", apps.triangle_count(g))
print("triangles (nested) :", apps.triangle_count_nested(g))
print("3-chains (induced) :", apps.three_chain_count(g, induced=True))
print("tailed triangles   :", apps.tailed_triangle_count(g))
print("4-cliques          :", apps.clique_count(g, 4))
