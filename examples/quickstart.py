"""Quickstart: the IntersectX stream ISA + the Miner session in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


from repro.core import isa, make_stream, to_host, s_nestinter
from repro.graph import build_csr, neighbors_stream
from repro.graph.generators import erdos_renyi
from repro.mining import Miner       # the stable public surface

# --- streams are first-class: Table I instructions as library calls -------
a = make_stream([1, 3, 5, 7, 9], values=[1., 2., 3., 4., 5.])
b = make_stream([3, 4, 5, 9, 11], values=[10., 20., 30., 40., 50.])
print("S_INTER    :", to_host(isa.s_inter(a, b)))          # [3 5 9]
print("S_INTER R3 :", to_host(isa.s_inter(a, b, bound=6)))  # early termination
print("S_SUB      :", to_host(isa.s_sub(a, b)))
print("S_VINTER   :", float(isa.s_vinter(a, b, op="mac")))  # sparse dot
print("S_FETCH EOS:", int(isa.s_fetch(a, 99)))              # 2^31-1

# --- a graph is a CSR of streams; S_NESTINTER is the mining inner loop ----
g = build_csr(erdos_renyi(500, 3000, seed=0), 500)
n0 = neighbors_stream(g, 0)
print("S_NESTINTER(N(0)) =", int(s_nestinter(g, n0)))

# --- mining is a session: one Miner owns the graph, queries are cheap -----
# compile (pattern -> plan), schedule (matching-order search + forest),
# execute (device-resident waves) — every stage cached for the session.
m = Miner(g)
print("triangles          :", m.count("triangle"))
print("triangles (nested) :", m.count("triangle-nested"))
print("3-chains (induced) :", m.count("three-chain"))
print("tailed triangles   :", m.count("tailed-triangle"))
print("4-cliques          :", m.count("4-clique"))

# the six connected 4-vertex motifs, one fused pass (shared-prefix forest
# built by the automatic matching-order search — no hand-tuned schedules)
names = ["4-clique", "diamond", "4-cycle", "paw", "4-path", "4-star"]
print("4-motifs (fused)   :", dict(zip(names, m.count_many(names))))

# embeddings come from the same session (emit plan, device compaction)
print("triangle list      :", m.embeddings("triangle").shape)

# repeated queries are pure cache hits: 0 retraces from here on
before = m.stats["retraces"]
m.count("triangle")
m.count_many(names)
print("retraces on repeat :", m.stats["retraces"] - before)

# --- weighted mining: the SVPU value plane (paper §IV-E) ------------------
# attach one f32 weight per edge (aligned with the CSR keys, staged once
# per session) and the same fused plans aggregate embedding weights —
# SUM/MAX/MIN of the per-embedding products of pattern-edge weights — at
# the unweighted query's dispatch cost: value lanes ride the membership
# kernels, never add feed passes, and repeat with 0 retraces.
from repro.graph import edge_weights, with_edge_values
from repro.graph.csr import edge_list

gw = with_edge_values(g, edge_weights(edge_list(g), seed=1))
mw = Miner(gw)
print("weighted triangles :", mw.aggregate("triangle", op="sum"))
print("heaviest triangle  :", mw.aggregate("triangle", op="max"))
print("weighted (batched) :", mw.aggregate_many(["triangle", "4-clique"]))
before = mw.stats["retraces"]
mw.aggregate("triangle", op="sum")
print("retraces on repeat :", mw.stats["retraces"] - before)

# --- observability: trace a query, see where its time went ----------------
# a Telemetry(enabled=True) session records a span tree per query (query ->
# compile/schedule/execute -> per-level -> per-dispatch, perf_counter wall
# time around dispatch + block_until_ready); counters live in the same
# registry the stats dicts above are views of. write_trace() exports
# Chrome-trace JSON for ui.perfetto.dev (same as `launch/mine.py --trace`).
from repro.obs import Telemetry

tel = Telemetry(enabled=True)
mt = Miner(g, telemetry=tel)
mt.count("4-clique")
q = tel.tracer.last("query")
print("traced query       :", f"{q.seconds * 1e3:.1f}ms,",
      sum(1 for _ in q.walk()), "spans,",
      len(q.find("dispatch")), "dispatches")
top = sorted(tel.tracer.level_seconds().items(),
             key=lambda kv: -kv[1])[:3]
print("hottest spans      :", {k: f"{v * 1e3:.1f}ms" for k, v in top})

# --- concurrent traffic: a MiningService over a pool of sessions ----------
# submit() is thread-safe and non-blocking; each tick() merges the queued
# requests into ONE forest schedule per traffic class (cross-request
# sharing), serves repeats from a graph-version-keyed result cache, and
# applies admission control (max_in_flight, per-request deadlines).
from repro.serving import MiningService

svc = MiningService(g)
r1 = svc.submit(("triangle", "paw"))      # two concurrent requests ...
r2 = svc.submit(("triangle", "4-cycle"))  # ... sharing the triangle prefix
tick = svc.tick()
print("service tick       :", tick["requests"], "requests merged,",
      "feed passes", tick["feed_passes"]["independent"], "->",
      tick["feed_passes"]["fused"])
print("request results    :", r1.result(), r2.result())
print("cached repeat      :", svc.query("triangle"),
      f"(hits={svc.cache.snapshot()['hits']})")

# multi-device? the same session mines data-parallel over a mesh — counts
# are bit-identical (on CPU: XLA_FLAGS=--xla_force_host_platform_device_count=8)
import jax
if jax.device_count() > 1:
    ms = Miner(g, mesh=jax.device_count())
    print("triangles (mesh)   :", ms.count("triangle"))
