"""End-to-end driver: train a ~100M-param qwen3-style model for a few
hundred steps on the synthetic bigram corpus and watch the loss fall well
below the unigram entropy — the full production loop (sharded init, pjit'd
step, checkpointing, NaN guard) on whatever devices exist.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import DEFAULT_RULES
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import Model, ModelConfig, param_count
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticLMData, input_spec_batch
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import jit_train_step
from repro.distributed.fault_tolerance import StepGuard


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/ckpt_100m")
    args = ap.parse_args()

    cfg = ModelConfig(name="qwen3-100m", num_layers=10, d_model=768,
                      num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2560,
                      vocab_size=32768, qk_norm=True, tie_embeddings=True,
                      kv_repeat=2)
    model = Model(cfg)
    print(f"[100m] params: {param_count(model)/1e6:.1f}M")
    mesh = make_host_mesh()
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=1, noise=0.05)
    opt_cfg = OptConfig(lr=1e-3)
    spec = input_spec_batch(cfg.vocab_size, args.seq, args.batch)
    step_fn, (p_shard, o_shard, shapes, _) = jit_train_step(
        model, mesh, DEFAULT_RULES, opt_cfg, spec, total_steps=args.steps)
    with mesh:
        params = jax.jit(lambda k: model.init(k)[0],
                         out_shardings=p_shard)(jax.random.PRNGKey(0))
        opt = jax.jit(lambda p: adamw_init(p, opt_cfg),
                      out_shardings=o_shard)(params)
    ckpt = CheckpointManager(args.ckpt)
    guard = StepGuard()
    unigram_entropy = math.log(cfg.vocab_size)
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params2, opt2, metrics = step_fn(params, opt, batch, jnp.int32(step))
        metrics = jax.device_get(metrics)
        if guard.ok(metrics):
            params, opt = params2, opt2
        if step % 25 == 0 or step == args.steps - 1:
            print(f"[100m] step {step:4d} loss={metrics['loss']:.4f} "
                  f"(unigram entropy {unigram_entropy:.2f})", flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(step, params, opt, data.state())
    final = float(metrics["loss"])
    print(f"[100m] final loss {final:.3f} vs unigram {unigram_entropy:.2f} "
          f"-> {'LEARNED structure' if final < unigram_entropy - 1 else 'check hyperparams'}")


if __name__ == "__main__":
    main()
