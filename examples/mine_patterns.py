"""Scenario: mine all seven paper applications over a paper-twin dataset,
comparing the stream engine against both baselines, plus FSM with the
correct (MNI) vs GRAMER's broken (count) support.

  PYTHONPATH=src python examples/mine_patterns.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

from repro.graph import get_dataset
from repro.graph.datasets import dataset_stats
from repro.mining import Miner, baseline, exhaustive
from repro.mining.fsm import fsm, random_labels, sfsm
from repro.mining.plan import clique_pattern

g = get_dataset("email-eu-core")
print("[mine] email-eu-core twin:", dataset_stats(g))

# one resident session serves every query: the graph is staged to device
# once and executables are cached across apps
m = Miner(g)


def three_motif():
    t, chain = m.count_many(["triangle", "three-chain"])
    return {"triangle": t, "chain": chain}


for name, eng, base in [
    ("triangle", lambda: m.count("triangle"),
     lambda: baseline.triangle_count(g)),
    ("3-chain(ind)", lambda: m.count("three-chain"),
     lambda: baseline.three_chain_count(g, induced=True)),
    ("tailed-tri", lambda: m.count("tailed-triangle"),
     lambda: baseline.tailed_triangle_count(g)),
    ("3-motif", three_motif, lambda: baseline.three_motif(g)),
    ("4-clique", lambda: m.count(clique_pattern(4)),
     lambda: baseline.clique_count(g, 4)),
    ("5-clique", lambda: m.count(clique_pattern(5)),
     lambda: baseline.clique_count(g, 5)),
]:
    t0 = time.time()
    r = eng()
    t1 = time.time() - t0
    t0 = time.time()
    rb = base()
    t2 = time.time() - t0
    assert r == rb
    print(f"[mine] {name:12s} = {r!s:>14}  engine {t1:6.2f}s | scalar {t2:6.2f}s")

t0 = time.time()
ex = exhaustive.exhaustive_count(g, "triangle")
print(f"[mine] GRAMER-style exhaustive triangle = {ex} "
      f"({time.time()-t0:.2f}s — the method the paper shows losing)")

labels = random_labels(g.num_vertices, 4, seed=7)
t0 = time.time()
freq = fsm(g, labels, min_support=400)
print(f"[mine] FSM (MNI support>=400): {len(freq)} frequent patterns "
      f"({time.time()-t0:.1f}s)")
wrong = sfsm(g, labels, min_support=400)
print(f"[mine] sFSM (GRAMER count-support): {len(wrong)} patterns — "
      "violates downward closure (§VI-B)")
