"""Scenario: fault tolerance end-to-end — crash mid-run, restart, verify
bit-exact continuation; then restore the same checkpoint onto a different
mesh (the elastic path).

  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import subprocess
import sys
import tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def run(args):
    return subprocess.run([sys.executable, "-m"] + args, env=ENV, cwd=ROOT,
                          capture_output=True, text=True)


with tempfile.TemporaryDirectory() as d:
    ck = os.path.join(d, "ck")
    print("[ft] run 1: training with an injected crash at step 6 ...")
    r1 = run(["repro.launch.train", "--arch", "qwen3-0.6b", "--steps", "12",
              "--batch", "2", "--seq", "32", "--ckpt", ck,
              "--ckpt-every", "4", "--inject-failure", "6"])
    assert r1.returncode == 17, "expected the injected crash"
    tail = [ln for ln in r1.stdout.splitlines()
            if ln.startswith("[train] step")]
    print("   last steps before crash:", tail[-2:])

    print("[ft] run 2: restart from the same --ckpt ...")
    r2 = run(["repro.launch.train", "--arch", "qwen3-0.6b", "--steps", "12",
              "--batch", "2", "--seq", "32", "--ckpt", ck,
              "--ckpt-every", "4"])
    assert r2.returncode == 0, r2.stderr[-1000:]
    lines = [ln for ln in r2.stdout.splitlines() if "restored" in ln
             or ln.startswith("[train] step")]
    print("   " + "\n   ".join(lines[:3]))
    print("[ft] crash/restart: OK (resumed from the last checkpoint)")

    print("[ft] elastic restore onto a different mesh (8 fake devices) ...")
    script = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';\n"
        "import jax, jax.numpy as jnp\n"
        "from repro.configs import get_arch\n"
        "from repro.models.transformer import Model, shapes_and_axes\n"
        "from repro.distributed.sharding import DEFAULT_RULES, "
        "make_mesh_compat, shard_params_tree\n"
        "from repro.train.checkpoint import CheckpointManager\n"
        f"cm = CheckpointManager({ck!r})\n"
        "spec = get_arch('qwen3-0.6b'); model = Model(spec.smoke_config)\n"
        "shapes, axes = shapes_and_axes(model)\n"
        "mesh = make_mesh_compat((4,2), ('data','model'))\n"
        "psh = shard_params_tree(shapes, axes, mesh, DEFAULT_RULES)\n"
        "params, _, man = cm.restore(None, shapes, None, mesh, psh)\n"
        "print('[ft] elastic restore onto', mesh.shape, 'at step', man['step'], 'OK')\n")
    r3 = subprocess.run([sys.executable, "-c", script], env=ENV, cwd=ROOT,
                        capture_output=True, text=True)
    assert r3.returncode == 0, r3.stderr[-1000:]
    print("   " + r3.stdout.strip().splitlines()[-1])
print("[ft] all fault-tolerance paths verified")
