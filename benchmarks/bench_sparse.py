"""Fig. 15 / Table VI analogue: SpMxSpM and TTV via the S_VINTER engine vs
a scipy.sparse CPU baseline (the TACO stand-in in this container).

Reproduces the paper's trend: denser matrices => more intersection work =>
larger relative wins for the stream engine; TTV (shared dense B stream) is
the best case.
"""
from __future__ import annotations

import time

import numpy as np
import scipy.sparse as sp

from repro.sparse import from_dense, random_csf, spmsp_matmul, ttv

# synthetic twins of Table VI (dims x density); full-size where CPU-feasible
MATRICES = [
    ("circuit204", 1020, 0.0057), ("email-core", 1005, 0.025),
    ("fpga", 1220, 0.0040), ("laser", 1500, 0.00055),
    ("grid2", 1600, 0.00059),
]
TENSORS = [
    ("chicago-s", (600, 24, 240), 50_000),
    ("uber-s", (430, 110, 170), 33_000),
]


def _dense(n, density, seed):
    rng = np.random.default_rng(seed)
    return np.where(rng.random((n, n)) < density,
                    rng.normal(size=(n, n)), 0.0).astype(np.float32)


def run(quick: bool = True):
    rows = []
    for name, n, density in (MATRICES[:3] if quick else MATRICES):
        a_d, b_d = _dense(n, density, 1), _dense(n, density, 2)
        a, b = from_dense(a_d), from_dense(b_d, "csc")
        t0 = time.time()
        c = spmsp_matmul(a, b, backend="xla")
        t_eng = time.time() - t0
        a_s, b_s = sp.csr_matrix(a_d), sp.csr_matrix(b_d)
        t0 = time.time()
        c_ref = (a_s @ b_s).toarray()
        t_ref = time.time() - t0
        assert np.allclose(c, c_ref, atol=1e-3)
        rows.append(dict(kind="spmm", name=name, n=n, density=density,
                         engine_s=round(t_eng, 4), scipy_s=round(t_ref, 5)))
        print(f"[sparse] spmm {name:12s} n={n} d={density:.4f} "
              f"engine={t_eng:7.3f}s scipy={t_ref:7.4f}s", flush=True)
    for name, shape, nnz in TENSORS:
        t = random_csf(shape, nnz, seed=3)
        vec = np.random.default_rng(4).normal(size=shape[2]).astype(np.float32)
        t0 = time.time()
        ii, jj, vv = ttv(t, np.arange(shape[2], dtype=np.int32), vec,
                         backend="xla")
        t_eng = time.time() - t0
        # scipy baseline: flatten (i,j) x k CSR then matvec
        fk = t.i_ids.astype(np.int64) * shape[1] + t.j_ids
        row_ids = np.repeat(fk, np.diff(t.fiber_ptr))
        m = sp.csr_matrix((t.vals, (row_ids, t.k_ids)),
                          shape=(shape[0] * shape[1], shape[2]))
        t0 = time.time()
        ref = m @ vec
        t_ref = time.time() - t0
        got = np.zeros(shape[0] * shape[1], np.float32)
        got[fk] = vv
        assert np.allclose(got, ref, atol=1e-3)
        rows.append(dict(kind="ttv", name=name, nnz=nnz,
                         engine_s=round(t_eng, 4), scipy_s=round(t_ref, 5)))
        print(f"[sparse] ttv  {name:12s} nnz={nnz} engine={t_eng:7.3f}s "
              f"scipy={t_ref:7.4f}s", flush=True)
    return rows


if __name__ == "__main__":
    run(quick=False)
