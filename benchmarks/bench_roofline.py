"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and prints the per-cell three-term roofline
+ dominant bottleneck + useful-flops ratio. Run the sweep first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load(dirname=DRYRUN_DIR):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows, mesh="single"):
    out = []
    hdr = (f"{'arch':24s} {'shape':11s} {'comp_s':>9} {'mem_s':>9} "
           f"{'coll_s':>9} {'dominant':>10} {'roofl%':>7} {'useful%':>8} "
           f"{'peakGB':>7} fit")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:11s} "
                  f"{'skipped (' + r['reason'][:40] + '...)'}")
            continue
        if r.get("status") != "ok":
            print(f"{r['arch']:24s} {r['shape']:11s} ERROR {r.get('error','')[:60]}")
            continue
        roof = r["roofline"]
        mem = r["scan_measure"]["memory"]
        print(f"{r['arch']:24s} {r['shape']:11s} "
              f"{roof['compute_s']:9.4f} {roof['memory_s']:9.4f} "
              f"{roof['collective_s']:9.4f} {roof['dominant'][:-2]:>10} "
              f"{100*roof['roofline_fraction']:6.1f}% "
              f"{100*roof['useful_flops_ratio']:7.1f}% "
              f"{mem['peak_bytes']/1e9:7.2f} {r['fits_hbm']}")
        out.append(r)
    return out


def run(quick: bool = True):
    rows = load()
    if not rows:
        print("[roofline] no dry-run artifacts yet — run the sweep first")
        return []
    print("\n== single pod (16x16 = 256 chips) ==")
    table(rows, "single")
    print("\n== multi pod (2x16x16 = 512 chips) ==")
    table(rows, "multi")
    return rows


if __name__ == "__main__":
    run()
