"""Frozen pre-refactor hand-coded wave paths (benchmark baseline only).

A trimmed copy of the device-resident clique / tailed-triangle code exactly
as it stood before the pattern-plan compiler landed: bespoke per-pattern
engine methods (`clique`, `tailed_triangle`) with hand-scheduled
expand/compact loops. ``bench_mining.plan_overhead_report`` times these
against the same workloads run through compiled ``WavePlan``s so the
interpreter's dispatch overhead is *measured*, not assumed. Not a library
surface — nothing outside benchmarks imports this.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.stream import round_capacity
from repro.graph.csr import padded_rows
from repro.kernels.ops import xinter_count, xinter_compact
from repro.mining.engine import (_neighbor_cap, _pow2cap, choose_chunk,
                                 directed_edges, edge_chunks, pair_chunks)


class HandCodedRunner:
    """Pre-refactor WaveRunner: device compaction only, no record/oracle."""

    def __init__(self, g, chunk: int | None = None, backend: str = "auto"):
        self.g = g
        self.chunk = chunk or choose_chunk(g.padded_max_degree)
        self.backend = backend
        self._exec: dict[tuple, Callable] = {}

    def _executable(self, key: tuple, build: Callable) -> Callable:
        fn = self._exec.get(key)
        if fn is None:
            fn = self._exec[key] = build()
        return fn

    def _rows_fn(self, cap: int):
        def build():
            @jax.jit
            def fn(g, vs):
                return padded_rows(g, vs, cap)[0]
            return fn
        return self._executable(("rows", cap), build)

    def _count_fn(self, cap_a: int, capn: int, bounded: bool):
        backend = self.backend

        def build():
            @jax.jit
            def fn(g, rows, verts, n):
                nbr, _ = padded_rows(g, verts, capn)
                bounds = verts if bounded else None
                counts = xinter_count(rows, nbr, bounds, backend=backend)
                live = jnp.arange(rows.shape[0], dtype=jnp.int32) < n
                return jnp.sum(jnp.where(live, counts, 0), dtype=jnp.int32)
            return fn
        return self._executable(("count", cap_a, capn, bounded), build)

    def _expand_fn(self, cap_a: int, capn: int, out_cap: int, out_items: int):
        backend = self.backend

        def build():
            @jax.jit
            def fn(g, rows, verts):
                nbr, _ = padded_rows(g, verts, capn)
                rows2, counts2, src, verts2, total, maxc = xinter_compact(
                    rows, nbr, bounds=verts, out_cap=out_cap,
                    out_items=out_items, backend=backend)
                live = jnp.arange(out_items, dtype=jnp.int32) < total
                dmax = jnp.max(jnp.where(live, g.degrees[verts2], 0))
                meta = jnp.stack([total, maxc, dmax])
                return rows2, src, verts2, meta
            return fn
        return self._executable(
            ("expand", cap_a, capn, out_cap, out_items), build)

    def _chunk_fn(self, b: int, out_cap: int, cap2: int, chunk: int):
        def build():
            @jax.jit
            def fn(rows2, src, verts2, lo):
                s = jax.lax.dynamic_slice_in_dim(src, lo, chunk)
                v = jax.lax.dynamic_slice_in_dim(verts2, lo, chunk)
                return rows2[s, :cap2], v
            return fn
        return self._executable(("chunk", b, out_cap, cap2, chunk), build)

    @staticmethod
    def _double_buffered(chunks, put_idx: frozenset):
        pending = None
        for tup in chunks:
            nxt = tuple(jax.device_put(x) if i in put_idx else x
                        for i, x in enumerate(tup))
            if pending is not None:
                yield pending
            pending = nxt
        if pending is not None:
            yield pending

    def _edge_feed(self, symmetric: bool = True):
        chunks = ((cap, v0, v1, v1, n) for cap, v0, v1, n
                  in edge_chunks(self.g, self.chunk, symmetric))
        return self._double_buffered(chunks, frozenset({1, 2}))

    def _pair_feed(self, edges: np.ndarray):
        chunks = ((ca, cb, v0, v1, v1, n) for ca, cb, v0, v1, n
                  in pair_chunks(self.g, edges, self.chunk))
        return self._double_buffered(chunks, frozenset({2, 3}))

    def clique(self, k: int) -> int:
        parts = []
        for cap, dv0, dv1, v1h, n in self._edge_feed(True):
            rows = self._rows_fn(cap)(self.g, dv0)
            capn = _neighbor_cap(self.g, v1h)
            parts += self._descend(rows, dv1, capn, k - 2, n)
        return sum(int(p) for p in parts)

    def _descend(self, rows, verts, capn: int, depth: int, n: int) -> list:
        cap_a = int(rows.shape[1])
        if depth == 1:
            return [self._count_fn(cap_a, capn, True)(self.g, rows, verts, n)]
        out_cap = min(cap_a, capn)
        b = int(rows.shape[0])
        out_items = -(-b * out_cap // self.chunk) * self.chunk
        rows2, src, verts2, meta = self._expand_fn(
            cap_a, capn, out_cap, out_items)(self.g, rows, verts)
        total, maxc, dmax = (int(x) for x in np.asarray(meta))
        if total == 0:
            return []
        cap2 = round_capacity(maxc)
        capn2 = _pow2cap(max(dmax, 1))
        cfn = self._chunk_fn(b, out_cap, cap2, self.chunk)
        parts = []
        for lo in range(0, total, self.chunk):
            crows, cverts = cfn(rows2, src, verts2, lo)
            m = min(self.chunk, total - lo)
            parts += self._descend(crows, cverts, capn2, depth - 1, m)
        return parts

    def _pair_counts_fn(self, ca: int, cb: int):
        backend = self.backend

        def build():
            @jax.jit
            def fn(g, v0, v1):
                rows_a, _ = padded_rows(g, v0, ca)
                rows_b, _ = padded_rows(g, v1, cb)
                return xinter_count(rows_a, rows_b, v0, backend=backend)
            return fn
        return self._executable(("pair", ca, cb), build)

    def tailed_triangle(self) -> int:
        deg = np.asarray(self.g.degrees, dtype=np.int64)
        total = 0
        for ca, cb, dv0, dv1, v1h, n in self._pair_feed(directed_edges(self.g)):
            c = self._pair_counts_fn(ca, cb)(self.g, dv0, dv1)
            c = np.asarray(c)[:n].astype(np.int64)
            total += int((c * (deg[v1h[:n]] - 2)).sum())
        return total
