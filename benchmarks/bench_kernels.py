"""Fig. 11 / Fig. 12 analogue: kernel-level sensitivity sweeps.

The paper varies #IUs (saturates at 4) and S-Cache bandwidth (saturates
~8 elem/cycle). The TPU analogues:
  batch sweep  — batched-kernel width == number of concurrent IUs
  tile sweep   — VMEM tile footprint == S-Cache slot/bandwidth provisioning
  skip stats   — tile-overlap schedule efficiency (the S-Cache prefetcher):
                 fraction of B-tiles the schedule avoids touching
plus the merge-vs-bitmap crossover of the beyond-paper dense path.

Wall-clock uses the XLA paths (interpret-mode Pallas is a correctness
vehicle, not a perf one); schedule stats are structural (exact tile counts).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.batch import batch_inter_count
from repro.core.stream import SENTINEL
from repro.kernels.bitmap import bitmap_and_count_ref, keys_to_bitmap
from repro.kernels.intersect import TB, tile_schedule

RNG = np.random.default_rng(3)


def _rows(batch, cap, hi, density=None):
    out = np.full((batch, cap), SENTINEL, np.int32)
    for i in range(batch):
        n = int(RNG.integers(cap // 2, cap)) if density is None else \
            min(cap, max(1, int(hi * density)))
        out[i, :n] = np.sort(RNG.choice(hi, size=n, replace=False))
    return jnp.asarray(out)


def _bench(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.time() - t0) / iters


def batch_sweep():
    """IU-count analogue: throughput vs batched width."""
    rows = []
    cap, hi = 512, 100_000
    for batch in (1, 2, 4, 8, 16, 64, 256):
        a, b = _rows(batch, cap, hi), _rows(batch, cap, hi)
        dt = _bench(batch_inter_count, a, b)
        rows.append(dict(batch=batch, us_per_pair=1e6 * dt / batch))
        print(f"[kernels] batch={batch:4d}  {1e6*dt/batch:9.2f} us/pair",
              flush=True)
    return rows


def tile_skip_stats():
    """S-Cache-prefetch analogue: % of B tiles the overlap schedule skips."""
    rows = []
    for hi, label in ((4_000, "dense keys"), (400_000, "sparse keys")):
        a, b = _rows(64, 512, hi), _rows(64, 2048, hi)
        bounds = jnp.full((64,), SENTINEL, jnp.int32)
        lo, nv = tile_schedule(a, b, bounds)
        total = 64 * (512 // 128) * (2048 // TB)   # naive all-pairs visits
        visited = int(np.asarray(nv).sum())
        frac = visited / total
        rows.append(dict(keyspace=label, visited_frac=round(frac, 4)))
        print(f"[kernels] schedule {label:12s}: visits {frac*100:5.1f}% of "
              f"naive tile pairs", flush=True)
    return rows


def bitmap_crossover():
    """merge vs bitmap: crossover density of the beyond-paper path."""
    rows = []
    for density in (0.01, 0.05, 0.1, 0.2, 0.4):
        hi = 8192
        a = _rows(128, 1024, hi, density=density * hi / 1024)
        b = _rows(128, 1024, hi, density=density * hi / 1024)
        t_merge = _bench(batch_inter_count, a, b)
        wa, wb = keys_to_bitmap(a, hi), keys_to_bitmap(b, hi)
        t_bitmap = _bench(bitmap_and_count_ref, wa, wb)
        rows.append(dict(density=density, merge_us=1e6 * t_merge,
                         bitmap_us=1e6 * t_bitmap,
                         winner="bitmap" if t_bitmap < t_merge else "merge"))
        print(f"[kernels] density={density:4.2f} merge={1e6*t_merge:8.1f}us "
              f"bitmap={1e6*t_bitmap:8.1f}us -> "
              f"{'bitmap' if t_bitmap < t_merge else 'merge'}", flush=True)
    return rows


def run(quick: bool = True):
    return {"batch_sweep": batch_sweep(),
            "tile_skip": tile_skip_stats(),
            "bitmap_crossover": bitmap_crossover()}


if __name__ == "__main__":
    run()
