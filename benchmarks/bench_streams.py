"""Fig. 14 analogue: stream-length distributions per app / dataset.

Reproduces both observations: (a) clique inner streams are much shorter
than level-1 edge streams; (b) heavier-tailed datasets have longer max
streams.
"""
from __future__ import annotations

import numpy as np

from repro.graph import get_dataset
from repro.mining.engine import edge_wave, expand


def stream_length_cdf(name: str, scale: float = 1.0):
    g = get_dataset(name, scale=scale)
    deg = np.asarray(g.degrees)
    lvl1 = deg[deg > 0]                              # S_READ streams
    lvl2 = []                                        # clique S2 streams
    for wave, n in edge_wave(g, 4096):
        rows2, counts2 = expand(g, wave, out_cap=g.padded_max_degree)
        lvl2.append(np.asarray(counts2)[:n])
    lvl2 = np.concatenate(lvl2) if lvl2 else np.zeros(1)
    out = {}
    for label, arr in (("edge-stream", lvl1), ("clique-S2", lvl2)):
        qs = np.percentile(arr, [50, 90, 99, 100])
        out[label] = dict(p50=float(qs[0]), p90=float(qs[1]),
                          p99=float(qs[2]), max=float(qs[3]))
        print(f"[streams] {name:14s} {label:12s} p50={qs[0]:7.1f} "
              f"p90={qs[1]:7.1f} p99={qs[2]:7.1f} max={qs[3]:7.1f}",
              flush=True)
    return out


def run(quick: bool = True):
    sets = [("email-eu-core", 1.0), ("wiki-vote", 1.0), ("haverford", 1.0)]
    if not quick:
        sets += [("youtube", 0.05), ("livejournal", 0.01)]
    return {name: stream_length_cdf(name, s) for name, s in sets}


if __name__ == "__main__":
    run(quick=False)
