"""Serving benchmark: the concurrent mining service under mixed traffic.

Three phases, each feeding benchmarks/ci_gate.py --serving:

1. **Deterministic batching** (exact facts): the heterogeneous request mix
   is submitted concurrently with the result cache OFF, so every tick
   merges the requests into one ``PlanForest`` schedule per traffic class.
   The gated facts are the per-tick feed passes — fused must be strictly
   below the sum of the requests' independent schedules (``sharing_ok``,
   the cross-request sharing acceptance) — and ``steady_retraces == 0``
   (warmed ticks rebuild no executables).
2. **Result cache** (exact facts): a cached service serves the same mix
   twice — the second submission must complete entirely from cache
   (``cached_tick_executed == 0``), and a ``set_graph`` version bump must
   invalidate every entry.
3. **Load** (gated ratios): the threaded ``LoadGenerator`` bursts the mix
   at the service (queue depth > clients guarantees merged ticks) and the
   resulting qps/p50/p99 are normalised against a sequential warmed
   ``Miner`` serving the identical request stream one at a time —
   ``qps_vs_sequential`` is the concurrency acceptance ratio.

Wall-clock rides ``repro.obs`` spans on the service telemetry; the trace
JSON written by ``main()``/ci_gate is the Perfetto artifact showing the
tick/execute span tree under load.
"""
from __future__ import annotations

import time

from repro.mining import FOUR_MOTIF_SHAPES, Miner, MinerConfig
from repro.obs import Telemetry
from repro.serving import LoadGenerator, MiningService, WorkerSpec, \
    percentile

# the heterogeneous request mix: four single-pattern requests + the
# 4-motif batch ("4-clique" rides in two different requests on purpose —
# the union dedup must still schedule it once per tick)
MIX_LABELS = ["T", "TC", "TT", "4C"] + list(FOUR_MOTIF_SHAPES)
MIXES = [("triangle",), ("three-chain",), ("tailed-triangle",),
         ("4-clique",), tuple(FOUR_MOTIF_SHAPES)]


def _specs(shards: int) -> tuple[list[WorkerSpec], list[str]]:
    """Worker pool + per-request routing for the mix: ``shards > 1`` adds
    a mesh-sharded class serving the heavy motif batch (mixed pool)."""
    specs = [WorkerSpec("default", MinerConfig())]
    classes = ["default"] * len(MIXES)
    if shards > 1:
        specs.append(WorkerSpec("bulk", MinerConfig(mesh=shards)))
        classes[-1] = "bulk"
    return specs, classes


def _submit_mix(svc: MiningService, classes: list[str]) -> list:
    return [svc.submit(qs, traffic_class=tc)
            for qs, tc in zip(MIXES, classes)]


def batching_report(g, shards: int = 0, rounds: int = 3,
                    telemetry: Telemetry | None = None) -> dict:
    """Phase 1: cross-request forest batching + steady-state retraces."""
    specs, classes = _specs(shards)
    svc = MiningService(g, workers=tuple(specs), cache_results=False,
                        telemetry=telemetry)
    first = None
    warm_retraces = steady_retraces = 0
    tick = {}
    for _ in range(max(rounds, 2)):
        before = svc.stats["retraces"]
        handles = _submit_mix(svc, classes)
        tick = svc.tick()
        flat = [v for h in handles for v in h.result(0)]
        res = dict(zip(MIX_LABELS, flat))
        if first is None:
            first, warm_retraces = res, svc.stats["retraces"] - before
        else:
            assert res == first, (res, first)
            steady_retraces += svc.stats["retraces"] - before
    fp = tick["feed_passes"]
    return {
        "counts": first,
        "batch_requests": len(MIXES),
        "feed_passes_independent": fp["independent"],
        "feed_passes_fused": fp["fused"],
        "sharing_ok": bool(fp["fused"] < fp["independent"]),
        "warm_retraces": warm_retraces,
        "steady_retraces": steady_retraces,
        "workers": sorted(svc.stats["workers"]),
    }


def cache_report(g) -> dict:
    """Phase 2: result-cache hit path + version-bump invalidation."""
    svc = MiningService(g, cache_results=True)
    _, classes = _specs(0)
    _submit_mix(svc, classes)
    svc.run_until_idle()
    warm = svc.cache.snapshot()
    handles = _submit_mix(svc, classes)
    tick = svc.tick()
    assert all(h.from_cache for h in handles)
    snap = svc.cache.snapshot()
    svc.set_graph(g)                       # version bump: drops every entry
    after = svc.cache.snapshot()
    return {
        "first_pass_misses": warm["misses"],
        "entries": snap["entries"],
        "second_pass_hits": snap["hits"] - warm["hits"],
        "cached_tick_executed": tick["executed"],
        "invalidations": after["invalidations"],
        "entries_after_bump": after["entries"],
    }


def load_report(g, requests: int = 24, clients: int = 4,
                telemetry: Telemetry | None = None) -> dict:
    """Phase 3: burst load through the service vs a sequential session.

    Both sides run warmed (executables traced before timing) and serve the
    identical request stream (``MIXES`` cycled ``requests`` times); burst
    submission keeps the queue deeper than one request so ticks merge."""
    # sequential baseline: one warmed Miner, one request at a time
    miner = Miner(g)
    for qs in MIXES:
        miner.count_many(list(qs))
    lat = []
    t0 = time.perf_counter()
    for i in range(requests):
        t1 = time.perf_counter()
        miner.count_many(list(MIXES[i % len(MIXES)]))
        lat.append(time.perf_counter() - t1)
    seq_wall = time.perf_counter() - t0
    seq = {"qps": requests / max(seq_wall, 1e-9),
           "p50_s": percentile(lat, 50), "p99_s": percentile(lat, 99)}

    # service under burst: warm every executable first, then load
    specs, classes = _specs(0)
    svc = MiningService(g, workers=tuple(specs), cache_results=False,
                        telemetry=telemetry)
    _submit_mix(svc, classes)
    svc.run_until_idle()
    before = svc.stats["retraces"]
    lg = LoadGenerator(svc, list(zip(MIXES, classes)), requests=requests,
                       clients=clients, qps=None)
    res = lg.run()
    assert res["completed"] == requests, res
    return {
        "sequential": {k: round(v, 4) for k, v in seq.items()},
        "service": {"qps": round(res["qps"], 4),
                    "p50_s": round(res["p50_s"], 4),
                    "p99_s": round(res["p99_s"], 4),
                    "feed_passes": res["feed_passes"]},
        "load_retraces": svc.stats["retraces"] - before,
        "load_sharing_ok": bool(res["feed_passes"]["fused"]
                                < res["feed_passes"]["independent"]),
        "qps_vs_sequential": round(res["qps"] / max(seq["qps"], 1e-9), 4),
        "p50_vs_sequential": round(res["p50_s"] / max(seq["p50_s"], 1e-9), 4),
        "p99_vs_sequential": round(res["p99_s"] / max(seq["p99_s"], 1e-9), 4),
    }


def serving_report(g, shards: int = 0, requests: int = 24, clients: int = 4,
                   telemetry: Telemetry | None = None) -> dict:
    """All three phases; ``shards > 1`` adds the mixed sharded pool to the
    batching phase (the load phase stays single-device — thread-per-client
    timing over a mesh is a wall-clock fact, not a determinism fact)."""
    out = {"batching": batching_report(g, shards=shards, telemetry=telemetry)}
    out["cache"] = cache_report(g)
    out["load"] = load_report(g, requests=requests, clients=clients,
                              telemetry=telemetry)
    return out


def main(argv=None):
    import argparse
    import json

    from repro.graph import get_dataset
    from repro.graph.datasets import dataset_stats
    from repro.launch.cli import add_graph_args, add_service_args, \
        add_session_args

    ap = argparse.ArgumentParser()
    add_graph_args(ap)
    add_session_args(ap)
    add_service_args(ap)
    args = ap.parse_args(argv)
    g = get_dataset(args.dataset, scale=args.scale)
    print(f"[serving] {args.dataset} x{args.scale}: {dataset_stats(g)}")
    telemetry = Telemetry(enabled=bool(args.trace))
    rep = serving_report(g, shards=args.shards, requests=args.requests,
                         clients=args.clients, telemetry=telemetry)
    b, c, ld = rep["batching"], rep["cache"], rep["load"]
    print(f"[serving] batching: feed passes "
          f"{b['feed_passes_independent']} -> {b['feed_passes_fused']} "
          f"(sharing {'OK' if b['sharing_ok'] else 'FAIL'}), "
          f"steady retraces {b['steady_retraces']}")
    print(f"[serving] cache: {c['second_pass_hits']} hits / "
          f"{c['first_pass_misses']} misses, bump dropped "
          f"{c['invalidations']} entries")
    print(f"[serving] load: service {ld['service']['qps']:.1f} qps vs "
          f"sequential {ld['sequential']['qps']:.1f} qps "
          f"(x{ld['qps_vs_sequential']:.2f}), p50 x{ld['p50_vs_sequential']}"
          f", p99 x{ld['p99_vs_sequential']}")
    if args.trace:
        print(f"[serving] trace -> {telemetry.write_trace(args.trace)}")
    print(json.dumps(rep, indent=2))


if __name__ == "__main__":
    main()
