"""Benchmark harness entry point — one module per paper table/figure.

  bench_mining    Fig. 8/9/13 (mining speedups vs CPU + exhaustive check)
  bench_kernels   Fig. 11/12  (IU-count / S-Cache-bandwidth analogues)
  bench_streams   Fig. 14     (stream length distributions)
  bench_sparse    Fig. 15     (SpMM / TTV via S_VINTER)
  bench_roofline  EXPERIMENTS.md §Roofline table from dry-run artifacts

Usage: PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (bench_kernels, bench_mining, bench_roofline,
                        bench_sparse, bench_streams)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full dataset sweep (slow); default quick mode")
    ap.add_argument("--only", default="",
                    help="comma list: mining,kernels,streams,sparse,roofline")
    args = ap.parse_args()
    quick = not args.full
    wanted = set(args.only.split(",")) if args.only else None
    suites = {
        "mining": bench_mining.run,
        "kernels": bench_kernels.run,
        "streams": bench_streams.run,
        "sparse": bench_sparse.run,
        "roofline": bench_roofline.run,
    }
    results = {}
    for name, fn in suites.items():
        if wanted and name not in wanted:
            continue
        print(f"\n===== bench: {name} =====", flush=True)
        t0 = time.time()
        try:
            results[name] = fn(quick=quick)
        except Exception as e:  # keep the harness going; record the failure
            print(f"[{name}] FAILED: {e!r}", flush=True)
            results[name] = {"error": repr(e)}
        print(f"===== {name} done in {time.time()-t0:.1f}s =====", flush=True)
    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)

    def default(o):
        return str(o)

    json.dump(results, open(out, "w"), indent=1, default=default)
    print(f"\n[bench] results -> {out}")


if __name__ == "__main__":
    main()
