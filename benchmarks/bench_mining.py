"""Fig. 8 / Fig. 9 / Fig. 13 analogue: mining throughput, IntersectX engine
vs InHouseAutoMine (scalar CPU) vs GRAMER-style exhaustive check.

CPU wall-clock stands in for the paper's zSim cycles; the *relative* trends
the paper claims are what we reproduce: pattern enumeration >> exhaustive
check, engine >> scalar baseline, bigger wins on denser graphs, and
intersection dominating the engine's time (Fig. 13).

Timing rides ``repro.obs``: every timed region is a span on the
module-level ``TELEMETRY`` (``perf_counter`` under the hood), so
``telemetry_snapshot()`` hands consumers (benchmarks/ci_gate.py ->
BENCH_mining.json) the per-report span aggregates instead of bespoke
stopwatch plumbing. The timed runners themselves stay UNTRACED — outer
stopwatch spans only — so no per-dispatch ``block_until_ready`` skews the
gated wall-clock ratios.
"""
from __future__ import annotations

from repro.graph import get_dataset
from repro.graph.datasets import dataset_stats
from repro.mining import baseline, exhaustive
from repro.mining.apps import shared_session
from repro.mining.plan import clique_pattern
from repro.obs import Telemetry

# bench-local telemetry: outer stopwatch spans only (runners untraced)
TELEMETRY = Telemetry(enabled=True)


def telemetry_snapshot() -> dict:
    """Metrics + per-span timing aggregates of every report run so far."""
    return TELEMETRY.snapshot()

# datasets kept CPU-benchable; big twins run scaled (noted in output)
BENCH_SETS = [
    ("citeseer", 1.0), ("email-eu-core", 1.0), ("bitcoinalpha", 1.0),
    ("gnutella", 1.0), ("haverford", 1.0), ("wiki-vote", 1.0),
    ("mico", 0.2), ("youtube", 0.02), ("patent", 0.01), ("livejournal", 0.004),
]
EXHAUSTIVE_SETS = {"citeseer", "gnutella"}   # exponential baseline: small only

# engine side: the stable session API (one shared Miner per graph — same
# warm-cache semantics the deprecated one-shot shims had)
APPS = [
    ("T", lambda g: shared_session(g).count("triangle"),
     lambda g: baseline.triangle_count(g)),
    ("TC", lambda g: shared_session(g).count("three-chain"),
     lambda g: baseline.three_chain_count(g, induced=True)),
    ("TT", lambda g: shared_session(g).count("tailed-triangle"),
     lambda g: baseline.tailed_triangle_count(g)),
    ("4C", lambda g: shared_session(g).count(clique_pattern(4)),
     lambda g: baseline.clique_count(g, 4)),
    ("5C", lambda g: shared_session(g).count(clique_pattern(5)),
     lambda g: baseline.clique_count(g, 5)),
]


def _stopwatch(name: str, fn, **attrs):
    """Run ``fn()`` inside one bench span; returns (result, wall seconds)."""
    with TELEMETRY.tracer.span(name, cat="bench", **attrs) as sp:
        out = fn()
    return out, sp.seconds


def _time(fn, *a, warm: bool = True, label: str | None = None):
    if warm:
        fn(*a)                                 # JIT warm-up excluded
    return _stopwatch(label or getattr(fn, "__name__", "timed"),
                      lambda: fn(*a))


def modeled_tpu_triangle_time(g) -> float:
    """Compute+DMA floor for triangle counting on one v5e core with the
    Pallas tile-overlap schedule: visited tile pairs x (128x128 compares /
    VPU rate) + streamed bytes / HBM bw. The §Roofline methodology applied
    to the mining kernel (no real-TPU wall clock in this container)."""
    import jax.numpy as jnp
    from repro.kernels.intersect import tile_schedule
    from repro.mining.engine import edge_wave, _neighbor_cap
    from repro.graph.csr import padded_rows
    VPU_OPS = 4e12          # int cmp/s per chip (conservative v5e VPU)
    HBM = 819e9
    visits = 0
    bytes_moved = 0
    for wave, n in edge_wave(g, 8192):
        capn = _neighbor_cap(g, wave.verts)
        nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
        lo, nv = tile_schedule(jnp.asarray(wave.rows), nbr,
                               jnp.asarray(wave.verts))
        import numpy as _np
        visits += int(_np.asarray(nv)[:n].sum())
        bytes_moved += n * (wave.rows.shape[1] + capn) * 4
    t_compute = visits * 128 * 128 / VPU_OPS
    t_mem = bytes_moved / HBM
    return max(t_compute, t_mem)


def _level2_dispatches(level_execs: dict) -> int:
    """Dynamic level-2 expand dispatches in a runner's ``level_execs``."""
    return sum(v for (kind, lv), v in level_execs.items()
               if kind == "expand" and lv == 2)


def wave_throughput_report(g, k: int = 4) -> dict:
    """Before/after the device-resident rewrite: work items/s through the
    expand -> compact -> next-wave loop on a warmed executable cache.

    'host' routes every level through the np.nonzero + re-upload oracle;
    'device' keeps the worklist on device (ops.xinter_compact) with only
    the 3-scalar meta sync per level. Same counts by construction (tested
    bit-identical in tests/test_wave_device.py)."""
    from repro.mining.engine import WaveRunner
    out = {}
    for label, dc in (("host", False), ("device", True)):
        runner = WaveRunner(g, device_compact=dc)
        runner.clique(k)                    # warm-up: traces + compiles
        warm = dict(runner.stats)
        count, dt = _stopwatch(f"wave_throughput:{label}",
                               lambda: runner.clique(k))
        items = runner.stats["items"] - warm["items"]
        out[label] = {
            "count": count, "seconds": round(dt, 4), "items": items,
            "items_per_s": round(items / max(dt, 1e-9), 1),
            # per-timed-run deltas: the warm-up pass must not inflate these
            "host_compactions": (runner.stats["host_compactions"]
                                 - warm["host_compactions"]),
            "device_compactions": (runner.stats["device_compactions"]
                                   - warm["device_compactions"]),
            "exec_misses": runner.stats["exec_misses"] - warm["exec_misses"],
        }
    assert out["host"]["count"] == out["device"]["count"]
    out["wave_speedup"] = round(
        out["host"]["seconds"] / max(out["device"]["seconds"], 1e-9), 2)
    return out


def forest_fusion_report(g) -> dict:
    """Fused multi-pattern mining (PlanForest) vs six independent WavePlans.

    Reports wall time, *dynamic* level-2 expand executions (executable
    dispatches per edge-feed chunk — the redundancy the forest removes) and
    the static sharing stats for the 4-motif batch, on warmed executable
    caches. Counts are asserted bit-identical, the acceptance contract of
    ``mining.forest``."""
    from repro.mining.engine import WaveRunner
    from repro.mining.forest import build_forest
    from repro.mining.plan import FOUR_MOTIFS, compile_pattern
    plans = [compile_pattern(p) for p in FOUR_MOTIFS.values()]
    forest = build_forest(plans)
    # independent: each plan its own run (shared runner = shared exec cache)
    runner_i = WaveRunner(g)
    [runner_i.run(pl) for pl in plans]          # warm-up
    runner_i.level_execs.clear()
    indep, t_ind = _stopwatch("forest_fusion:independent",
                              lambda: [runner_i.run(pl) for pl in plans])
    # fused: one forest pass
    runner_f = WaveRunner(g)
    runner_f.run_set(forest)                    # warm-up
    runner_f.level_execs.clear()
    fused, t_fus = _stopwatch("forest_fusion:fused",
                              lambda: runner_f.run_set(forest))
    assert fused == indep, (fused, indep)
    st = forest.sharing_stats()
    out = {
        "counts": dict(zip(FOUR_MOTIFS, fused)),
        "independent_s": round(t_ind, 4), "fused_s": round(t_fus, 4),
        "fusion_speedup": round(t_ind / max(t_fus, 1e-9), 2),
        # dynamic: level-2 expand dispatches actually issued per pass
        "level2_execs_independent": _level2_dispatches(runner_i.level_execs),
        "level2_execs_fused": _level2_dispatches(runner_f.level_execs),
        # static: trie shape (6 plan ops -> 3 shared nodes for 4-motif)
        "level2_ops_static": (
            sum(v for (k, lv), v in st["plan_ops"].items() if lv == 2),
            sum(v for (k, lv), v in st["forest_ops"].items() if lv == 2)),
        "feed_passes": (st["feed_passes"]["independent"],
                        st["feed_passes"]["fused"]),
    }
    return out


def fused_level_report(g) -> dict:
    """Fused k-operand level kernel vs the per-ref mark fallback.

    4-cycle's terminal level references two streams (v3 ∈ N(v1) ∩ N(v2) \\
    N(v0) after the base pull: one INTER + one SUB ref), so the per-ref path
    issues k=2 membership dispatches per executable call where the fused
    path (``ops.xlevel_count``) issues exactly 1 — the per-operand B-tile
    DMA the tentpole removes. Counts are asserted bit-identical; dispatch
    counts come from ``WaveRunner.stats['level_kernel_dispatches']``."""
    from repro.mining.engine import WaveRunner
    from repro.mining.plan import CYCLE4, compile_pattern
    plan = compile_pattern(CYCLE4)
    k_general = len(plan.ops[-1].inter) + len(plan.ops[-1].sub)
    out = {}
    for label, fl in (("per_ref", False), ("fused", True)):
        runner = WaveRunner(g, fused_level=fl)
        runner.run(plan)                    # warm-up: traces + compiles
        warm = dict(runner.stats)
        warm_execs = dict(runner.level_execs)
        count, dt = _stopwatch(f"fused_level:{label}",
                               lambda: runner.run(plan))
        gen_execs = (runner.level_execs.get(("count", 3), 0)
                     - warm_execs.get(("count", 3), 0))
        dispatches = (runner.stats["level_kernel_dispatches"]
                      - warm["level_kernel_dispatches"])
        out[label] = {
            "count": count, "seconds": round(dt, 4),
            "kernel_dispatches": dispatches,
            "general_level_execs": gen_execs,
        }
    assert out["fused"]["count"] == out["per_ref"]["count"]
    # isolate the general level: the single-op level-2 dispatches (one each,
    # identical in both modes) are whatever the fused run spent beyond its
    # one-per-general-level — the acceptance metric is k -> 1 per level
    n = out["fused"]["general_level_execs"]
    shared = out["fused"]["kernel_dispatches"] - n
    for label in ("per_ref", "fused"):
        out[label]["dispatches_per_general_level"] = round(
            (out[label]["kernel_dispatches"] - shared) / max(n, 1), 2)
    out["k_general"] = k_general
    out["fused_level_speedup"] = round(
        out["per_ref"]["seconds"] / max(out["fused"]["seconds"], 1e-9), 2)
    return out


def session_serving_report(g) -> dict:
    """One ``Miner`` session serving the full app mix back-to-back.

    Two identical passes of {T, TC, TT, 4C, fused 4M} on one session: the
    first pass pays schedule search + tracing, the second must be pure
    cache hits — ``retraces_second_pass`` is the session-reuse acceptance
    counter (0, gated exactly in benchmarks/ci_gate.py) and the
    auto-scheduled 4-motif forest stats (static level-2 nodes, dynamic
    level-2 dispatches per pass, feed passes) are schedule facts."""
    from repro.mining.plan import FOUR_MOTIF_SHAPES
    from repro.mining.session import Miner
    miner = Miner(g)
    names = list(FOUR_MOTIF_SHAPES)
    lvl2_4m: list = []                   # level-2 dispatches of each 4M batch

    def mix():
        out = {"T": miner.count("triangle"),
               "TC": miner.count("three-chain"),
               "TT": miner.count("tailed-triangle"),
               "4C": miner.count("4-clique")}
        before = _level2_dispatches(miner.runner.level_execs)
        out["4M"] = dict(zip(names, miner.count_many(names)))
        lvl2_4m.append(_level2_dispatches(miner.runner.level_execs) - before)
        return out

    first, t_first = _stopwatch("session_serving:first_pass", mix)
    retraces_first = miner.stats["retraces"]
    second, t_second = _stopwatch("session_serving:second_pass", mix)
    assert first == second, (first, second)
    st = miner.schedule(names).sharing_stats()
    return {
        "counts": first,
        "first_pass_s": round(t_first, 4),
        "second_pass_s": round(t_second, 4),
        "warm_speedup": round(t_first / max(t_second, 1e-9), 2),
        # the session-reuse contract: second pass builds NO new executables
        "retraces_first_pass": retraces_first,
        "retraces_second_pass": miner.stats["retraces"] - retraces_first,
        "exec_cache": miner.stats["exec_cache"],
        # auto-scheduled 4-motif forest facts (no hand-ordered patterns)
        "level2_execs_per_pass": lvl2_4m[0],
        "level2_nodes_static": sum(
            v for (k, lv), v in st["forest_ops"].items()
            if k == "expand" and lv == 2),
        "feed_passes": st["feed_passes"]["fused"],
    }


def svpu_report(g) -> dict:
    """SVPU value plane: weighted aggregates vs their unweighted twins.

    One session on the weight-attached graph runs {T, 4C} as counts and
    as SUM aggregates, fully warmed, and reports per-pass kernel
    dispatches / feed chunks for both paths — the zero-overhead contract
    is that the value lanes RIDE the membership dispatches
    (``dispatch_parity_ok`` / ``feed_parity_ok``), weighted wall clock
    stays within a small ratio of unweighted (``weighted_overhead``) and
    the second pass retraces nothing. ``oracle_check`` cross-checks
    sum/max/min against the host-float64 permutation oracle on a tiny
    fixed graph — exact equality, the dyadic-weight guarantee."""
    from repro.graph import build_csr, edge_weights, with_edge_values
    from repro.graph.csr import edge_list
    from repro.graph.generators import erdos_renyi
    from repro.mining import reference
    from repro.mining.plan import TRIANGLE, clique_pattern
    from repro.mining.session import Miner

    gw = with_edge_values(g, edge_weights(edge_list(g), seed=0))
    m = Miner(gw)
    queries = [("T", "triangle"), ("4C", "4-clique")]
    for _, q in queries:                     # warm both paths: traces, plans
        m.count(q)
        m.aggregate(q, op="sum")
    warm_retraces = m.stats["retraces"]
    lanes0 = m.runner.metrics.value("value_lane_dispatches")
    out: dict = {"queries": {}}
    for app, q in queries:
        row: dict = {}
        for mode, fn in (("count", lambda q=q: m.count(q)),
                         ("aggregate", lambda q=q: m.aggregate(q, op="sum"))):
            rs = m.runner.stats
            d0 = rs["level_kernel_dispatches"]
            f0 = m.runner.metrics.value("feed_chunks")
            res, dt = _stopwatch(f"svpu:{app}:{mode}", fn)
            row[mode] = {
                "result": res, "seconds": round(dt, 4),
                "dispatches": rs["level_kernel_dispatches"] - d0,
                "feed_chunks": m.runner.metrics.value("feed_chunks") - f0,
            }
        row["dispatch_parity_ok"] = (row["aggregate"]["dispatches"]
                                     == row["count"]["dispatches"])
        row["feed_parity_ok"] = (row["aggregate"]["feed_chunks"]
                                 == row["count"]["feed_chunks"])
        row["weighted_overhead"] = round(
            row["aggregate"]["seconds"]
            / max(row["count"]["seconds"], 1e-9), 3)
        out["queries"][app] = row
    out["retraces_second_pass"] = m.stats["retraces"] - warm_retraces
    out["value_lane_dispatches"] = (
        m.runner.metrics.value("value_lane_dispatches") - lanes0)
    out["weighted_overhead"] = round(
        sum(r["aggregate"]["seconds"] for r in out["queries"].values())
        / max(sum(r["count"]["seconds"] for r in out["queries"].values()),
              1e-9), 3)

    tg = build_csr(erdos_renyi(22, 80, seed=5), 22)
    tgw = with_edge_values(tg, edge_weights(edge_list(tg), seed=3))
    mt = Miner(tgw)
    checks: dict = {}
    exact = True
    for name, pat in (("triangle", TRIANGLE), ("4-clique", clique_pattern(4))):
        checks[name] = {}
        for op in ("sum", "max", "min"):
            got = mt.aggregate(pat, op=op)
            checks[name][op] = got
            exact = exact and (
                got == reference.weighted_pattern_oracle(tgw, pat, op))
    out["oracle_check"] = {"values": checks, "exact_match": exact}
    return out


def sharded_scaling_report(g, shard_counts=(1, 2, 4, 8)) -> dict:
    """Mesh-sharded session vs single device: the full app mix {T, TC, TT,
    4C, fused 4M} on 1/2/4/8(-fake-CPU)-device meshes from one ``Miner``
    each (on CPU, devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

    Per mesh width the report records the warm second-pass wall clock,
    per-shard dynamic dispatches (every executable call is one lockstep
    dispatch on each shard, so the host-side dispatch count IS the
    per-shard count), psum leaf reductions, the per-shard feed-item split
    and its max/min balance ratio, plus ``speedup_vs_1dev``. Counts are
    asserted bit-identical across widths.

    ``dispatch_scaling_ok`` is the scaling acceptance: per-shard dispatches
    on an S-way mesh must be <= single-device dispatches / S + a per-level
    constant. Every dispatch happens inside a chunking loop (the level-1
    feed or a compacted-worklist slice loop) whose sharded step count is
    <= ceil(single-device steps / S): summing the ceil tax over all
    executable call sites x degree buckets gives the static allowance
    (``dispatch_allowance`` = plan/forest op sites x feed buckets)."""
    import jax
    import numpy as np
    from repro.mining.engine import _pow2cap
    from repro.mining.plan import FOUR_MOTIF_SHAPES
    from repro.mining.session import Miner
    names = list(FOUR_MOTIF_SHAPES)
    deg = np.asarray(g.degrees)
    n_buckets = len(np.unique(
        [_pow2cap(max(int(d), 1)) for d in deg[deg > 0]])) or 1
    out: dict = {"devices_visible": jax.device_count(),
                 "shard_counts": [], "per_mesh": {}}
    ref_counts = None

    for s in shard_counts:
        if s > jax.device_count():
            out["per_mesh"][str(s)] = {
                "skipped": f"only {jax.device_count()} device(s) visible"}
            continue
        miner = Miner(g, mesh=None if s == 1 else s)

        def mix():
            res = {"T": miner.count("triangle"),
                   "TC": miner.count("three-chain"),
                   "TT": miner.count("tailed-triangle"),
                   "4C": miner.count("4-clique")}
            res.update(zip(names, miner.count_many(names)))
            return res

        mix()                                   # warm-up: traces + schedules
        warm = {"retraces": miner.stats["retraces"],
                "dispatches": sum(miner.runner.level_execs.values()),
                "psums": miner.stats["runner"].get("psum_reductions", 0)}
        counts, dt = _stopwatch(f"sharded_scaling:x{s}", mix)
        if ref_counts is None:
            ref_counts = counts
        assert counts == ref_counts, (s, counts, ref_counts)
        rs = miner.stats["runner"]
        feed = rs.get("shard_feed_items")
        row = {
            "counts": counts,
            "wall_s": round(dt, 4),
            "dispatches_per_pass": (sum(miner.runner.level_execs.values())
                                    - warm["dispatches"]),
            "retraces_second_pass": miner.stats["retraces"]
            - warm["retraces"],
            "psum_reductions_per_pass": rs.get("psum_reductions", 0)
            - warm["psums"],
        }
        if feed is not None:
            half = [v // 2 for v in feed]       # two passes accumulated
            row["shard_feed_items"] = half
            row["feed_balance_ratio"] = round(
                max(half) / max(min(half), 1), 3)
        # executable call sites per pass — a schedule fact, identical for
        # every mesh width; sizes the per-level dispatch allowance
        if "n_sites" not in out:
            sites = sum(len(miner.compile(q).ops) for q in
                        ("triangle", "three-chain", "tailed-triangle",
                         "4-clique"))
            forest = miner.schedule(names)
            stack = list(forest.symmetric_roots) + \
                list(forest.directed_roots)
            while stack:
                node = stack.pop()
                sites += 1
                stack.extend(node.children)
            out["n_sites"] = sites
        out["per_mesh"][str(s)] = row
        out["shard_counts"].append(s)

    out["n_buckets"] = n_buckets
    base = out["per_mesh"].get("1")
    if base and "wall_s" in base:
        # ceil tax of dividing every chunking loop's steps over S shards:
        # at most one extra step per call site per degree bucket
        allowance = n_buckets * out["n_sites"]
        for s in out["shard_counts"]:
            row = out["per_mesh"][str(s)]
            row["speedup_vs_1dev"] = round(
                base["wall_s"] / max(row["wall_s"], 1e-9), 2)
            if s > 1:
                row["dispatch_allowance"] = allowance
                row["dispatch_scaling_ok"] = bool(
                    row["dispatches_per_pass"]
                    <= base["dispatches_per_pass"] / s + allowance)
    return out


def plan_overhead_report(g) -> dict:
    """Interpreter tax: the same clique/TT workloads through compiled
    ``WavePlan``s vs the frozen pre-refactor hand-coded engine paths
    (``benchmarks/handcoded_ref.py``), both on warmed executable caches.

    The compiler's carry analysis + fused fast paths should make the plan
    path issue the identical executable sequence, so the ratio isolates the
    pure Python dispatch overhead of interpreting the plan."""
    try:
        from benchmarks.handcoded_ref import HandCodedRunner
    except ImportError:                       # run as a script from benchmarks/
        from handcoded_ref import HandCodedRunner
    from repro.mining.engine import WaveRunner
    out = {}
    for app, plan_fn, hand_fn in [
        ("4C", lambda r: r.clique(4), lambda r: r.clique(4)),
        ("TT", lambda r: r.tailed_triangle(), lambda r: r.tailed_triangle()),
    ]:
        plan_r, hand_r = WaveRunner(g), HandCodedRunner(g)
        res_p, t_p = _time(lambda: plan_fn(plan_r))
        res_h, t_h = _time(lambda: hand_fn(hand_r))
        assert res_p == res_h, (app, res_p, res_h)
        out[app] = {"count": res_p, "plan_s": round(t_p, 4),
                    "handcoded_s": round(t_h, 4),
                    "plan_overhead": round(t_p / max(t_h, 1e-9), 3)}
    return out


def run(quick: bool = True):
    rows = []
    sets = BENCH_SETS[:6] if quick else BENCH_SETS
    for name, scale in sets:
        g = get_dataset(name, scale=scale)
        stats = dataset_stats(g)
        t_tpu = modeled_tpu_triangle_time(g)
        print(f"[mining] {name:14s} modeled v5e triangle kernel floor: "
              f"{t_tpu*1e3:.2f} ms (schedule-derived)", flush=True)
        wt = wave_throughput_report(g)
        print(f"[mining] {name:14s} 4C wave loop: "
              f"host {wt['host']['items_per_s']:.0f} items/s "
              f"({wt['host']['host_compactions']} np.nonzero round-trips) | "
              f"device {wt['device']['items_per_s']:.0f} items/s "
              f"(0 host round-trips) | wave_speedup={wt['wave_speedup']}x",
              flush=True)
        rows.append(dict(dataset=name, app="4C-wave", **{
            "host_items_per_s": wt["host"]["items_per_s"],
            "device_items_per_s": wt["device"]["items_per_s"],
            "wave_speedup": wt["wave_speedup"]}))
        po = plan_overhead_report(g)
        print(f"[mining] {name:14s} plan vs hand-coded: "
              + " | ".join(f"{a} {v['plan_s']:.3f}s vs {v['handcoded_s']:.3f}s "
                           f"(overhead {v['plan_overhead']}x)"
                           for a, v in po.items()), flush=True)
        rows.append(dict(dataset=name, app="plan-overhead", **{
            f"{a}_{k}": v[k] for a, v in po.items()
            for k in ("plan_s", "handcoded_s", "plan_overhead")}))
        fl = fused_level_report(g)
        print(f"[mining] {name:14s} CY fused level: "
              f"{fl['per_ref']['dispatches_per_general_level']:.0f} -> "
              f"{fl['fused']['dispatches_per_general_level']:.0f} membership "
              f"dispatches per general level (k={fl['k_general']}) | "
              f"fused {fl['fused']['seconds']:.3f}s vs per-ref "
              f"{fl['per_ref']['seconds']:.3f}s "
              f"(speedup {fl['fused_level_speedup']}x)", flush=True)
        rows.append(dict(dataset=name, app="CY-fused-level", **{
            "per_ref_dispatches": fl["per_ref"]["kernel_dispatches"],
            "fused_dispatches": fl["fused"]["kernel_dispatches"],
            "fused_level_speedup": fl["fused_level_speedup"]}))
        if name == "email-eu-core":
            import jax as _jax
            sr = sharded_scaling_report(g)
            for s in sr["shard_counts"]:
                pm = sr["per_mesh"][str(s)]
                print(f"[mining] {name:14s} mesh x{s}: "
                      f"{pm['wall_s']:.3f}s "
                      f"({pm['dispatches_per_pass']} dispatches/pass, "
                      f"{pm['psum_reductions_per_pass']} psums, "
                      f"speedup {pm.get('speedup_vs_1dev', 1.0)}x"
                      + (f", feed ratio {pm['feed_balance_ratio']}"
                         if "feed_balance_ratio" in pm else "")
                      + (", dispatch scaling "
                         + ("OK" if pm.get("dispatch_scaling_ok") else "FAIL")
                         if s > 1 else "") + ")", flush=True)
                rows.append(dict(
                    dataset=name, app=f"sharded-x{s}",
                    wall_s=pm["wall_s"],
                    dispatches_per_pass=pm["dispatches_per_pass"],
                    psum_reductions_per_pass=pm["psum_reductions_per_pass"],
                    retraces_second_pass=pm["retraces_second_pass"],
                    speedup_vs_1dev=pm.get("speedup_vs_1dev", 1.0),
                    **({"feed_balance_ratio": pm["feed_balance_ratio"]}
                       if "feed_balance_ratio" in pm else {}),
                    **({"dispatch_scaling_ok": pm["dispatch_scaling_ok"]}
                       if "dispatch_scaling_ok" in pm else {})))
            if any("skipped" in v for v in sr["per_mesh"].values()):
                print(f"[mining] {name:14s} mesh: only "
                      f"{_jax.device_count()} device(s) visible — set "
                      "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                      "for the full scaling sweep", flush=True)
        sv = svpu_report(g)
        qT, q4 = sv["queries"]["T"], sv["queries"]["4C"]
        print(f"[mining] {name:14s} SVPU weighted: overhead "
              f"T {qT['weighted_overhead']}x / 4C {q4['weighted_overhead']}x"
              f" | dispatch parity "
              + ("OK" if qT["dispatch_parity_ok"] and q4["dispatch_parity_ok"]
                 else "FAIL")
              + f" | oracle "
              + ("exact" if sv["oracle_check"]["exact_match"] else "MISMATCH")
              + f" | retraces {sv['retraces_second_pass']}", flush=True)
        rows.append(dict(dataset=name, app="SVPU", **{
            "weighted_overhead": sv["weighted_overhead"],
            "dispatch_parity_ok": qT["dispatch_parity_ok"]
            and q4["dispatch_parity_ok"],
            "oracle_exact": sv["oracle_check"]["exact_match"],
            "retraces_second_pass": sv["retraces_second_pass"]}))
        ff = forest_fusion_report(g)
        print(f"[mining] {name:14s} 4M forest fusion: "
              f"fused {ff['fused_s']:.3f}s vs independent "
              f"{ff['independent_s']:.3f}s "
              f"(speedup {ff['fusion_speedup']}x) | L2 expands "
              f"{ff['level2_execs_independent']} -> "
              f"{ff['level2_execs_fused']} dispatches "
              f"(static {ff['level2_ops_static'][0]} -> "
              f"{ff['level2_ops_static'][1]} ops) | feed passes "
              f"{ff['feed_passes'][0]} -> {ff['feed_passes'][1]}", flush=True)
        rows.append(dict(dataset=name, app="4M-forest", **{
            k: ff[k] for k in ("independent_s", "fused_s", "fusion_speedup",
                               "level2_execs_independent",
                               "level2_execs_fused")}))
        for app, engine_fn, base_fn in APPS:
            if quick and app == "5C" and stats["avg_deg"] > 30:
                continue                      # dense 5C: slow scalar baseline
            res, t_eng = _time(engine_fn, g)
            res2, t_base = _time(base_fn, g)
            assert res == res2, (name, app, res, res2)
            row = dict(dataset=name, scale=scale, app=app, count=res,
                       engine_s=round(t_eng, 4), automine_s=round(t_base, 4),
                       speedup=round(t_base / max(t_eng, 1e-9), 2))
            if name in EXHAUSTIVE_SETS and app in ("T", "4C"):
                pat = {"T": "triangle", "4C": "4-clique"}[app]
                _, t_ex = _time(exhaustive.exhaustive_count, g, pat)
                row["exhaustive_s"] = round(t_ex, 4)
                row["speedup_vs_exhaustive"] = round(t_ex / max(t_eng, 1e-9), 2)
            rows.append(row)
            print(f"[mining] {name:14s} {app:3s} count={res!s:>12} "
                  f"engine={t_eng:7.3f}s automine={t_base:7.3f}s "
                  f"speedup={row['speedup']:7.2f}x"
                  + (f" exhaustive={row.get('exhaustive_s')}s" if "exhaustive_s" in row else ""),
                  flush=True)
    return rows


if __name__ == "__main__":
    run(quick=False)
