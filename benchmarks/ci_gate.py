"""CI perf-regression gate over the mining benchmarks.

Runs a small-graph subset of ``bench_mining``'s reports, writes the result
to ``BENCH_mining.json`` (uploaded as a CI artifact) and compares it
against the checked-in ``benchmarks/baseline.json``:

* **exact metrics** — mining counts and structural counters (forest level-2
  dispatch/feed counts, fused-level membership dispatches per general
  level). The datasets are deterministic synthetic generators and the
  counters are schedule facts, so these are machine-independent and must
  match the baseline EXACTLY: any drift is a correctness or scheduling
  regression, not noise.
* **ratio metrics** — wall-clock ratios (plan interpreter overhead, forest
  fusion speedup, fused-level speedup, device-vs-host wave speedup).
  Ratios, not absolute times, so they transfer across machines, but CI
  runners are noisy: a metric only fails when it is worse than baseline by
  more than its tolerance (per-metric ``tolerances`` in baseline.json,
  direction from ``directions``: for ``higher_better`` a regression is
  ``got < base * (1 - tol)``, for ``lower_better`` it is
  ``got > base * (1 + tol)``).

Usage (CI runs exactly this):

    PYTHONPATH=src python benchmarks/ci_gate.py \
        --out BENCH_mining.json --baseline benchmarks/baseline.json

``--update-baseline`` rewrites baseline.json from the current measurement
(keeping tolerances/directions) — run locally when a PR legitimately moves
a ratio, and say so in the PR.

``--telemetry`` adds the observability parity section (exact keys): the
``repro.obs`` registry-derived stats view must equal the legacy counters
bit-for-bit on the full app mix, tracing on must change nothing (zero
extra dispatches), and span counts per category are recorded as schedule
facts. The traced span timings land in the output JSON (artifact) under
``telemetry_spans`` but are never baselined — they are wall clock.

``--values`` adds the SVPU value-plane section: weighted sum/max/min
aggregates must equal the host-float64 permutation oracle EXACTLY (dyadic
weights make every aggregate representable in f32), the weighted query's
kernel-dispatch and feed-chunk counters must equal the unweighted twin's
(value lanes ride, never add), repeats retrace nothing, and the
weighted-vs-unweighted wall-clock ratio is tolerance-gated.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.bench_mining import (fused_level_report,   # noqa: E402
                                     forest_fusion_report,
                                     plan_overhead_report,
                                     session_serving_report,
                                     sharded_scaling_report,
                                     svpu_report,
                                     wave_throughput_report)

# exact app counts: small + cheap (deterministic synthetic graphs)
COUNT_SETS = [("citeseer", 1.0), ("email-eu-core", 0.25)]
# session-API smoke: one Miner serving the app mix twice on this set
SESSION_SET = ("email-eu-core", 0.25)
# mesh-sharded leg (--sharded, needs >= 8 devices: CI sets
# XLA_FLAGS=--xla_force_host_platform_device_count=8): counts parity,
# shard/psum counters, retraces and the dispatch-scaling bound
SHARDED_SET = ("email-eu-core", 0.25)
SHARDED_WIDTHS = (1, 8)
# telemetry leg (--telemetry): registry-derived stats view must equal the
# legacy counters bit-for-bit, and enabling tracing must not change them
TELEMETRY_SET = ("email-eu-core", 0.25)
# serving leg (--serving): concurrent MiningService facts — cross-request
# feed-pass sharing, steady/load retraces, result-cache counters (exact)
# plus the qps/p99 ratios vs a sequential session (tolerance-gated)
SERVING_SET = ("email-eu-core", 0.25)
# values leg (--values): SVPU weighted aggregates — exact oracle equality,
# dispatch/feed parity vs the unweighted twin, zero repeat retraces (exact)
# plus the weighted-overhead wall-clock ratio (tolerance-gated)
VALUES_SET = ("email-eu-core", 0.25)
# wall-clock ratios + structural counters: dense enough that the timed
# region is hundreds of ms, not noise (see stability note in tolerances)
PERF_SET = ("email-eu-core", 1.0)

# optional gate sections: each key prefix only exists in a run that passed
# the matching flag; compare()/--update-baseline treat absent sections as
# "not run this leg", never as regressions
SECTION_PREFIXES = ("sharded.", "telemetry.", "serving.", "values.")

# ratio tolerances (fractional, see module docstring) — generous because CI
# wall clock is shared-runner noisy; the exact counters carry the precise
# regression signal, the ratios catch order-of-magnitude slumps.
DEFAULT_TOLERANCES = {
    "plan_overhead_4C": 0.6,
    "plan_overhead_TT": 0.8,
    "fusion_speedup": 0.5,
    "fused_level_speedup": 0.5,
    "wave_speedup": 0.6,
    # service vs sequential: thread scheduling + queueing make these the
    # noisiest gated ratios (p50 is artifact-only for the same reason)
    "qps_vs_sequential": 0.6,
    "p99_vs_sequential": 2.0,
    # weighted vs unweighted wall clock: both sides are warmed identical
    # dispatch sequences, but the value lanes add per-dispatch work inside
    # the kernel, so gate only order-of-magnitude slumps
    "weighted_overhead": 0.8,
}
DIRECTIONS = {
    "plan_overhead_4C": "lower_better",
    "plan_overhead_TT": "lower_better",
    "fusion_speedup": "higher_better",
    "fused_level_speedup": "higher_better",
    "wave_speedup": "higher_better",
    "qps_vs_sequential": "higher_better",
    "p99_vs_sequential": "lower_better",
    "weighted_overhead": "lower_better",
}


def measure_sharded(exact: dict) -> None:
    """Mesh-sharded gate section (CI's multi-device leg): every key is an
    exact schedule/count fact under 8 fake CPU devices.

    * counts parity — the sharded mix must equal the 1-device mix
      bit-for-bit (asserted inside ``sharded_scaling_report``; the counts
      land in the baseline once);
    * retraces — a repeated sharded pass builds 0 new executables;
    * dispatch/psum counters — per-shard dispatches and psum leaf
      reductions per pass are schedule facts, including the scaling bound
      ``dispatches_8 <= dispatches_1 / 8 + allowance``;
    * feed balance — the round-robin partitioner's per-shard feed items on
      FULL email-eu-core (host-only sweep, no mining) with the max/min
      ratio <= 2 acceptance bound.
    """
    import jax
    from repro.graph import get_dataset
    from repro.mining.engine import choose_chunk
    from repro.mining.shard import shard_edge_steps
    if jax.device_count() < max(SHARDED_WIDTHS):
        raise SystemExit(
            f"[gate] --sharded needs {max(SHARDED_WIDTHS)} devices, have "
            f"{jax.device_count()}: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={max(SHARDED_WIDTHS)}")

    name, scale = SHARDED_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: sharded scaling ...", flush=True)
    sr = sharded_scaling_report(g, SHARDED_WIDTHS)
    s_max = max(SHARDED_WIDTHS)
    many = sr["per_mesh"][str(s_max)]
    exact[f"sharded.{tag}.counts"] = many["counts"]
    exact[f"sharded.{tag}.retraces_second_pass"] = \
        many["retraces_second_pass"]
    exact[f"sharded.{tag}.dispatches_per_pass"] = {
        str(s): sr["per_mesh"][str(s)]["dispatches_per_pass"]
        for s in SHARDED_WIDTHS}
    exact[f"sharded.{tag}.psum_reductions_per_pass"] = \
        many["psum_reductions_per_pass"]
    exact[f"sharded.{tag}.shard_feed_items_{s_max}"] = \
        many["shard_feed_items"]
    exact[f"sharded.{tag}.dispatch_scaling_ok"] = \
        bool(many["dispatch_scaling_ok"])

    # full-graph partitioner balance: host-only feed sweep, no mining
    g_full = get_dataset(name, scale=1.0)
    chunk = min(choose_chunk(g_full.padded_max_degree), 1 << 15)
    items = [0] * s_max
    for _cap, _v0, _v1, n in shard_edge_steps(g_full, chunk, s_max):
        for s in range(s_max):
            items[s] += int(n[s])
    ratio = max(items) / max(min(items), 1)
    exact[f"sharded.{name}.feed_items_{s_max}"] = items
    exact[f"sharded.{name}.feed_balance_ratio_le_2"] = bool(ratio <= 2.0)
    print(f"[gate] sharded: feed ratio {ratio:.3f} on {name}, "
          f"dispatches {exact[f'sharded.{tag}.dispatches_per_pass']}, "
          f"{many['psum_reductions_per_pass']} psums/pass", flush=True)


def measure_telemetry(exact: dict, sharded: bool = False) -> dict:
    """Telemetry gate section (``--telemetry``): the ``repro.obs`` registry
    is the source of truth for runner/session counters and the legacy
    ``stats`` dicts are derived views — this leg runs the full app mix on
    one traced ``Miner`` and one untraced one, then records as exact keys:

    * ``registry_equals_legacy`` — every legacy stats key read back through
      the public ``MetricsRegistry`` API matches the view bit-for-bit
      (including the per-shard ``shard_feed_items`` labeled series);
    * ``enabled_disabled_parity`` — counts AND the complete stats dict are
      identical with tracing on vs off (tracing is observationally free:
      zero extra kernel dispatches, no counter drift);
    * the traced run's runner/session counters and per-category span counts
      — all schedule facts, machine-independent.

    With ``--sharded`` too, the same checks repeat on a mesh=8 session.
    Returns the traced spans summary (seconds — machine-dependent, so it
    rides in the output doc ungated, never in the baseline)."""
    from repro.graph import get_dataset
    from repro.mining.plan import FOUR_MOTIF_SHAPES
    from repro.mining.session import Miner
    from repro.obs import Telemetry

    name, scale = TELEMETRY_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    motifs = list(FOUR_MOTIF_SHAPES)

    def mix(miner):
        return {"T": miner.count("triangle"),
                "TC": miner.count("three-chain"),
                "TT": miner.count("tailed-triangle"),
                "4C": miner.count("4-clique"),
                "4M": list(miner.count_many(motifs))}

    spans_doc: dict = {}
    for mesh in [None] + ([8] if sharded else []):
        mtag = tag if mesh is None else f"{tag}.mesh{mesh}"
        print(f"[gate] {mtag}: telemetry parity ...", flush=True)
        telemetry = Telemetry(enabled=True)
        traced = Miner(g, mesh=mesh, telemetry=telemetry)
        counts = mix(traced)
        plain = Miner(g, mesh=mesh)
        counts_plain = mix(plain)

        # legacy view == registry, re-read through the public metrics API
        # (a drifted exposure — wrong counter bound to a key — fails here)
        reg = telemetry.metrics
        rs = dict(traced.runner.stats)
        reg_ok = all(reg.value(k) == v for k, v in rs.items()
                     if not isinstance(v, list))
        if "shard_feed_items" in rs:
            fam = reg.series("shard_feed_items")
            per = [fam[(("shard", s),)].value
                   for s in range(len(rs["shard_feed_items"]))]
            reg_ok = reg_ok and per == rs["shard_feed_items"]
        sess = traced.stats
        sess_keys = ("queries", "plan_hits", "plan_misses",
                     "schedule_hits", "schedule_misses")
        reg_ok = reg_ok and all(reg.value(k) == sess[k] for k in sess_keys)

        by_cat: dict[str, int] = {}
        for sp in telemetry.tracer.spans():
            by_cat[sp.cat] = by_cat.get(sp.cat, 0) + 1

        exact[f"telemetry.{mtag}.registry_equals_legacy"] = bool(reg_ok)
        exact[f"telemetry.{mtag}.enabled_disabled_parity"] = bool(
            counts == counts_plain and sess == plain.stats)
        exact[f"telemetry.{mtag}.runner_stats"] = rs
        exact[f"telemetry.{mtag}.session_counters"] = {
            k: sess[k] for k in sess_keys}
        exact[f"telemetry.{mtag}.span_counts"] = dict(sorted(by_cat.items()))
        spans_doc[mtag] = telemetry.snapshot()["spans"]
        print(f"[gate] telemetry {mtag}: registry==legacy {reg_ok}, "
              f"spans {by_cat}", flush=True)
    return spans_doc


def measure_serving(exact: dict, ratios: dict, sharded: bool = False,
                    trace_telemetry=None) -> dict:
    """Serving gate section (``--serving``): the concurrent MiningService
    on the small deterministic set.

    Exact keys: the batched counts, the cross-request feed-pass sharing
    facts (``fused < independent`` is the batching acceptance), zero
    steady-state retraces — including under threaded burst load — and the
    result-cache hit/invalidation counters. With ``--sharded`` too, the
    mixed sharded/unsharded pool repeats the batching phase on a mesh=8
    bulk worker and must reproduce the same counts. Gated ratios:
    qps/p99 of the loaded service vs a sequential warmed session.
    Returns the artifact-only wall-clock details (absolute latencies)."""
    from benchmarks.bench_serving import batching_report, cache_report, \
        load_report
    from repro.graph import get_dataset

    name, scale = SERVING_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: serving (batching + cache) ...", flush=True)
    b = batching_report(g, telemetry=trace_telemetry)
    exact[f"serving.{tag}.counts"] = b["counts"]
    exact[f"serving.{tag}.batch_requests"] = b["batch_requests"]
    exact[f"serving.{tag}.feed_passes"] = [
        b["feed_passes_independent"], b["feed_passes_fused"]]
    exact[f"serving.{tag}.sharing_ok"] = b["sharing_ok"]
    exact[f"serving.{tag}.steady_retraces"] = b["steady_retraces"]
    c = cache_report(g)
    exact[f"serving.{tag}.cache"] = c
    if sharded:
        print(f"[gate] {tag}: serving mixed sharded pool ...", flush=True)
        bm = batching_report(g, shards=8)
        exact[f"serving.{tag}.mesh8.counts_parity"] = \
            bool(bm["counts"] == b["counts"])
        exact[f"serving.{tag}.mesh8.workers"] = bm["workers"]
        exact[f"serving.{tag}.mesh8.sharing_ok"] = bm["sharing_ok"]
        exact[f"serving.{tag}.mesh8.steady_retraces"] = bm["steady_retraces"]
    print(f"[gate] {tag}: serving load ...", flush=True)
    ld = load_report(g, telemetry=trace_telemetry)
    exact[f"serving.{tag}.load_sharing_ok"] = ld["load_sharing_ok"]
    exact[f"serving.{tag}.load_retraces"] = ld["load_retraces"]
    ratios[f"serving.{tag}.qps_vs_sequential"] = ld["qps_vs_sequential"]
    ratios[f"serving.{tag}.p99_vs_sequential"] = ld["p99_vs_sequential"]
    print(f"[gate] serving: feed passes "
          f"{exact[f'serving.{tag}.feed_passes']}, "
          f"load retraces {ld['load_retraces']}, qps x"
          f"{ld['qps_vs_sequential']}, p99 x{ld['p99_vs_sequential']}",
          flush=True)
    return {"sequential": ld["sequential"], "service": ld["service"],
            "p50_vs_sequential": ld["p50_vs_sequential"]}


def measure_values(exact: dict, ratios: dict) -> None:
    """SVPU value-plane gate section (``--values``): every key but the
    overhead ratio is an exact fact.

    * weighted aggregates on the gate set AND the tiny-oracle graph —
      dyadic weights make sum/max/min exactly representable in f32, so
      the values baseline bit-for-bit and ``oracle_exact`` asserts
      engine == host-float64 permutation oracle;
    * dispatch/feed parity — the weighted query's per-pass
      ``level_kernel_dispatches`` and ``feed_chunks`` equal the
      unweighted twin's: value lanes ride existing membership dispatches
      and add ZERO feed passes;
    * retraces — a repeated weighted query builds 0 new executables;
    * ``weighted_overhead`` — warmed weighted/unweighted wall-clock
      ratio, tolerance-gated.
    """
    from repro.graph import get_dataset

    name, scale = VALUES_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: SVPU value plane ...", flush=True)
    sv = svpu_report(g)
    for app in ("T", "4C"):
        row = sv["queries"][app]
        exact[f"values.{tag}.{app}.aggregate"] = row["aggregate"]["result"]
        exact[f"values.{tag}.{app}.count"] = row["count"]["result"]
        exact[f"values.{tag}.{app}.dispatches"] = [
            row["count"]["dispatches"], row["aggregate"]["dispatches"]]
        exact[f"values.{tag}.{app}.feed_chunks"] = [
            row["count"]["feed_chunks"], row["aggregate"]["feed_chunks"]]
        exact[f"values.{tag}.{app}.dispatch_parity_ok"] = \
            bool(row["dispatch_parity_ok"] and row["feed_parity_ok"])
    exact[f"values.{tag}.retraces_second_pass"] = sv["retraces_second_pass"]
    exact[f"values.{tag}.value_lane_dispatches"] = \
        sv["value_lane_dispatches"]
    exact[f"values.{tag}.oracle_exact"] = \
        bool(sv["oracle_check"]["exact_match"])
    exact[f"values.{tag}.oracle_values"] = sv["oracle_check"]["values"]
    ratios[f"values.{tag}.weighted_overhead"] = sv["weighted_overhead"]
    print(f"[gate] values: oracle exact {sv['oracle_check']['exact_match']}"
          f", dispatch parity "
          f"{[sv['queries'][a]['dispatch_parity_ok'] for a in ('T', '4C')]}"
          f", overhead x{sv['weighted_overhead']}, retraces "
          f"{sv['retraces_second_pass']}", flush=True)


def measure(sharded: bool = False, telemetry: bool = False,
            serving: bool = False, serving_trace: str = "",
            values: bool = False) -> dict:
    from repro.graph import get_dataset
    from repro.mining import Miner
    from repro.mining.plan import FOUR_MOTIF_SHAPES
    exact: dict = {}
    ratios: dict = {}
    for name, scale in COUNT_SETS:
        g = get_dataset(name, scale=scale)
        tag = f"{name}@{scale}"
        print(f"[gate] {tag}: counting ...", flush=True)
        m = Miner(g)
        exact[f"{tag}.T"] = m.count("triangle")
        exact[f"{tag}.TC"] = m.count("three-chain")
        exact[f"{tag}.TT"] = m.count("tailed-triangle")
        exact[f"{tag}.4C"] = m.count("4-clique")
        exact[f"{tag}.4M"] = dict(zip(
            FOUR_MOTIF_SHAPES, m.count_many(list(FOUR_MOTIF_SHAPES))))

    # session-API smoke leg: one Miner serving the full app mix twice —
    # exact counts, the zero-retrace reuse contract and the auto-scheduled
    # forest counters are all schedule facts (machine-independent)
    name, scale = SESSION_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: session serving ...", flush=True)
    ss = session_serving_report(g)
    exact[f"{tag}.session.counts"] = ss["counts"]
    exact[f"{tag}.session.retraces_second_pass"] = ss["retraces_second_pass"]
    exact[f"{tag}.session.retraces_first_pass"] = ss["retraces_first_pass"]
    exact[f"{tag}.session.exec_cache_entries"] = ss["exec_cache"]["entries"]
    exact[f"{tag}.session.level2_execs_per_pass"] = \
        ss["level2_execs_per_pass"]
    exact[f"{tag}.session.level2_nodes_static"] = ss["level2_nodes_static"]
    exact[f"{tag}.session.feed_passes"] = ss["feed_passes"]

    name, scale = PERF_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: perf reports ...", flush=True)
    fl = fused_level_report(g)
    exact[f"{tag}.CY"] = fl["fused"]["count"]
    exact[f"{tag}.fused_level.k_general"] = fl["k_general"]
    exact[f"{tag}.fused_level.dispatches_per_general_level"] = {
        m: fl[m]["dispatches_per_general_level"]
        for m in ("per_ref", "fused")}
    ratios[f"{tag}.fused_level_speedup"] = fl["fused_level_speedup"]

    ff = forest_fusion_report(g)
    exact[f"{tag}.forest.level2_execs"] = [
        ff["level2_execs_independent"], ff["level2_execs_fused"]]
    exact[f"{tag}.forest.level2_ops_static"] = list(ff["level2_ops_static"])
    exact[f"{tag}.forest.feed_passes"] = list(ff["feed_passes"])
    ratios[f"{tag}.fusion_speedup"] = ff["fusion_speedup"]

    po = plan_overhead_report(g)
    ratios[f"{tag}.plan_overhead_4C"] = po["4C"]["plan_overhead"]
    ratios[f"{tag}.plan_overhead_TT"] = po["TT"]["plan_overhead"]

    wt = wave_throughput_report(g)
    ratios[f"{tag}.wave_speedup"] = wt["wave_speedup"]

    if sharded:
        measure_sharded(exact)
    if values:
        measure_values(exact, ratios)
    out = {
        "meta": {
            "python": platform.python_version(),
            "machine": platform.machine(),
            "measured_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        },
        "exact": exact,
        "ratios": ratios,
    }
    if telemetry:
        # spans carry wall-clock seconds: artifact-only, never baselined
        out["telemetry_spans"] = measure_telemetry(exact, sharded=sharded)
    if serving:
        from repro.obs import Telemetry
        trace_tel = Telemetry(enabled=bool(serving_trace))
        # absolute latencies are wall clock: artifact-only, never baselined
        out["serving_latency"] = measure_serving(
            exact, ratios, sharded=sharded, trace_telemetry=trace_tel)
        if serving_trace:
            path = trace_tel.write_trace(serving_trace)
            print(f"[gate] serving trace -> {path}", flush=True)
    return out


def _tolerance_for(metric: str, baseline: dict) -> tuple[float, str]:
    """(tolerance, direction) for a ratio key '<dataset>@<scale>.<name>';
    matched on the final dotted component (the scale contains a dot, so
    splitting on the FIRST dot would eat the metric name)."""
    stem = metric.rsplit(".", 1)[-1]
    tols = baseline.get("tolerances", DEFAULT_TOLERANCES)
    return (float(tols.get(stem, 0.6)),
            baseline.get("directions", DIRECTIONS).get(stem, "lower_better"))


def _section_of(key: str) -> str | None:
    """The optional-section prefix a metric key belongs to, if any."""
    return next((p for p in SECTION_PREFIXES if key.startswith(p)), None)


def _skip_key(key: str, ran: dict) -> bool:
    """True when a baseline key belongs to a section this invocation did
    not run (``sharded.*`` without --sharded, etc.). ``*.mesh*`` keys in
    the telemetry/serving sections additionally need --sharded."""
    sect = _section_of(key)
    if sect is None:
        return False
    if not ran[sect]:
        return True
    return ".mesh" in key and not ran["sharded."]


def compare(got: dict, baseline: dict) -> list[str]:
    """Return a list of regression messages (empty = gate passes).

    The ``sharded.*`` keys only exist when the gate ran with ``--sharded``
    (the multi-device CI leg), ``telemetry.*`` only with ``--telemetry``
    and ``serving.*`` only with ``--serving``. A run without those flags
    skips the matching baseline keys (exact AND ratios) instead of
    failing, so a partial invocation stays green against the full
    baseline."""
    failures = []
    base_exact = baseline.get("exact", {})
    ran = {p: any(k.startswith(p) for d in (got["exact"], got["ratios"])
                  for k in d)
           for p in SECTION_PREFIXES}
    for key, want in base_exact.items():
        if _skip_key(key, ran):
            continue
        have = got["exact"].get(key, "<missing>")
        if have != want:
            failures.append(f"EXACT {key}: baseline {want!r} != got {have!r}")
    for key in got["exact"]:
        if key not in base_exact:
            failures.append(f"EXACT {key}: missing from baseline "
                            "(run --update-baseline)")
    base_ratios = baseline.get("ratios", {})
    for key in got["ratios"]:
        if key not in base_ratios:
            failures.append(f"RATIO {key}: missing from baseline "
                            "(run --update-baseline)")
    for key, base_val in base_ratios.items():
        have = got["ratios"].get(key)
        if have is None:
            if _skip_key(key, ran):
                continue
            failures.append(f"RATIO {key}: not measured")
            continue
        tol, direction = _tolerance_for(key, baseline)
        if direction == "higher_better":
            bad = have < base_val * (1 - tol)
        else:
            bad = have > base_val * (1 + tol)
        if bad:
            failures.append(
                f"RATIO {key}: {have} vs baseline {base_val} "
                f"({direction}, tol {tol:.0%}) — REGRESSION")
    return failures


def _merge_kept(new: dict, old: dict, ran: dict) -> dict:
    """Baseline update for one of the exact/ratios dicts: keep every old
    key whose optional section this invocation did not run (including the
    ``*.mesh*`` keys when --sharded was absent) so a partial
    ``--update-baseline`` never silently drops another leg's baseline."""
    keep = {k: v for k, v in old.items() if _skip_key(k, ran)}
    return {**keep, **new}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_mining.json")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--sharded", action="store_true",
                    help="also run the mesh-sharded gate section (needs "
                         "8 devices; CI sets XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--telemetry", action="store_true",
                    help="also run the telemetry parity section: registry-"
                         "derived stats must equal the legacy counters "
                         "bit-for-bit, with tracing on and off")
    ap.add_argument("--serving", action="store_true",
                    help="also run the concurrent-service section: cross-"
                         "request feed-pass sharing, steady/load retraces "
                         "and cache counters (exact) + qps/p99 vs a "
                         "sequential session (ratios); writes the loaded "
                         "service's Perfetto trace next to --out")
    ap.add_argument("--values", action="store_true",
                    help="also run the SVPU value-plane section: weighted "
                         "sum/max/min aggregates vs the host-f64 oracle "
                         "(exact), dispatch/feed parity vs the unweighted "
                         "twin, zero repeat retraces + the weighted-"
                         "overhead wall-clock ratio")
    args = ap.parse_args(argv)

    serving_trace = ""
    if args.serving:
        serving_trace = str(Path(args.out).with_name(
            Path(args.out).stem + "_serving_trace.json"))
    got = measure(sharded=args.sharded, telemetry=args.telemetry,
                  serving=args.serving, serving_trace=serving_trace,
                  values=args.values)
    Path(args.out).write_text(json.dumps(got, indent=2, sort_keys=True))
    print(f"[gate] wrote {args.out}")

    if args.update_baseline:
        ran = {p: any(k.startswith(p)
                      for d in (got["exact"], got["ratios"]) for k in d)
               for p in SECTION_PREFIXES}
        if not all(ran.values()) or not args.sharded:
            # keep the sections recorded by a previous --sharded /
            # --telemetry / --serving update instead of dropping them
            try:
                old = json.loads(Path(args.baseline).read_text())
            except (FileNotFoundError, json.JSONDecodeError):
                old = {}
            got = {**got,
                   "exact": _merge_kept(got["exact"],
                                        old.get("exact", {}), ran),
                   "ratios": _merge_kept(got["ratios"],
                                         old.get("ratios", {}), ran)}
        doc = {
            "_doc": ("CI perf-regression baseline (benchmarks/ci_gate.py). "
                     "'exact' must match bit-for-bit; 'ratios' fail when "
                     "worse than baseline by more than 'tolerances' "
                     "(fractional) in the 'directions' sense. Refresh with "
                     "--update-baseline and justify in the PR."),
            "exact": got["exact"],
            "ratios": got["ratios"],
            "tolerances": DEFAULT_TOLERANCES,
            "directions": DIRECTIONS,
        }
        Path(args.baseline).write_text(
            json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"[gate] baseline refreshed -> {args.baseline}")
        return 0

    baseline = json.loads(Path(args.baseline).read_text())
    failures = compare(got, baseline)
    for f in failures:
        print(f"[gate] {f}", flush=True)
    if failures:
        print(f"[gate] FAIL: {len(failures)} regression(s)")
        return 1
    print("[gate] PASS: counts/counters exact, ratios within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
