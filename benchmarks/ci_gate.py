"""CI perf-regression gate over the mining benchmarks.

Runs a small-graph subset of ``bench_mining``'s reports, writes the result
to ``BENCH_mining.json`` (uploaded as a CI artifact) and compares it
against the checked-in ``benchmarks/baseline.json``:

* **exact metrics** — mining counts and structural counters (forest level-2
  dispatch/feed counts, fused-level membership dispatches per general
  level). The datasets are deterministic synthetic generators and the
  counters are schedule facts, so these are machine-independent and must
  match the baseline EXACTLY: any drift is a correctness or scheduling
  regression, not noise.
* **ratio metrics** — wall-clock ratios (plan interpreter overhead, forest
  fusion speedup, fused-level speedup, device-vs-host wave speedup).
  Ratios, not absolute times, so they transfer across machines, but CI
  runners are noisy: a metric only fails when it is worse than baseline by
  more than its tolerance (per-metric ``tolerances`` in baseline.json,
  direction from ``directions``: for ``higher_better`` a regression is
  ``got < base * (1 - tol)``, for ``lower_better`` it is
  ``got > base * (1 + tol)``).

Usage (CI runs exactly this):

    PYTHONPATH=src python benchmarks/ci_gate.py \
        --out BENCH_mining.json --baseline benchmarks/baseline.json

``--update-baseline`` rewrites baseline.json from the current measurement
(keeping tolerances/directions) — run locally when a PR legitimately moves
a ratio, and say so in the PR.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.bench_mining import (fused_level_report,   # noqa: E402
                                     forest_fusion_report,
                                     plan_overhead_report,
                                     session_serving_report,
                                     sharded_scaling_report,
                                     wave_throughput_report)

# exact app counts: small + cheap (deterministic synthetic graphs)
COUNT_SETS = [("citeseer", 1.0), ("email-eu-core", 0.25)]
# session-API smoke: one Miner serving the app mix twice on this set
SESSION_SET = ("email-eu-core", 0.25)
# mesh-sharded leg (--sharded, needs >= 8 devices: CI sets
# XLA_FLAGS=--xla_force_host_platform_device_count=8): counts parity,
# shard/psum counters, retraces and the dispatch-scaling bound
SHARDED_SET = ("email-eu-core", 0.25)
SHARDED_WIDTHS = (1, 8)
# wall-clock ratios + structural counters: dense enough that the timed
# region is hundreds of ms, not noise (see stability note in tolerances)
PERF_SET = ("email-eu-core", 1.0)

# ratio tolerances (fractional, see module docstring) — generous because CI
# wall clock is shared-runner noisy; the exact counters carry the precise
# regression signal, the ratios catch order-of-magnitude slumps.
DEFAULT_TOLERANCES = {
    "plan_overhead_4C": 0.6,
    "plan_overhead_TT": 0.8,
    "fusion_speedup": 0.5,
    "fused_level_speedup": 0.5,
    "wave_speedup": 0.6,
}
DIRECTIONS = {
    "plan_overhead_4C": "lower_better",
    "plan_overhead_TT": "lower_better",
    "fusion_speedup": "higher_better",
    "fused_level_speedup": "higher_better",
    "wave_speedup": "higher_better",
}


def measure_sharded(exact: dict) -> None:
    """Mesh-sharded gate section (CI's multi-device leg): every key is an
    exact schedule/count fact under 8 fake CPU devices.

    * counts parity — the sharded mix must equal the 1-device mix
      bit-for-bit (asserted inside ``sharded_scaling_report``; the counts
      land in the baseline once);
    * retraces — a repeated sharded pass builds 0 new executables;
    * dispatch/psum counters — per-shard dispatches and psum leaf
      reductions per pass are schedule facts, including the scaling bound
      ``dispatches_8 <= dispatches_1 / 8 + allowance``;
    * feed balance — the round-robin partitioner's per-shard feed items on
      FULL email-eu-core (host-only sweep, no mining) with the max/min
      ratio <= 2 acceptance bound.
    """
    import jax
    from repro.graph import get_dataset
    from repro.mining.engine import choose_chunk
    from repro.mining.shard import shard_edge_steps
    if jax.device_count() < max(SHARDED_WIDTHS):
        raise SystemExit(
            f"[gate] --sharded needs {max(SHARDED_WIDTHS)} devices, have "
            f"{jax.device_count()}: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={max(SHARDED_WIDTHS)}")

    name, scale = SHARDED_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: sharded scaling ...", flush=True)
    sr = sharded_scaling_report(g, SHARDED_WIDTHS)
    s_max = max(SHARDED_WIDTHS)
    many = sr["per_mesh"][str(s_max)]
    exact[f"sharded.{tag}.counts"] = many["counts"]
    exact[f"sharded.{tag}.retraces_second_pass"] = \
        many["retraces_second_pass"]
    exact[f"sharded.{tag}.dispatches_per_pass"] = {
        str(s): sr["per_mesh"][str(s)]["dispatches_per_pass"]
        for s in SHARDED_WIDTHS}
    exact[f"sharded.{tag}.psum_reductions_per_pass"] = \
        many["psum_reductions_per_pass"]
    exact[f"sharded.{tag}.shard_feed_items_{s_max}"] = \
        many["shard_feed_items"]
    exact[f"sharded.{tag}.dispatch_scaling_ok"] = \
        bool(many["dispatch_scaling_ok"])

    # full-graph partitioner balance: host-only feed sweep, no mining
    g_full = get_dataset(name, scale=1.0)
    chunk = min(choose_chunk(g_full.padded_max_degree), 1 << 15)
    items = [0] * s_max
    for _cap, _v0, _v1, n in shard_edge_steps(g_full, chunk, s_max):
        for s in range(s_max):
            items[s] += int(n[s])
    ratio = max(items) / max(min(items), 1)
    exact[f"sharded.{name}.feed_items_{s_max}"] = items
    exact[f"sharded.{name}.feed_balance_ratio_le_2"] = bool(ratio <= 2.0)
    print(f"[gate] sharded: feed ratio {ratio:.3f} on {name}, "
          f"dispatches {exact[f'sharded.{tag}.dispatches_per_pass']}, "
          f"{many['psum_reductions_per_pass']} psums/pass", flush=True)


def measure(sharded: bool = False) -> dict:
    from repro.graph import get_dataset
    from repro.mining import apps
    exact: dict = {}
    ratios: dict = {}
    for name, scale in COUNT_SETS:
        g = get_dataset(name, scale=scale)
        tag = f"{name}@{scale}"
        print(f"[gate] {tag}: counting ...", flush=True)
        exact[f"{tag}.T"] = apps.triangle_count(g)
        exact[f"{tag}.TC"] = apps.three_chain_count(g, induced=True)
        exact[f"{tag}.TT"] = apps.tailed_triangle_count(g)
        exact[f"{tag}.4C"] = apps.clique_count(g, 4)
        exact[f"{tag}.4M"] = apps.four_motif(g)

    # session-API smoke leg: one Miner serving the full app mix twice —
    # exact counts, the zero-retrace reuse contract and the auto-scheduled
    # forest counters are all schedule facts (machine-independent)
    name, scale = SESSION_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: session serving ...", flush=True)
    ss = session_serving_report(g)
    exact[f"{tag}.session.counts"] = ss["counts"]
    exact[f"{tag}.session.retraces_second_pass"] = ss["retraces_second_pass"]
    exact[f"{tag}.session.retraces_first_pass"] = ss["retraces_first_pass"]
    exact[f"{tag}.session.exec_cache_entries"] = ss["exec_cache"]["entries"]
    exact[f"{tag}.session.level2_execs_per_pass"] = \
        ss["level2_execs_per_pass"]
    exact[f"{tag}.session.level2_nodes_static"] = ss["level2_nodes_static"]
    exact[f"{tag}.session.feed_passes"] = ss["feed_passes"]

    name, scale = PERF_SET
    g = get_dataset(name, scale=scale)
    tag = f"{name}@{scale}"
    print(f"[gate] {tag}: perf reports ...", flush=True)
    fl = fused_level_report(g)
    exact[f"{tag}.CY"] = fl["fused"]["count"]
    exact[f"{tag}.fused_level.k_general"] = fl["k_general"]
    exact[f"{tag}.fused_level.dispatches_per_general_level"] = {
        m: fl[m]["dispatches_per_general_level"]
        for m in ("per_ref", "fused")}
    ratios[f"{tag}.fused_level_speedup"] = fl["fused_level_speedup"]

    ff = forest_fusion_report(g)
    exact[f"{tag}.forest.level2_execs"] = [
        ff["level2_execs_independent"], ff["level2_execs_fused"]]
    exact[f"{tag}.forest.level2_ops_static"] = list(ff["level2_ops_static"])
    exact[f"{tag}.forest.feed_passes"] = list(ff["feed_passes"])
    ratios[f"{tag}.fusion_speedup"] = ff["fusion_speedup"]

    po = plan_overhead_report(g)
    ratios[f"{tag}.plan_overhead_4C"] = po["4C"]["plan_overhead"]
    ratios[f"{tag}.plan_overhead_TT"] = po["TT"]["plan_overhead"]

    wt = wave_throughput_report(g)
    ratios[f"{tag}.wave_speedup"] = wt["wave_speedup"]

    if sharded:
        measure_sharded(exact)
    return {
        "meta": {
            "python": platform.python_version(),
            "machine": platform.machine(),
            "measured_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        },
        "exact": exact,
        "ratios": ratios,
    }


def _tolerance_for(metric: str, baseline: dict) -> tuple[float, str]:
    """(tolerance, direction) for a ratio key '<dataset>@<scale>.<name>';
    matched on the final dotted component (the scale contains a dot, so
    splitting on the FIRST dot would eat the metric name)."""
    stem = metric.rsplit(".", 1)[-1]
    tols = baseline.get("tolerances", DEFAULT_TOLERANCES)
    return (float(tols.get(stem, 0.6)),
            baseline.get("directions", DIRECTIONS).get(stem, "lower_better"))


def compare(got: dict, baseline: dict) -> list[str]:
    """Return a list of regression messages (empty = gate passes).

    The ``sharded.*`` exact keys only exist when the gate ran with
    ``--sharded`` (the multi-device CI leg). A run without it skips those
    baseline keys instead of failing, so the single-device bench job stays
    green against a baseline recorded under 8 fake devices."""
    failures = []
    base_exact = baseline.get("exact", {})
    ran_sharded = any(k.startswith("sharded.") for k in got["exact"])
    for key, want in base_exact.items():
        if key.startswith("sharded.") and not ran_sharded:
            continue
        have = got["exact"].get(key, "<missing>")
        if have != want:
            failures.append(f"EXACT {key}: baseline {want!r} != got {have!r}")
    for key in got["exact"]:
        if key not in base_exact:
            failures.append(f"EXACT {key}: missing from baseline "
                            "(run --update-baseline)")
    base_ratios = baseline.get("ratios", {})
    for key in got["ratios"]:
        if key not in base_ratios:
            failures.append(f"RATIO {key}: missing from baseline "
                            "(run --update-baseline)")
    for key, base_val in base_ratios.items():
        have = got["ratios"].get(key)
        if have is None:
            failures.append(f"RATIO {key}: not measured")
            continue
        tol, direction = _tolerance_for(key, baseline)
        if direction == "higher_better":
            bad = have < base_val * (1 - tol)
        else:
            bad = have > base_val * (1 + tol)
        if bad:
            failures.append(
                f"RATIO {key}: {have} vs baseline {base_val} "
                f"({direction}, tol {tol:.0%}) — REGRESSION")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_mining.json")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--sharded", action="store_true",
                    help="also run the mesh-sharded gate section (needs "
                         "8 devices; CI sets XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args(argv)

    got = measure(sharded=args.sharded)
    Path(args.out).write_text(json.dumps(got, indent=2, sort_keys=True))
    print(f"[gate] wrote {args.out}")

    if args.update_baseline:
        exact = got["exact"]
        if not any(k.startswith("sharded.") for k in exact):
            # keep the sharded section recorded by a previous --sharded
            # update rather than silently dropping it
            try:
                old = json.loads(Path(args.baseline).read_text())
            except (FileNotFoundError, json.JSONDecodeError):
                old = {}
            exact = {**{k: v for k, v in old.get("exact", {}).items()
                        if k.startswith("sharded.")}, **exact}
            got = {**got, "exact": exact}
        doc = {
            "_doc": ("CI perf-regression baseline (benchmarks/ci_gate.py). "
                     "'exact' must match bit-for-bit; 'ratios' fail when "
                     "worse than baseline by more than 'tolerances' "
                     "(fractional) in the 'directions' sense. Refresh with "
                     "--update-baseline and justify in the PR."),
            "exact": got["exact"],
            "ratios": got["ratios"],
            "tolerances": DEFAULT_TOLERANCES,
            "directions": DIRECTIONS,
        }
        Path(args.baseline).write_text(
            json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"[gate] baseline refreshed -> {args.baseline}")
        return 0

    baseline = json.loads(Path(args.baseline).read_text())
    failures = compare(got, baseline)
    for f in failures:
        print(f"[gate] {f}", flush=True)
    if failures:
        print(f"[gate] FAIL: {len(failures)} regression(s)")
        return 1
    print("[gate] PASS: counts/counters exact, ratios within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
