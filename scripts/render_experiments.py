"""Render the §Dry-run / §Roofline markdown tables from dry-run artifacts.

  python scripts/render_experiments.py [--dir experiments/dryrun] [--mesh single]
"""
import argparse
import glob
import json
import os


def load(d):
    rows = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def fmt(rows, mesh):
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "roofline% | useful% | peak GB/chip | fits 16GB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {a} | {s} | — | — | — | skip | — | — | — | n/a |")
            continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | ERR | | | | | | | |")
            continue
        roof = r["roofline"]
        mem = r["scan_measure"]["memory"]
        out.append(
            f"| {a} | {s} | {roof['compute_s']:.3f} | {roof['memory_s']:.3f} "
            f"| {roof['collective_s']:.3f} | {roof['dominant'][:-2]} "
            f"| {100*roof['roofline_fraction']:.1f}% "
            f"| {100*roof['useful_flops_ratio']:.1f}% "
            f"| {mem['peak_bytes']/1e9:.2f} | {r['fits_hbm']} |")
    return "\n".join(out)


def compare(base_dir, new_dir, cells):
    b, n = load(base_dir), load(new_dir)
    out = ["| cell | metric | baseline | optimized | Δ |", "|---|---|---|---|---|"]
    for key in cells:
        rb, rn = b.get(key), n.get(key)
        if not rb or not rn or rb.get("status") != "ok" or rn.get("status") != "ok":
            continue
        for metric, get in [
            ("dominant-term s", lambda r: max(r["roofline"]["compute_s"],
                                              r["roofline"]["memory_s"],
                                              r["roofline"]["collective_s"])),
            ("memory_s", lambda r: r["roofline"]["memory_s"]),
            ("collective_s", lambda r: r["roofline"]["collective_s"]),
            ("peak GB", lambda r: r["scan_measure"]["memory"]["peak_bytes"] / 1e9),
            ("roofline %", lambda r: 100 * r["roofline"]["roofline_fraction"]),
        ]:
            vb, vn = get(rb), get(rn)
            d = (vn - vb) / vb * 100 if vb else 0
            out.append(f"| {key[0]} {key[1]} {key[2]} | {metric} | {vb:.3f} "
                       f"| {vn:.3f} | {d:+.1f}% |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--baseline", default="experiments/dryrun_baseline")
    args = ap.parse_args()
    rows = load(args.dir)
    print("## single pod (16x16)\n")
    print(fmt(rows, "single"))
    print("\n## multi pod (2x16x16)\n")
    print(fmt(rows, "multi"))
