#!/usr/bin/env bash
# Documented local tier-1 flow — the same steps CI runs
# (.github/workflows/ci.yml), so local results match CI: deps first
# (requirements.txt bakes hypothesis in — it powers the random-plan/forest
# property tests), then the suite with TIER1_REQUIRE_DEPS=1, which makes
# tests/conftest.py FAIL collection if any dependency is missing — zero
# tests may skip for a missing dependency.
#
# A failed deps install aborts (CI must never green with the property
# tests silently skipped). Offline machines can opt out explicitly:
#   TIER1_ALLOW_OFFLINE=1 scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."
require_deps=1
if ! python -m pip install -q -r requirements.txt; then
    if [ "${TIER1_ALLOW_OFFLINE:-0}" = "1" ]; then
        echo "[tier1] WARNING: deps install failed (offline) —" \
             "hypothesis property tests will be SKIPPED (seeded twins run)"
        require_deps=0
    else
        echo "[tier1] ERROR: deps install failed; the property tests" \
             "would silently skip. Set TIER1_ALLOW_OFFLINE=1 to run anyway." >&2
        exit 1
    fi
fi
TIER1_REQUIRE_DEPS="$require_deps" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
