#!/usr/bin/env bash
# Documented local tier-1 flow — the same steps CI runs
# (.github/workflows/ci.yml), so local results match CI: dev deps first
# (hypothesis powers the random-plan/forest property tests; without it they
# skip and only the seeded twins run), then the suite.
#
# A failed dev-deps install aborts (CI must never green with the property
# tests silently skipped). Offline machines can opt out explicitly:
#   TIER1_ALLOW_OFFLINE=1 scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."
if ! python -m pip install -q -r requirements-dev.txt; then
    if [ "${TIER1_ALLOW_OFFLINE:-0}" = "1" ]; then
        echo "[tier1] WARNING: dev-deps install failed (offline) —" \
             "hypothesis property tests will be SKIPPED (seeded twins run)"
    else
        echo "[tier1] ERROR: dev-deps install failed; the property tests" \
             "would silently skip. Set TIER1_ALLOW_OFFLINE=1 to run anyway." >&2
        exit 1
    fi
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
