"""Mesh-sharded mining: ``ShardedWaveRunner`` parity, feed, cache contracts.

The sharding contract (``mining.shard`` docstring) promises:

  * **parity** — counts from an S-way mesh session are bit-identical to the
    single-device session (same integer summands, psum'd as 16-bit limbs),
    and embeddings enumerate the same multiset;
  * **feed** — ``shard_edge_steps`` enumerates exactly the single-device
    edge multiset, round-robin dealing bounds per-step imbalance at one
    item, and ``stats["shard_feed_items"]`` accounts for every edge;
  * **reuse** — sharded executables live under a mesh-prefixed cache key
    (never colliding with unsharded traces) and repeated sharded queries
    retrace nothing;
  * **degeneracy** — ``mesh=1`` (or ``mesh=None``) is the plain unsharded
    runner, and unsupported runner modes are rejected loudly.

The partitioner and ``_finalize`` tests are host-only and run everywhere;
mesh tests skip unless enough devices are visible (on CPU:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI mesh8 leg).
"""
import numpy as np
import pytest

import jax

from repro.graph import build_csr
from repro.graph.generators import clique_planted, erdos_renyi, \
    powerlaw_cluster
from repro.mining import plan as P
from repro.mining import reference
from repro.mining.engine import WaveRunner, half_edges
from repro.mining.session import ExecutableCache, Miner, mesh_signature
from repro.mining.shard import FEED_PARTITIONS, ShardedWaveRunner, \
    shard_edge_steps

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def wheel(n: int) -> np.ndarray:
    """Hub 0 joined to every rim vertex 1..n-1, rim a cycle: one extreme
    hub (degree n-1) plus n-1 triangles — the feed-skew stress shape."""
    hub = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], axis=1)
    rim = np.stack([np.arange(1, n), np.arange(2, n + 1)], axis=1)
    rim[-1, 1] = 1
    return np.concatenate([hub, rim], axis=0)


GRAPHS = {
    "er": build_csr(erdos_renyi(60, 240, seed=3), 60),
    "plc": build_csr(powerlaw_cluster(50, 4, seed=5), 50),
    "cliq": build_csr(clique_planted(45, 120, (6, 5), seed=1), 45),
    "wheel": build_csr(wheel(40), 40),
}
TINY = build_csr(erdos_renyi(6, 5, seed=2), 6)       # fewer edges than shards

MOTIF_NAMES = list(P.FOUR_MOTIF_SHAPES)


def mesh_for(shards: int):
    from repro.distributed.sharding import make_mining_mesh
    return make_mining_mesh(shards)


# ---------------------------------------------------------------------------
# feed partitioner (host-only: runs on any device count)
# ---------------------------------------------------------------------------


def feed_edges(g, shards, mode):
    """Flatten a sharded feed back to its (src, dst) edge list."""
    out = []
    for _cap, v0, v1, n in shard_edge_steps(g, 64, shards, mode=mode):
        nb = v0.shape[0] // shards
        for s in range(shards):
            k = int(n[s])
            out.append(np.stack([v0[s * nb: s * nb + k],
                                 v1[s * nb: s * nb + k]], axis=1))
    return np.concatenate(out, axis=0) if out else np.zeros((0, 2), np.int64)


@pytest.mark.parametrize("mode", FEED_PARTITIONS)
@pytest.mark.parametrize("name", ["er", "wheel"])
def test_feed_preserves_the_edge_multiset(name, mode):
    """Both dealing modes enumerate exactly the single-device half-edge
    multiset — only the edge -> shard assignment differs."""
    g = GRAPHS[name]
    want = half_edges(g)
    got = feed_edges(g, 8, mode)
    assert got.shape == want.shape
    order = np.lexsort((got[:, 1], got[:, 0]))
    worder = np.lexsort((want[:, 1], want[:, 0]))
    np.testing.assert_array_equal(got[order], np.asarray(want)[worder])


def test_round_robin_per_step_imbalance_is_at_most_one():
    for name in ("plc", "wheel"):
        for _cap, _v0, _v1, n in shard_edge_steps(GRAPHS[name], 64, 8):
            assert int(n.max()) - int(n.min()) <= 1


def test_contiguous_partial_steps_pin_low_shards():
    """The foil mode fills shards front to back, so a partial super-step
    leaves the high shards empty — per-shard counts are non-increasing."""
    for _cap, _v0, _v1, n in shard_edge_steps(GRAPHS["wheel"], 64, 8,
                                              mode="contiguous"):
        assert all(int(n[s]) >= int(n[s + 1]) for s in range(7))


def test_round_robin_beats_contiguous_on_a_hub_graph():
    """Total feed balance (max/min items per shard): dealing spreads the
    hub's edge run across the mesh, the contiguous split pins it."""
    g = build_csr(powerlaw_cluster(200, 8, seed=0), 200)

    def ratio(mode):
        items = np.zeros(8, np.int64)
        for _cap, _v0, _v1, n in shard_edge_steps(g, 512, 8, mode=mode):
            items += n
        return items.max() / max(items.min(), 1)

    rr, contig = ratio("round_robin"), ratio("contiguous")
    assert rr <= 1.5 < contig
    assert rr < contig


def test_feed_rejects_unknown_partition_mode():
    with pytest.raises(ValueError):
        list(shard_edge_steps(GRAPHS["er"], 64, 8, mode="hashed"))


def test_finalize_reassembles_limb_quads_exactly():
    """A psum'd 4-limb partial and the plain (hi, lo) pair for the same
    count reduce to the same integer — including hi words past 2^16."""
    r = WaveRunner(TINY)
    plan = P.compile_pattern(P.TRIANGLE)
    hi, lo = (1 << 17) + 9, (1 << 20) + 123
    pair = np.array([hi, lo], np.int64)
    quad = np.array([hi >> 16, hi & 0xFFFF, lo >> 16, lo & 0xFFFF], np.int64)
    assert r._finalize(plan, [pair]) == r._finalize(plan, [quad])
    assert r._finalize(plan, [quad]) == (hi << 16) + lo


# ---------------------------------------------------------------------------
# session degeneracy + cache keying
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh", [None, 1])
def test_mesh_one_is_the_plain_unsharded_runner(mesh):
    m = Miner(GRAPHS["er"], mesh=mesh)
    assert type(m.runner) is WaveRunner
    assert m.mesh is None
    assert m.count("triangle") == reference.triangle_count(GRAPHS["er"])


@needs2
def test_mesh_signature_isolates_sharded_executables():
    mesh = mesh_for(2)
    assert mesh_signature(mesh) != mesh_signature(None)
    assert ("mine", 2) in mesh_signature(mesh)
    assert ExecutableCache(mesh=mesh).prefix != ExecutableCache().prefix


@needs2
def test_sharded_runner_rejects_unsupported_modes():
    g, mesh = GRAPHS["er"], mesh_for(2)
    with pytest.raises(ValueError):
        ShardedWaveRunner(g, mesh, device_compact=False)
    with pytest.raises(ValueError):
        ShardedWaveRunner(g, mesh, record=True)
    with pytest.raises(ValueError):
        ShardedWaveRunner(g, mesh, axis="model")
    with pytest.raises(ValueError):
        ShardedWaveRunner(g, mesh, feed_partition="hashed")


@needs2
def test_two_way_mesh_triangle_parity():
    g = GRAPHS["plc"]
    assert Miner(g, mesh=2).count("triangle") == reference.triangle_count(g)


# ---------------------------------------------------------------------------
# 8-way parity (the CI mesh8 leg)
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("name", list(GRAPHS))
def test_sharded_counts_bit_identical(name):
    """The full app mix on an 8-way mesh: every count equals the
    single-device session bit for bit (and the reference oracles)."""
    g = GRAPHS[name]
    m1, m8 = Miner(g), Miner(g, mesh=8)
    for q in ("triangle", "tailed-triangle", "4-clique"):
        assert m8.count(q) == m1.count(q), q
    assert m8.count_many(MOTIF_NAMES) == m1.count_many(MOTIF_NAMES)
    assert m8.count("triangle") == reference.triangle_count(g)
    assert dict(zip(MOTIF_NAMES, m8.count_many(MOTIF_NAMES))) == \
        reference.four_motif_counts(g)


@needs8
def test_sharded_embeddings_enumerate_the_same_multiset():
    g = GRAPHS["plc"]
    t1 = Miner(g).embeddings("triangle")
    t8 = Miner(g, mesh=8).embeddings("triangle")
    assert t8.shape == t1.shape

    def key(t):
        return t[np.lexsort(t.T[::-1])]
    np.testing.assert_array_equal(key(t8), key(t1))


@needs8
def test_sharded_repeats_retrace_nothing():
    m = Miner(GRAPHS["er"], mesh=8)
    first = m.count("triangle")
    batch = m.count_many(MOTIF_NAMES)
    traced = m.stats["retraces"]
    assert traced > 0
    psums = m.stats["runner"]["psum_reductions"]
    assert psums > 0
    assert m.count("triangle") == first
    assert m.count_many(MOTIF_NAMES) == batch
    assert m.stats["retraces"] == traced
    assert m.stats["runner"]["psum_reductions"] > psums


@needs8
def test_sharded_feed_accounts_for_every_edge():
    g = GRAPHS["wheel"]
    m = Miner(g, mesh=8)
    m.count("triangle")                      # one symmetric feed pass
    items = m.stats["runner"]["shard_feed_items"]
    assert sum(items) == half_edges(g).shape[0]
    assert min(items) > 0                    # the hub's run was dealt out


@needs8
def test_sharded_handles_more_shards_than_edges():
    """TINY has fewer half-edges than shards: some shards mine nothing but
    carry bound-0 padding — counts still exact."""
    assert Miner(TINY, mesh=8).count("triangle") == \
        reference.triangle_count(TINY)
    assert Miner(TINY, mesh=8).count_many(MOTIF_NAMES) == \
        Miner(TINY).count_many(MOTIF_NAMES)


@needs8
def test_contiguous_feed_partition_is_exact_too():
    """The foil dealing mode changes only the edge -> shard assignment,
    never the counted multiset."""
    g = GRAPHS["wheel"]
    m = Miner(g, mesh=8, feed_partition="contiguous")
    assert m.count("triangle") == reference.triangle_count(g)
