"""Roofline machinery: HLO collective parsing, scan-counted-once property
(the basis of the dry-run calibration), report math."""
import jax
import jax.numpy as jnp

from repro.roofline.analysis import (collective_bytes, model_flops_6nd,
                                     roofline_report)

HLO_SAMPLE = """
  %ar = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %p0), replica_groups={}
  %ag.1 = bf16[4,256]{1,0} all-gather(bf16[4,128]{1,0} %x), dimensions={1}
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(f32[128]{0} %a, f32[128]{0} %b)
  %a2a = s32[16,16]{1,0} all-to-all(s32[16,16]{1,0} %y), dimensions={0}
  %cp = f32[32]{0} collective-permute(f32[32]{0} %z)
  %ars = f32[8,128]{1,0} all-reduce-start(f32[8,128]{1,0} %p1)
"""


def test_collective_parse():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-reduce"] == 8 * 128 * 4 * 2      # plain + -start
    assert got["all-gather"] == 4 * 256 * 2
    assert got["reduce-scatter"] == 2 * 64 * 4
    assert got["all-to-all"] == 16 * 16 * 4
    assert got["collective-permute"] == 32 * 4


def test_scan_body_counted_once():
    """The empirical fact the dry-run calibration relies on."""
    W = jnp.ones((128, 128), jnp.float32)

    def body(c, _):
        return c @ W, None

    def scan_n(n):
        def f(x):
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((4, 128), jnp.float32)).compile()
        ca = c.cost_analysis()
        d = ca[0] if isinstance(ca, (list, tuple)) else ca
        return float(d["flops"])

    assert scan_n(2) == scan_n(8)          # trip count invisible

    def unroll(x):
        for _ in range(8):
            x = x @ W
        return x

    c = jax.jit(unroll).lower(
        jax.ShapeDtypeStruct((4, 128), jnp.float32)).compile()
    ca = c.cost_analysis()
    d = ca[0] if isinstance(ca, (list, tuple)) else ca
    # unrolled ~= 8x the single-body count => calibration algebra is sound
    assert float(d["flops"]) > 7 * scan_n(8) / 2


def test_roofline_report_math():
    r = roofline_report(flops=197e12, bytes_hbm=819e9 / 2,
                        coll={"all-reduce": 50e9 / 4}, chips=256,
                        model_flops=197e12 * 256 / 2)
    assert abs(r["compute_s"] - 1.0) < 1e-6
    assert abs(r["memory_s"] - 0.5) < 1e-6
    assert abs(r["collective_s"] - 0.25) < 1e-6
    assert r["dominant"] == "compute_s"
    assert abs(r["roofline_fraction"] - 1.0) < 1e-6
    assert abs(r["useful_flops_ratio"] - 0.5) < 1e-6


def test_model_flops():
    assert model_flops_6nd(10, 5) == 300
    assert model_flops_6nd(10, 5, n_active=2) == 60
