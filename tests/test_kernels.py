"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps per kernel + property tests; the kernels must agree
bit-for-bit on integer outputs and to float32 tolerance on reductions.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.stream import SENTINEL
from repro.kernels import ops, ref
from repro.kernels.bitmap import keys_to_bitmap

RNG = np.random.default_rng(7)


def make_rows(batch, cap, hi=4000, rng=RNG, empty_prob=0.1):
    out = np.full((batch, cap), SENTINEL, np.int32)
    for i in range(batch):
        if rng.random() < empty_prob:
            continue
        n = int(rng.integers(1, cap))
        out[i, :n] = np.sort(rng.choice(hi, size=n, replace=False))
    return out


@pytest.mark.parametrize("cap_a,cap_b", [(128, 128), (128, 384), (256, 128),
                                         (384, 640)])
def test_intersect_count_sweep(cap_a, cap_b):
    a = jnp.asarray(make_rows(6, cap_a))
    b = jnp.asarray(make_rows(6, cap_b))
    bounds = jnp.asarray(RNG.choice([SENTINEL, 100, 2000, 3999], size=6)
                         .astype(np.int32))
    got = ops.xinter_count(a, b, bounds, backend="pallas")
    want = ref.intersect_count_ref(a, b, bounds)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cap_a,cap_b", [(128, 256), (256, 256)])
def test_intersect_rows_sweep(cap_a, cap_b):
    a = jnp.asarray(make_rows(5, cap_a))
    b = jnp.asarray(make_rows(5, cap_b))
    bounds = jnp.asarray(RNG.choice([SENTINEL, 1500], size=5).astype(np.int32))
    rows_p, n_p = ops.xinter(a, b, bounds, backend="pallas")
    rows_x, n_x = ops.xinter(a, b, bounds, backend="xla")
    np.testing.assert_array_equal(rows_p, rows_x)
    np.testing.assert_array_equal(n_p, n_x)


def test_intersect_identical_and_disjoint():
    a = jnp.asarray(make_rows(3, 128, empty_prob=0))
    same = ops.xinter_count(a, a, backend="pallas")
    lens = np.sum(np.asarray(a) != SENTINEL, axis=1)
    np.testing.assert_array_equal(np.asarray(same), lens)
    b = jnp.asarray(np.where(np.asarray(a) != SENTINEL,
                             np.asarray(a) + 100_000, SENTINEL).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.xinter_count(a, b, backend="pallas")), 0)


def test_intersect_empty_rows():
    a = jnp.full((2, 128), SENTINEL, jnp.int32)
    b = jnp.asarray(make_rows(2, 128))
    np.testing.assert_array_equal(
        np.asarray(ops.xinter_count(a, b, backend="pallas")), 0)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 2))
def test_bound_property(bound):
    a = jnp.asarray(make_rows(4, 128))
    b = jnp.asarray(make_rows(4, 128))
    bounds = jnp.full((4,), bound, jnp.int32)
    got = np.asarray(ops.xinter_count(a, b, bounds, backend="pallas"))
    want = np.asarray(ref.intersect_count_ref(a, b, bounds))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cap_a,cap_b", [(128, 128), (256, 128)])
def test_mark_pallas_matches_xla(cap_a, cap_b):
    a = jnp.asarray(make_rows(5, cap_a))
    b = jnp.asarray(make_rows(5, cap_b))
    got = ops.xmark(a, b, backend="pallas")
    want = ops.xmark(a, b, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bounded", [False, True])
def test_sub_count_pallas_matches_xla(bounded):
    a = jnp.asarray(make_rows(6, 256))
    b = jnp.asarray(make_rows(6, 128))
    bounds = jnp.asarray(RNG.choice([SENTINEL, 100, 2000], size=6)
                         .astype(np.int32)) if bounded else None
    got = ops.xsub_count(a, b, bounds, backend="pallas")
    want = ops.xsub_count(a, b, bounds, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sub_compact_pallas_matches_xla():
    a = jnp.asarray(make_rows(6, 256, hi=800))
    b = jnp.asarray(make_rows(6, 128, hi=800))
    bounds = jnp.asarray(RNG.integers(0, 800, 6).astype(np.int32))
    outs_p = ops.xsub_compact(a, b, bounds, out_cap=256, out_items=512,
                              backend="pallas")
    outs_x = ops.xsub_compact(a, b, bounds, out_cap=256, out_items=512,
                              backend="xla")
    for got, want in zip(outs_p, outs_x):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("op", ["mac", "max", "min"])
def test_vinter_sweep(op):
    a = jnp.asarray(make_rows(5, 256))
    b = jnp.asarray(make_rows(5, 128))
    va = jnp.asarray(RNG.normal(size=(5, 256)).astype(np.float32))
    vb = jnp.asarray(RNG.normal(size=(5, 128)).astype(np.float32))
    got = ops.xvinter_mac(a, va, b, vb, op=op, backend="pallas")
    want = ref.vinter_ref(a, va, b, vb, op=op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bitmap_vs_merge():
    a = jnp.asarray(make_rows(4, 256, hi=2000))
    b = jnp.asarray(make_rows(4, 256, hi=2000))
    wa, wb = keys_to_bitmap(a, 2000), keys_to_bitmap(b, 2000)
    got = ops.xbitmap_count(wa, wb, backend="pallas")
    want = ops.xinter_count(a, b, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cap_a,cap_b", [(128, 128), (256, 384)])
def test_lower_bound_pallas_matches_xla_and_bruteforce(cap_a, cap_b):
    """The lb operand (LevelOp.lb threaded into the tile schedule) must
    agree across backends and with a set-algebra oracle, for INTER and SUB
    counts and both fused compaction paths."""
    a = jnp.asarray(make_rows(6, cap_a))
    b = jnp.asarray(make_rows(6, cap_b))
    ub = jnp.asarray(RNG.choice([SENTINEL, 500, 2000, 3500], size=6)
                     .astype(np.int32))
    lb = jnp.asarray(RNG.choice([-1, 100, 1500, 3000], size=6)
                     .astype(np.int32))
    an, bn = np.asarray(a), np.asarray(b)
    for fn, setop in ((ops.xinter_count, lambda A, B: A & B),
                      (ops.xsub_count, lambda A, B: A - B)):
        got_p = np.asarray(fn(a, b, ub, backend="pallas", lbounds=lb))
        got_x = np.asarray(fn(a, b, ub, backend="xla", lbounds=lb))
        want = [len([k for k in setop(
            set(an[i][an[i] != SENTINEL].tolist()),
            set(bn[i][bn[i] != SENTINEL].tolist()))
            if int(lb[i]) < k < int(ub[i])]) for i in range(6)]
        np.testing.assert_array_equal(got_p, got_x)
        np.testing.assert_array_equal(got_p, want)
    for cfn, cap in ((ops.xinter_compact, min(cap_a, cap_b)),
                     (ops.xsub_compact, cap_a)):
        outs_p = cfn(a, b, ub, out_cap=cap, out_items=6 * cap,
                     backend="pallas", lbounds=lb)
        outs_x = cfn(a, b, ub, out_cap=cap, out_items=6 * cap,
                     backend="xla", lbounds=lb)
        for o_p, o_x in zip(outs_p, outs_x):
            np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_x))


def test_tile_schedule_skips_tiles_below_lower_bound():
    """A-tiles entirely <= lbound get zero visits (whole-tile skip), and the
    schedule still covers every in-window match."""
    from repro.kernels.intersect import TA, TB, tile_schedule
    a = jnp.asarray(make_rows(8, 512, empty_prob=0.0))
    b = jnp.asarray(make_rows(8, 1024, empty_prob=0.0))
    bounds = jnp.full((8,), SENTINEL, jnp.int32)
    lbounds = jnp.asarray(RNG.integers(0, 4000, 8).astype(np.int32))
    lo, nv = tile_schedule(a, b, bounds, lbounds)
    an, bn = np.asarray(a), np.asarray(b)
    lo, nv, lbn = np.asarray(lo), np.asarray(nv), np.asarray(lbounds)
    skipped = 0
    for i in range(8):
        for t in range(an.shape[1] // TA):
            tile = an[i, t * TA:(t + 1) * TA]
            if tile[TA - 1] <= lbn[i]:          # whole tile out of window
                assert nv[i, t] == 0
                skipped += 1
        common = np.intersect1d(an[i][an[i] != SENTINEL],
                                bn[i][bn[i] != SENTINEL])
        for k in common[common > lbn[i]]:
            ti = np.searchsorted(an[i], k) // TA
            tb = np.searchsorted(bn[i], k) // TB
            assert lo[i, ti] <= tb < lo[i, ti] + nv[i, ti], (i, k)
    assert skipped > 0          # the sweep actually exercised the skip


# ---------------------------------------------------------------------------
# fused multi-operand level kernel + prefix-scan compaction
# ---------------------------------------------------------------------------


def _level_bruteforce(a, bs, pol, ub, lb, excl):
    """Set-algebra oracle for the k-operand level keep/count semantics."""
    counts = []
    for i in range(a.shape[0]):
        banned = set(excl[i].tolist()) if excl is not None else set()
        n = 0
        for x in a[i]:
            if x == SENTINEL or not (lb[i] < x < ub[i]) or int(x) in banned:
                continue
            ok = True
            for r, p in enumerate(pol):
                row = set(bs[r, i][bs[r, i] != SENTINEL].tolist())
                ok &= (int(x) in row) if p else (int(x) not in row)
            n += ok
        counts.append(n)
    return counts


@pytest.mark.parametrize("pol", [(1,), (0,), (1, 0), (1, 1), (0, 0),
                                 (1, 1, 0)])
def test_xlevel_count_pallas_matches_xla_and_bruteforce(pol):
    a = jnp.asarray(make_rows(6, 256, hi=1200))
    bs = jnp.stack([jnp.asarray(make_rows(6, 128, hi=1200)) for _ in pol])
    ub = jnp.asarray(RNG.choice([SENTINEL, 300, 900, 0], size=6)
                     .astype(np.int32))       # 0 = bound-0 dead row
    lb = jnp.asarray(RNG.choice([-1, 100, 600], size=6).astype(np.int32))
    ex = jnp.asarray(RNG.integers(0, 1200, (6, 2)).astype(np.int32))
    got_p = np.asarray(ops.xlevel_count(a, bs, pol, ub, backend="pallas",
                                        lbounds=lb, excludes=ex))
    got_x = np.asarray(ops.xlevel_count(a, bs, pol, ub, backend="xla",
                                        lbounds=lb, excludes=ex))
    want = _level_bruteforce(np.asarray(a), np.asarray(bs), pol,
                             np.asarray(ub), np.asarray(lb), np.asarray(ex))
    np.testing.assert_array_equal(got_p, got_x)
    np.testing.assert_array_equal(got_p, want)


@pytest.mark.parametrize("pol", [(1, 0), (1, 1), (0, 0)])
def test_xlevel_compact_pallas_matches_xla(pol):
    a = jnp.asarray(make_rows(6, 256, hi=800))
    bs = jnp.stack([jnp.asarray(make_rows(6, 128, hi=800)) for _ in pol])
    ub = jnp.asarray(RNG.integers(0, 800, 6).astype(np.int32))
    lb = jnp.asarray(RNG.choice([-1, 200], size=6).astype(np.int32))
    outs_p = ops.xlevel_compact(a, bs, pol, ub, out_cap=256, out_items=2048,
                                backend="pallas", lbounds=lb)
    outs_x = ops.xlevel_compact(a, bs, pol, ub, out_cap=256, out_items=2048,
                                backend="xla", lbounds=lb)
    for got, want in zip(outs_p, outs_x):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xlevel_k1_degenerates_to_single_op_paths():
    """pol=(1,)/(0,) must reproduce the existing fused single-op entry
    points exactly — same counts, same compacted 6-tuple."""
    a = jnp.asarray(make_rows(6, 256, hi=700))
    b = jnp.asarray(make_rows(6, 128, hi=700))
    ub = jnp.asarray(RNG.integers(0, 700, 6).astype(np.int32))
    lb = jnp.asarray(RNG.choice([-1, 150], size=6).astype(np.int32))
    bs = b[None]
    for backend in ("pallas", "xla"):
        np.testing.assert_array_equal(
            np.asarray(ops.xlevel_count(a, bs, (1,), ub, backend=backend,
                                        lbounds=lb)),
            np.asarray(ops.xinter_count(a, b, ub, backend=backend,
                                        lbounds=lb)))
        np.testing.assert_array_equal(
            np.asarray(ops.xlevel_count(a, bs, (0,), ub, backend=backend,
                                        lbounds=lb)),
            np.asarray(ops.xsub_count(a, b, ub, backend=backend,
                                      lbounds=lb)))
        got = ops.xlevel_compact(a, bs, (1,), ub, out_cap=128,
                                 out_items=1024, backend=backend, lbounds=lb)
        want = ops.xinter_compact(a, b, ub, out_cap=128, out_items=1024,
                                  backend=backend, lbounds=lb)
        for o_g, o_w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(o_g), np.asarray(o_w))
        got = ops.xlevel_compact(a, bs, (0,), ub, out_cap=256,
                                 out_items=2048, backend=backend, lbounds=lb)
        want = ops.xsub_compact(a, b, ub, out_cap=256, out_items=2048,
                                backend=backend, lbounds=lb)
        for o_g, o_w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(o_g), np.asarray(o_w))


def test_xlevel_bound0_and_empty_worklists():
    """bound-0 rows (forest residual kills / padding items) and all-sentinel
    worklists must produce zero counts and no survivors on both backends."""
    a_live = jnp.asarray(make_rows(4, 128, empty_prob=0.0))
    a_dead = jnp.full((4, 128), SENTINEL, jnp.int32)
    bs = jnp.stack([a_live, jnp.asarray(make_rows(4, 128))])
    zero = jnp.zeros((4,), jnp.int32)
    for backend in ("pallas", "xla"):
        np.testing.assert_array_equal(
            np.asarray(ops.xlevel_count(a_live, bs, (1, 0), zero,
                                        backend=backend)), 0)
        np.testing.assert_array_equal(
            np.asarray(ops.xlevel_count(a_dead, bs, (1, 0), backend=backend)),
            0)
        rows, counts, src, verts, total, maxc = ops.xlevel_compact(
            a_dead, bs, (1, 0), out_cap=128, out_items=512, backend=backend)
        assert int(total) == 0 and int(maxc) == 0
        assert np.all(np.asarray(rows) == SENTINEL)
        assert np.all(np.asarray(verts) == 0) and np.all(np.asarray(src) == 0)


def test_xlevel_pol_empty_is_pure_window():
    """k=0 (no membership refs — star-like levels): window + excludes only,
    identical across backends (served by the XLA form on both)."""
    a = jnp.asarray(make_rows(5, 128, hi=500))
    ub = jnp.asarray(RNG.integers(0, 500, 5).astype(np.int32))
    ex = jnp.asarray(RNG.integers(0, 500, (5, 1)).astype(np.int32))
    got = np.asarray(ops.xlevel_count(a, None, (), ub, backend="pallas",
                                      excludes=ex))
    want = _level_bruteforce(np.asarray(a), None, (), np.asarray(ub),
                             np.full(5, -1), np.asarray(ex))
    np.testing.assert_array_equal(got, want)


def test_batch_compact_scan_matches_masked_sort_oracle():
    """The O(B·cap) prefix-scan scatter vs the masked-sort oracle: same
    survivor streams, same row-major item order, same scalars."""
    from repro.core.batch import batch_compact_items, batch_compact_scan
    rows = jnp.asarray(make_rows(16, 256, hi=2000))
    keep = jnp.asarray(RNG.random((16, 256)) < 0.35) & (rows != SENTINEL)
    r2, c2, src, verts, total, maxc = batch_compact_scan(rows, keep, 256,
                                                         16 * 256 + 128)
    want_rows = jnp.sort(jnp.where(keep, rows, SENTINEL), axis=1)
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(want_rows))
    np.testing.assert_array_equal(np.asarray(c2),
                                  np.asarray(jnp.sum(keep, axis=1)))
    src_o, verts_o, total_o, maxc_o = batch_compact_items(
        want_rows, c2, 16 * 256 + 128)
    np.testing.assert_array_equal(np.asarray(src), np.asarray(src_o))
    np.testing.assert_array_equal(np.asarray(verts), np.asarray(verts_o))
    assert int(total) == int(total_o) and int(maxc) == int(maxc_o)


def test_compact_rows_pallas_matches_scan():
    from repro.core.batch import batch_compact_rows
    from repro.kernels.compact import compact_rows_pallas
    rows = jnp.asarray(make_rows(8, 256, hi=1500))
    keep = jnp.asarray(RNG.random((8, 256)) < 0.4) & (rows != SENTINEL)
    for out_cap in (256, 128):
        capped = keep & (jnp.cumsum(keep, axis=1) <= out_cap)
        r_p, c_p = compact_rows_pallas(rows, capped, out_cap)
        r_x, c_x = batch_compact_rows(rows, capped, out_cap)
        np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_x))
        np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_x))


def test_compact_indices_scan_matches_index_sort():
    from repro.core.batch import compact_indices_scan
    ok = jnp.asarray(RNG.random(512) < 0.3)
    order, tot = compact_indices_scan(ok)
    idx = jnp.arange(512, dtype=jnp.int32)
    want = jnp.sort(jnp.where(ok, idx, SENTINEL))
    live = int(tot)
    np.testing.assert_array_equal(np.asarray(order)[:live],
                                  np.asarray(want)[:live])
    assert np.all(np.asarray(order)[live:] == 0)
    assert live == int(np.asarray(ok).sum())


def test_tile_schedule_visits_are_sound():
    """Every matching key pair must fall inside the scheduled tile range."""
    from repro.kernels.intersect import TA, TB, tile_schedule
    a = jnp.asarray(make_rows(8, 512))
    b = jnp.asarray(make_rows(8, 1024))
    bounds = jnp.full((8,), SENTINEL, jnp.int32)
    lo, nv = tile_schedule(a, b, bounds)
    an, bn = np.asarray(a), np.asarray(b)
    lo, nv = np.asarray(lo), np.asarray(nv)
    for i in range(8):
        common = np.intersect1d(an[i][an[i] != SENTINEL],
                                bn[i][bn[i] != SENTINEL])
        for k in common:
            ti = np.searchsorted(an[i], k) // TA        # a-tile of k
            tb = np.searchsorted(bn[i], k) // TB        # b-tile of k
            assert lo[i, ti] <= tb < lo[i, ti] + nv[i, ti], (i, k)
