"""SVPU value plane (paper §IV-E): weighted CSR, value-carrying kernels,
and aggregate queries vs the weighted permutation oracle.

Contracts under test:
  * **alignment** — edge values ride the exact permutation the keys take
    through ``build_csr`` (mirror / dedup / lexsort) and stay aligned in
    every padded row view and binary-search lookup;
  * **parity** — the pallas value kernel and the XLA fallback produce
    bit-identical (count, value) pairs on the dyadic weight corpus;
  * **exactness** — ``Miner.aggregate`` (sum / max / min) equals the host
    float64 ``reference.weighted_pattern_oracle`` EXACTLY on random
    weighted graphs, device and host compaction, tiny chunks;
  * **zero-overhead** — a weighted query costs the same feed passes and
    level-kernel dispatches as its unweighted twin, fuses into the same
    forest prefix, and repeats with 0 retraces.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.stream import SENTINEL
from repro.graph import build_csr, edge_weights, padded_rows, \
    padded_value_rows, with_edge_values
from repro.graph.csr import edge_list
from repro.graph.generators import erdos_renyi, powerlaw_cluster
from repro.kernels import ops
from repro.mining import plan as P
from repro.mining import reference
from repro.mining.engine import WaveRunner
from repro.mining.forest import build_forest
from repro.mining.session import Miner
from repro.values import edge_value_lookup, prefix_scale


def _weighted(edges, n=None, seed=0):
    g = build_csr(edges, n)
    return with_edge_values(g, edge_weights(edge_list(g), seed=seed))


TINY_EDGES = erdos_renyi(20, 70, seed=7)
TINY = _weighted(TINY_EDGES, 20, seed=11)
SMALL = _weighted(erdos_renyi(60, 240, seed=3), 60, seed=5)

AGG_PATTERNS = {
    "triangle": P.TRIANGLE,
    "three-chain-induced": P.THREE_CHAIN_INDUCED,
    "4-clique": P.clique_pattern(4),
}


def _weight_of(u, v, seed):
    return float(edge_weights(np.array([[u, v]]), seed=seed)[0])


# ---------------------------------------------------------------------------
# weighted CSR plumbing: alignment survives every permutation
# ---------------------------------------------------------------------------


def test_edge_weights_direction_and_duplicate_invariant():
    e = np.array([[3, 9], [9, 3], [0, 7], [7, 0]])
    w = edge_weights(e, seed=4)
    assert w[0] == w[1] and w[2] == w[3]
    assert set(np.unique(edge_weights(erdos_renyi(40, 150, seed=1), seed=2))
               ) <= {0.25, 0.5, 0.75, 1.0}


def test_build_csr_values_ride_the_key_permutation():
    """Shuffled, mirrored, duplicated input edges: every directed edge of
    the finished CSR still carries the weight of its own endpoint pair."""
    rng = np.random.default_rng(0)
    base = erdos_renyi(30, 90, seed=2)
    messy = np.concatenate([base, base[::-1, ::-1], base[:20]])
    messy = messy[rng.permutation(len(messy))]
    g = build_csr(messy, 30, edge_values=edge_weights(messy, seed=9))
    vals = np.asarray(g.edge_values)
    for i, (u, v) in enumerate(edge_list(g)):
        assert vals[i] == _weight_of(u, v, 9), (u, v)
    assert not np.any(vals[g.num_edges:])          # padding stays zero


def test_with_edge_values_roundtrip_and_validation():
    g = build_csr(TINY_EDGES, 20)
    assert not g.weighted
    e = edge_list(g)
    gw = with_edge_values(g, edge_weights(e, seed=11))
    assert gw.weighted and gw.num_edges == g.num_edges
    # key arrays are shared, values aligned with edge_list order
    assert gw.indices is g.indices
    vals = np.asarray(gw.edge_values)
    for i, (u, v) in enumerate(e):
        assert vals[i] == _weight_of(u, v, 11)
    with pytest.raises(ValueError):
        with_edge_values(g, np.ones(g.num_edges + 3, np.float32))


def test_padded_value_rows_align_with_padded_keys():
    vs = np.arange(TINY.num_vertices, dtype=np.int32)
    cap = int(TINY.padded_max_degree)
    keys, _ = padded_rows(TINY, vs, cap)
    vals = padded_value_rows(TINY, vs, cap)
    keys, vals = np.asarray(keys), np.asarray(vals)
    assert vals.shape == keys.shape
    for r, u in enumerate(vs):
        for c in range(cap):
            if keys[r, c] == SENTINEL:
                assert vals[r, c] == 0.0
            else:
                assert vals[r, c] == _weight_of(u, keys[r, c], 11)


def test_edge_value_lookup_matches_host_oracle():
    e = edge_list(TINY)
    w = {(int(u), int(v)): _weight_of(u, v, 11) for u, v in e}
    rng = np.random.default_rng(3)
    us = rng.integers(0, TINY.num_vertices, size=40).astype(np.int32)
    keys = rng.integers(0, TINY.num_vertices, size=(40, 6)).astype(np.int32)
    keys[rng.random(keys.shape) < 0.2] = SENTINEL   # padding slots miss
    got = np.asarray(edge_value_lookup(TINY, us, keys))
    for i in range(40):
        for j in range(6):
            assert got[i, j] == w.get((int(us[i]), int(keys[i, j])), 0.0)
    # 1-d form and prefix_scale compose the same lookups
    got1 = np.asarray(edge_value_lookup(TINY, us, keys[:, 0]))
    np.testing.assert_array_equal(got1, got[:, 0])
    sc = np.asarray(prefix_scale(TINY, {0: us, 1: keys[:, 0]}, ((0, 1),)))
    np.testing.assert_array_equal(sc, got[:, 0])


def test_edge_value_lookup_requires_weights():
    g = build_csr(TINY_EDGES, 20)
    with pytest.raises(ValueError):
        edge_value_lookup(g, np.zeros(4, np.int32), np.zeros(4, np.int32))


# ---------------------------------------------------------------------------
# value kernel: pallas vs XLA parity (dyadic corpus => bit-identical)
# ---------------------------------------------------------------------------


def _dyadic_streams(rng, b, cap, k):
    """(a, a_vals, bs, b_vals): sorted unique keys, SENTINEL-padded rows,
    dyadic values zeroed on padding slots."""
    def rows(n_rows, width):
        keys = np.full((n_rows, width), SENTINEL, np.int32)
        vals = np.zeros((n_rows, width), np.float32)
        for r in range(n_rows):
            m = int(rng.integers(0, min(width, 24) + 1))
            keys[r, :m] = np.sort(rng.choice(60, size=m, replace=False))
            vals[r, :m] = rng.choice([0.25, 0.5, 0.75, 1.0], size=m)
        return keys, vals
    a, av = rows(b, cap)
    bs, bv = zip(*(rows(b, cap) for _ in range(k)))
    return a, av, np.stack(bs), np.stack(bv)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["sum", "max", "min"]),
       st.integers(1, 3))
def test_xlevel_agg_pallas_xla_parity(seed, op, k):
    rng = np.random.default_rng(seed)
    a, av, bs, bv = _dyadic_streams(rng, b=12, cap=128, k=k)
    pol = (1,) * k
    scale = rng.choice([0.25, 0.5, 1.0], size=12).astype(np.float32)
    outs = {}
    for backend in ("pallas", "xla"):
        c, v = ops.xlevel_agg(a, bs, pol, av, bv, scale, op=op,
                              backend=backend)
        outs[backend] = (np.asarray(c), np.asarray(v))
    np.testing.assert_array_equal(outs["pallas"][0], outs["xla"][0])
    np.testing.assert_array_equal(outs["pallas"][1], outs["xla"][1])


def test_xlevel_agg_sub_refs_parity():
    rng = np.random.default_rng(77)
    a, av, bs, bv = _dyadic_streams(rng, b=10, cap=128, k=2)
    pol = (1, 0)                       # one INTER, one SUB ref
    scale = np.ones(10, np.float32)
    cp, vp = ops.xlevel_agg(a, bs, pol, av, bv, scale, backend="pallas")
    cx, vx = ops.xlevel_agg(a, bs, pol, av, bv, scale, backend="xla")
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cx))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vx))


# ---------------------------------------------------------------------------
# engine == weighted oracle, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("name", list(AGG_PATTERNS))
def test_aggregate_matches_weighted_oracle(name, op):
    pat = AGG_PATTERNS[name]
    want = reference.weighted_pattern_oracle(TINY, pat, op)
    assert Miner(TINY).aggregate(pat, op=op) == want, (name, op)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 10_000),
       st.sampled_from(["sum", "max", "min"]), st.booleans())
def test_aggregate_oracle_property(gseed, wseed, op, device_compact):
    g = _weighted(erdos_renyi(16, 44, seed=gseed), 16, seed=wseed)
    m = Miner(g, device_compact=device_compact, chunk=128)
    for pat in (P.TRIANGLE, P.THREE_CHAIN_INDUCED, P.clique_pattern(4)):
        assert m.aggregate(pat, op=op) == \
            reference.weighted_pattern_oracle(g, pat, op), (pat.name, op)


def test_aggregate_many_matches_singles():
    m = Miner(SMALL)
    names = list(AGG_PATTERNS)
    batch = m.aggregate_many(names, op="sum")
    assert batch == [m.aggregate(n, op="sum") for n in names]


# ---------------------------------------------------------------------------
# zero-overhead contracts: dispatches, fusion, retraces, guards
# ---------------------------------------------------------------------------


def test_value_lanes_add_no_dispatches_or_feed_passes():
    """A weighted query costs exactly the unweighted query's kernel
    dispatches and feed chunks — value lanes ride, they never add."""
    for query in ("triangle", "4-clique"):
        pat = AGG_PATTERNS[query]
        count_r = WaveRunner(SMALL)
        count_r.run(P.compile_pattern(pat))
        agg_r = WaveRunner(SMALL)
        agg_r.run(P.compile_pattern(pat, aggregate="sum"))
        assert agg_r.stats["level_kernel_dispatches"] == \
            count_r.stats["level_kernel_dispatches"], query
        assert agg_r.metrics.value("feed_chunks") == \
            count_r.metrics.value("feed_chunks"), query
        assert agg_r.metrics.value("value_lane_dispatches") > 0


def test_count_and_aggregate_share_forest_feed():
    """stream_key ignores the value disposition: a count leaf and an
    aggregate leaf over the same stream fuse into one feed pass, and the
    merged run still produces both exact results."""
    plans = [P.compile_pattern(P.TRIANGLE),
             P.compile_pattern(P.TRIANGLE, aggregate="sum"),
             P.compile_pattern(P.TRIANGLE, aggregate="max")]
    forest = build_forest(plans)
    assert forest.sharing_stats()["feed_passes"]["fused"] == 1
    got = WaveRunner(TINY).run_set(forest)
    assert got[0] == reference.pattern_count_oracle(TINY, P.TRIANGLE)
    assert got[1] == reference.weighted_pattern_oracle(TINY, P.TRIANGLE, "sum")
    assert got[2] == reference.weighted_pattern_oracle(TINY, P.TRIANGLE, "max")


def test_repeated_aggregate_zero_retraces():
    m = Miner(SMALL)
    first = m.aggregate("triangle", op="sum")
    traced = m.stats["retraces"]
    assert traced > 0
    assert m.aggregate("triangle", op="sum") == first
    assert m.stats["retraces"] == traced
    batch = m.aggregate_many(list(AGG_PATTERNS), op="max")
    traced = m.stats["retraces"]
    assert m.aggregate_many(list(AGG_PATTERNS), op="max") == batch
    assert m.stats["retraces"] == traced


def test_aggregate_guards():
    with pytest.raises(ValueError):                # weights required
        Miner(build_csr(TINY_EDGES, 20)).aggregate("triangle")
    with pytest.raises(ValueError):                # unknown op
        P.compile_pattern(P.TRIANGLE, aggregate="avg")
    with pytest.raises(ValueError):                # emit and aggregate clash
        P.compile_pattern(P.TRIANGLE, emit=True, aggregate="sum")
    sym = P.pattern("sym-tri", 3, ((0, 1), (0, 2), (1, 2)), div=6)
    with pytest.raises(ValueError):                # div != 1 rejected
        P.compile_pattern(sym, aggregate="sum")
    with pytest.raises(ValueError):                # oracle mirrors the guard
        reference.weighted_pattern_oracle(build_csr(TINY_EDGES, 20),
                                          P.TRIANGLE, "sum")


def test_powerlaw_weighted_smoke():
    g = _weighted(powerlaw_cluster(40, 4, seed=6), 40, seed=1)
    m = Miner(g)
    want = reference.weighted_pattern_oracle(g, P.TRIANGLE, "sum")
    assert m.aggregate("triangle", op="sum") == want
