"""Miner session API: compile → schedule → execute pipeline contracts.

Four contracts from the session redesign:
  * **reuse** — a second identical query on one session performs ZERO new
    traces (the lifted ``ExecutableCache``'s miss counter is the retrace
    counter), and executables survive the runner that built them;
  * **isolation** — two ``Miner``s on different graphs share nothing:
    counts stay correct and each session's caches are its own;
  * **auto-scheduling** — the matching-order search over the adjacency-only
    ``FOUR_MOTIF_SHAPES`` reproduces at least the hand-tuned sharing
    (level-2 shared nodes <= 3, feed passes <= 2) with counts bit-identical
    to the independent per-plan runs and the brute-force census, and no
    pattern definition carries a hand-written order or restriction;
  * **count-rides-expand** — a terminal count leaf whose stream and
    constraints match a sibling expand dispatches no kernel and still
    counts exactly (device and host compaction).
"""
import numpy as np
import pytest

from repro.graph import build_csr
from repro.graph.generators import clique_planted, erdos_renyi, \
    powerlaw_cluster
from repro.mining import apps, reference
from repro.mining import plan as P
from repro.mining.engine import WaveRunner
from repro.mining.session import ExecutableCache, Miner

GRAPHS = {
    "er": build_csr(erdos_renyi(60, 240, seed=3), 60),
    "plc": build_csr(powerlaw_cluster(50, 4, seed=5), 50),
    "cliq": build_csr(clique_planted(45, 120, (6, 5), seed=1), 45),
}
TINY = build_csr(erdos_renyi(18, 48, seed=7), 18)

MOTIF_NAMES = list(P.FOUR_MOTIF_SHAPES)


# ---------------------------------------------------------------------------
# session reuse: repeated queries never retrace
# ---------------------------------------------------------------------------


def test_repeated_count_zero_retraces():
    m = Miner(GRAPHS["er"])
    first = m.count("triangle")
    traced = m.stats["retraces"]
    assert traced > 0                       # the first query did compile
    assert m.count("triangle") == first
    st = m.stats
    assert st["retraces"] == traced         # second query: 0 new traces
    assert st["exec_cache"]["hits"] > 0
    assert st["plan_hits"] == 1


def test_repeated_batch_zero_retraces_and_bit_identical():
    m = Miner(GRAPHS["plc"])
    first = m.count_many(MOTIF_NAMES)
    traced = m.stats["retraces"]
    again = m.count_many(MOTIF_NAMES)
    st = m.stats
    assert again == first
    assert st["retraces"] == traced
    assert st["schedule_hits"] == 1 and st["schedule_misses"] == 1


def test_executables_outlive_the_runner():
    """The lifted cache is session state, not runner state: a second runner
    built on the same session cache starts fully warm."""
    g = GRAPHS["er"]
    cache = ExecutableCache()
    plan = P.compile_pattern(P.TRIANGLE)
    r1 = WaveRunner(g, exec_cache=cache)
    want = r1.run(plan)
    assert r1.stats["exec_misses"] == cache.misses > 0
    r2 = WaveRunner(g, exec_cache=cache)
    assert r2.run(plan) == want
    assert r2.stats["exec_misses"] == 0     # every executable reused
    assert r2.stats["exec_hits"] > 0


def test_query_forms_share_traces():
    """The same pattern asked by name and as an explicit ``Pattern`` lands
    on the same compiled plan and executables — 0 new traces (LevelOps and
    plans hash by value, not by query spelling)."""
    m = Miner(GRAPHS["er"])
    want = m.count("triangle")
    traced = m.stats["retraces"]
    assert m.count(P.TRIANGLE) == want
    assert m.stats["retraces"] == traced


# ---------------------------------------------------------------------------
# session isolation
# ---------------------------------------------------------------------------


def test_two_sessions_do_not_cross_contaminate():
    ga, gb = GRAPHS["er"], GRAPHS["cliq"]
    ma, mb = Miner(ga), Miner(gb)
    ta = ma.count("triangle")
    tb = mb.count("triangle")
    assert ta == reference.triangle_count(ga)
    assert tb == reference.triangle_count(gb)
    assert ta != tb                          # the graphs genuinely differ
    # caches are per-session: B compiled its own traces, A's were untouched
    assert ma.exec_cache is not mb.exec_cache
    assert mb.stats["retraces"] > 0
    # interleaved repeats stay warm per session
    ra, rb = ma.stats["retraces"], mb.stats["retraces"]
    assert ma.count("triangle") == ta and mb.count("triangle") == tb
    assert (ma.stats["retraces"], mb.stats["retraces"]) == (ra, rb)


def test_shared_session_pool_reuses_and_isolates():
    ga, gb = GRAPHS["er"], GRAPHS["plc"]
    ma = apps.shared_session(ga)
    assert apps.shared_session(ga) is ma
    assert apps.shared_session(gb) is not ma
    assert apps.shared_session(ga, chunk=128) is not ma   # config keyed


# ---------------------------------------------------------------------------
# automatic matching-order search
# ---------------------------------------------------------------------------


def test_shapes_carry_no_hand_ordering():
    """The 4-motif definitions are adjacency-only: no restrictions, no
    chosen matching order anywhere — ordering is derived."""
    for shape in P.FOUR_MOTIF_SHAPES.values():
        assert isinstance(shape, P.Motif)
        assert not hasattr(shape, "restrictions")
    for name, pat in P.FOUR_MOTIFS.items():
        # every scheduled pattern's restrictions are exactly the
        # automorphism-derived set for its chosen order — nothing bespoke
        assert pat.restrictions == P.auto_restrictions(pat.adj), name
        assert pat.div == 1


def test_auto_schedule_matches_hand_tuned_sharing():
    m = Miner(GRAPHS["er"])
    st = m.schedule(MOTIF_NAMES).sharing_stats()
    assert st["plan_ops"][("expand", 2)] == 6
    assert st["forest_ops"][("expand", 2)] <= 3    # hand-tuned bound
    assert st["feed_passes"]["fused"] <= 2


@pytest.mark.parametrize("name", list(GRAPHS))
def test_auto_scheduled_counts_bit_identical_and_exact(name):
    g = GRAPHS[name]
    m = Miner(g)
    fused = m.count_many(MOTIF_NAMES)
    indep = [m.count(P.FOUR_MOTIFS[n]) for n in MOTIF_NAMES]
    assert fused == indep
    assert dict(zip(MOTIF_NAMES, fused)) == reference.four_motif_counts(g)


def test_auto_schedule_device_host_agree():
    g = GRAPHS["cliq"]
    dev = Miner(g).count_many(MOTIF_NAMES)
    host = Miner(g, device_compact=False).count_many(MOTIF_NAMES)
    assert dev == host


@pytest.mark.parametrize("seed", range(6))
def test_auto_restrictions_count_exactly_once(seed):
    """Random connected motifs: the automorphism-derived restrictions must
    count every embedding exactly once (vs the permutation oracle)."""
    import itertools
    import random
    rng = random.Random(seed)
    k = rng.choice([3, 4])
    edges = {(0, 1)} | {(rng.randint(0, lvl - 1), lvl)
                        for lvl in range(2, k)}
    for i, j in itertools.combinations(range(k), 2):
        if (i, j) not in edges and rng.random() < 0.5:
            edges.add((i, j))
    shape = P.motif("rand", k, sorted(edges), induced=bool(seed % 2))
    m = Miner(TINY)
    got = m.count(shape)
    pat = m.compile(shape).pattern
    assert got == reference.pattern_count_oracle(TINY, pat), (shape, pat)


# ---------------------------------------------------------------------------
# count-rides-expand fusion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("device_compact", [True, False])
def test_clique_count_rides_sibling_expand(device_compact):
    """[4C, 5C]: the 4-clique's terminal count matches the 5-clique's
    level-3 expand exactly, so it reads that expand's counts vector —
    no ('count', 3) dispatch at all — and stays exact."""
    g = GRAPHS["cliq"]
    m = Miner(g, device_compact=device_compact)
    got = m.count_many([P.clique_pattern(4), P.clique_pattern(5)])
    assert got == [reference.clique_count(g, 4), reference.clique_count(g, 5)]
    assert ("count", 3) not in m.runner.level_execs
    assert m.runner.stats["count_rides"] > 0
    st = m.schedule([P.clique_pattern(4), P.clique_pattern(5)]) \
        .sharing_stats()
    assert st["count_rides"] == 1
    assert ("count", 3) not in st["forest_ops"]


def test_triangle_rides_wing_expand():
    """[T, 4C]: the triangle count leaf (ub = v1) equals the 4-clique's
    level-2 wing expand — one stream feeds both results."""
    g = GRAPHS["plc"]
    m = Miner(g)
    got = m.count_many([P.TRIANGLE, P.clique_pattern(4)])
    assert got == [reference.triangle_count(g),
                   reference.clique_count(g, 4)]
    assert ("count", 2) not in m.runner.level_execs
    assert m.runner.stats["count_rides"] > 0


def test_ride_does_not_fire_when_bounds_differ():
    """The 4-clique leaf must NOT ride the relaxed 4-motif wing expand
    (relaxation dropped the bound the leaf needs) — rides require exact
    constraint equality."""
    m = Miner(TINY)
    st = m.schedule(MOTIF_NAMES).sharing_stats()
    assert st["count_rides"] == 0
    assert st["forest_ops"][("count", 3)] == 6


def test_ride_tiny_chunks_agree():
    g = GRAPHS["cliq"]
    queries = [P.clique_pattern(4), P.clique_pattern(5)]
    assert Miner(g, chunk=128).count_many(queries) == \
        Miner(g).count_many(queries)


# ---------------------------------------------------------------------------
# embeddings + pipeline surface
# ---------------------------------------------------------------------------


def test_session_embeddings_match_host_oracle():
    g = GRAPHS["plc"]
    m = Miner(g)
    tris = m.embeddings("triangle")
    host = apps.triangle_list_host(g)
    assert tris.shape == host.shape == (reference.triangle_count(g), 3)

    def key(t):
        return t[np.lexsort(t.T[::-1])]
    np.testing.assert_array_equal(key(tris), key(host))
    before = m.stats["retraces"]
    m.embeddings("triangle")                 # warm repeat
    assert m.stats["retraces"] == before


def test_unknown_query_rejected():
    m = Miner(TINY)
    with pytest.raises(ValueError):
        m.count("no-such-pattern")


def test_compile_schedule_stages_cache():
    m = Miner(TINY)
    pl1 = m.compile("triangle")
    pl2 = m.compile("triangle")
    assert pl1 is pl2
    f1 = m.schedule(MOTIF_NAMES)
    f2 = m.schedule(MOTIF_NAMES)
    assert f1 is f2
    assert m.stats["schedule_misses"] == 1
