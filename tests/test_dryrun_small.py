"""Dry-run path on an 8-fake-device mesh in a subprocess (fast twin of the
512-device production dry-run; the full sweep artifacts live in
experiments/dryrun/)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.distributed.sharding import (DEFAULT_RULES, make_mesh_compat,
                                        mesh_context, shard_params_tree)
from repro.models.transformer import Model, shapes_and_axes
from repro.train.train_step import make_train_step, batch_shardings
from repro.train.optimizer import OptConfig, adamw_init, opt_state_shardings
from repro.roofline.analysis import collective_bytes

mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
spec = get_arch(sys.argv[1])
model = Model(spec.smoke_config)
shapes, axes = shapes_and_axes(model)
p_shard = shard_params_tree(shapes, axes, mesh, DEFAULT_RULES)
opt_cfg = OptConfig()
o_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), shapes)
o_shard = opt_state_shardings(shapes, axes, mesh, DEFAULT_RULES, opt_cfg)
batch = spec.input_specs("train_4k", smoke=True)
b_shard = batch_shardings(batch, mesh, DEFAULT_RULES)
fn = make_train_step(model, mesh, DEFAULT_RULES, opt_cfg)
from repro.distributed.sharding import named_sharding, Axes
rep = named_sharding(Axes(), mesh, DEFAULT_RULES)
jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard, rep),
                 out_shardings=(p_shard, o_shard,
                                {"loss": rep, "gnorm": rep, "lr": rep}))
low = jitted.lower(shapes, o_shapes, batch, jax.ShapeDtypeStruct((), jnp.int32))
comp = low.compile()
ma = comp.memory_analysis()
coll = collective_bytes(comp.as_text())
print(json.dumps({"ok": True,
                  "arg_bytes": int(ma.argument_size_in_bytes),
                  "collectives": coll}))
"""


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b", "rwkv6-3b",
                                  "seamless-m4t-medium"])
def test_multipod_lower_compile_smoke(arch):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"]
    # DP over pod+data must produce gradient all-reduces
    assert "all-reduce" in res["collectives"]
