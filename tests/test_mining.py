"""Mining applications vs brute-force oracles + cross-implementation
agreement (engine / InHouseAutoMine / exhaustive-check)."""
import numpy as np
import pytest

from repro.core import s_nestinter
from repro.graph import build_csr, neighbors_stream
from repro.graph.csr import degree_buckets, edge_list, padded_rows
from repro.graph.generators import clique_planted, erdos_renyi, powerlaw_cluster, rmat
from repro.mining import baseline, exhaustive, reference
from repro.mining.apps import fsm_pattern_feed, shared_session
from repro.core.stream import to_host

GRAPHS = {
    "er": build_csr(erdos_renyi(150, 700, seed=3), 150),
    "plc": build_csr(powerlaw_cluster(120, 4, seed=5), 120),
    "cliq": build_csr(clique_planted(90, 260, (6, 5, 5), seed=1), 90),
    "rmat": build_csr(rmat(7, 6, seed=2), 128),
}


@pytest.mark.parametrize("name", list(GRAPHS))
def test_triangles_all_paths_agree(name):
    g = GRAPHS[name]
    want = reference.triangle_count(g)
    assert shared_session(g).count("triangle") == want
    assert shared_session(g).count("triangle-nested") == want
    assert baseline.triangle_count(g) == want
    assert exhaustive.exhaustive_count(g, "triangle") == want


@pytest.mark.parametrize("name", ["er", "cliq"])
def test_chains(name):
    g = GRAPHS[name]
    # non-induced three-chain is the closed form Σ C(deg, 2)
    deg = np.asarray(g.degrees, dtype=np.int64)
    assert int((deg * (deg - 1) // 2).sum()) == reference.three_chain_count(g)
    want_i = reference.three_chain_count(g, induced=True)
    assert shared_session(g).count("three-chain") == want_i
    assert baseline.three_chain_count(g, induced=True) == want_i
    assert exhaustive.exhaustive_count(g, "3-chain") == want_i


@pytest.mark.parametrize("name", ["er", "plc"])
def test_tailed_triangle(name):
    g = GRAPHS[name]
    want = reference.tailed_triangle_count(g)
    assert shared_session(g).count("tailed-triangle") == want
    assert baseline.tailed_triangle_count(g) == want


def test_three_motif():
    g = GRAPHS["er"]
    t, chain = shared_session(g).count_many(["triangle", "three-chain"])
    assert {"triangle": t, "chain": chain} == reference.motif3(g)


@pytest.mark.parametrize("k", [3, 4, 5])
def test_cliques(k):
    from repro.mining.plan import clique_pattern
    g = GRAPHS["cliq"]
    want = reference.clique_count(g, k)
    assert shared_session(g).count(clique_pattern(k)) == want
    assert baseline.clique_count(g, k) == want
    if k in (4, 5):
        assert exhaustive.exhaustive_count(g, f"{k}-clique") == want


def test_triangle_list_matches_count():
    g = GRAPHS["er"]
    tris = fsm_pattern_feed(g)[0]
    assert tris.shape[0] == reference.triangle_count(g)
    # each row is a real triangle, strictly descending
    adj = {tuple(e) for e in edge_list(g)}
    for a, b, c in tris[:50]:
        assert a > b > c
        assert (a, b) in adj and (b, c) in adj and (a, c) in adj


def test_nestinter_instruction():
    """S_NESTINTER(N(v)) == Σ_u∈N(v) |N(v) ∩ N(u)| per the ISA definition."""
    g = GRAPHS["er"]
    for v in [0, 3, 17]:
        s = neighbors_stream(g, v)
        got = int(s_nestinter(g, s))
        nv = set(to_host(s).tolist())
        want = 0
        for u in sorted(nv):
            nu = set(to_host(neighbors_stream(g, u)).tolist())
            want += len(nv & nu)
        assert got == want


def test_degree_buckets_cover_all():
    g = GRAPHS["plc"]
    deg = np.asarray(g.degrees)
    covered = np.concatenate([v for _, v in degree_buckets(g)])
    assert sorted(covered.tolist()) == sorted(np.nonzero(deg > 0)[0].tolist())
    for cap, vs in degree_buckets(g):
        assert np.all(deg[vs] <= cap)


def test_padded_rows_sorted_and_masked():
    g = GRAPHS["er"]
    rows, lens = padded_rows(g, np.array([0, 5, 9]), 128)
    rows = np.asarray(rows)
    from repro.core.stream import SENTINEL
    for i, v in enumerate([0, 5, 9]):
        n = int(lens[i])
        assert n == int(g.degrees[v])
        assert np.all(rows[i, n:] == SENTINEL)
        assert np.all(np.diff(rows[i, :n]) > 0)
