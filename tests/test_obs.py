"""Observability (``repro.obs``): registry, span trees, exporters, and the
legacy-stats bit-identity contract.

The telemetry layer makes two promises the rest of the repo leans on:

  * **derived view, not a fork** — the engine's historical ``stats`` dicts
    are live views over the ``MetricsRegistry``; ``dict(runner.stats)``
    must reproduce the pre-registry dicts bit-for-bit (keys, order,
    values, write-through), golden-tested here against values recorded
    before the registry existed;
  * **observationally free** — enabling the tracer changes no counter and
    adds no kernel dispatches; disabling it records no spans at all.

Span-tree structure is pinned per app shape (single triangle query, fused
4-motif forest, mesh-8 sharded query) and the Chrome-trace export is
schema-checked: JSON round-trips, events are "X" phases, and children
nest inside their parent's interval.
"""
import json

import pytest

import jax

from repro.graph import build_csr
from repro.graph.generators import erdos_renyi, powerlaw_cluster
from repro.mining.engine import WaveRunner
from repro.mining.plan import FOUR_MOTIF_SHAPES
from repro.mining.session import Miner
from repro.obs import (LegacyStatsView, MetricsRegistry, Telemetry, Tracer,
                       chrome_trace)

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _er_graph():
    return build_csr(erdos_renyi(140, 900, seed=13), 140)


def _pl_graph():
    return build_csr(powerlaw_cluster(110, 5, seed=7), 110)


# --------------------------------------------------------------- registry

def test_registry_typed_and_labeled():
    reg = MetricsRegistry()
    c = reg.counter("dispatches")
    c.inc()
    c.inc(4)
    assert reg.value("dispatches") == 5
    # one name is one type: re-requesting as another kind raises
    with pytest.raises(TypeError):
        reg.gauge("dispatches")
    # labeled family: one instrument per label set, shared name
    for s in range(3):
        reg.counter("feed", shard=s).inc(s)
    fam = reg.series("feed")
    assert len(fam) == 3
    assert fam[(("shard", 2),)].value == 2
    snap = reg.snapshot()
    assert snap["dispatches"] == 5
    assert snap["feed"] == {"shard=0": 0, "shard=1": 1, "shard=2": 2}


def test_counter_underflow_raises():
    # the count-rides path subtracts host syncs it knows it never paid;
    # drifting below zero is a bookkeeping bug, not arithmetic to absorb
    reg = MetricsRegistry()
    c = reg.counter("host_syncs")
    c.inc(2)
    c.dec(2)
    assert c.value == 0
    with pytest.raises(ValueError, match="underflow"):
        c.dec()


def test_histogram_snapshot():
    reg = MetricsRegistry()
    h = reg.histogram("wave_items")
    for v in (1, 10, 100):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 3 and snap["sum"] == 111.0
    assert snap["min"] == 1.0 and snap["max"] == 100.0
    assert sum(h.buckets) == 3


def test_legacy_view_write_through_and_order():
    reg = MetricsRegistry()
    view = LegacyStatsView()
    for k in ("b_second", "a_first"):          # registration != sorted order
        view.expose_counter(k, reg)
    assert list(view) == ["b_second", "a_first"]
    view["a_first"] = 7                        # legacy `stats[k] = n` sites
    assert reg.value("a_first") == 7
    view.expose("derived", lambda: 42)         # read-only exposure
    assert view["derived"] == 42
    with pytest.raises(KeyError):
        view["derived"] = 0
    with pytest.raises(TypeError):
        del view["a_first"]


def test_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("items").inc(3)
    reg.counter("feed", shard=1).inc(2)
    reg.histogram("lat").observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE mining_items counter" in text
    assert "mining_items 3" in text
    assert 'mining_feed{shard="1"} 2' in text
    assert "mining_lat_count 1" in text and "mining_lat_sum 0.5" in text


# ---------------------------------------------------- golden bit-identity

def test_runner_stats_golden_bit_identity():
    """dict(runner.stats) must equal the dict the engine produced before
    the registry existed — values recorded from the pre-obs revision."""
    r = WaveRunner(_er_graph())
    assert r.clique(4) == 14
    assert r.count_edges() == 401
    assert dict(r.stats) == {
        "exec_hits": 0, "exec_misses": 4, "host_syncs": 3,
        "device_compactions": 1, "host_compactions": 0, "items": 401,
        "level_kernel_dispatches": 3, "count_rides": 0}
    # write-through: resetting a counter the legacy way hits the registry
    r.stats["exec_misses"] = 0
    assert r.stats["exec_misses"] == 0
    assert r.metrics.value("exec_misses") == 0


def test_session_stats_golden_bit_identity():
    m = Miner(_pl_graph())
    assert m.count("triangle") == 440
    assert list(m.count_many(list(FOUR_MOTIF_SHAPES))) == \
        [78, 1628, 2611, 15782, 68694, 35818]
    st = m.stats
    assert {k: st[k] for k in ("queries", "plan_hits", "plan_misses",
                               "schedule_hits", "schedule_misses")} == \
        {"queries": 2, "plan_hits": 0, "plan_misses": 1,
         "schedule_hits": 0, "schedule_misses": 1}
    assert st["retraces"] == 15
    assert st["exec_cache"] == {"hits": 3, "misses": 15, "entries": 15}
    assert st["runner"] == {
        "exec_hits": 3, "exec_misses": 15, "host_syncs": 13,
        "device_compactions": 3, "host_compactions": 0, "items": 19937,
        "level_kernel_dispatches": 10, "count_rides": 0}


@needs8
def test_sharded_stats_golden_bit_identity():
    m = Miner(_pl_graph(), mesh=8)
    assert m.count("triangle") == 440
    assert m.count("4-clique") == 78
    rs = dict(m.runner.stats)
    assert rs["psum_reductions"] == 2
    assert rs["shard_feed_items"] == [160, 160, 158, 158, 158, 158, 158, 158]
    # labeled series carries the same accounting per shard
    fam = m.telemetry.metrics.series("shard_feed_items")
    assert [fam[(("shard", s),)].value for s in range(8)] == \
        rs["shard_feed_items"]


# ------------------------------------------------------------- span trees

def test_span_tree_single_query():
    tel = Telemetry(enabled=True)
    m = Miner(_pl_graph(), telemetry=tel)
    m.count("triangle")
    roots = tel.tracer.finished
    assert [r.name for r in roots] == ["query"]
    q = roots[0]
    assert q.attrs == {"kind": "count", "query": "triangle"}
    assert [c.name for c in q.children] == ["compile", "execute"]
    ex = q.children[1]
    feeds = ex.find("feed")
    assert feeds and all(f.cat == "level" for f in feeds)
    dispatches = q.find("dispatch")
    assert dispatches
    for d in dispatches:
        assert {"kind", "level", "dispatches", "exec_cached"} <= \
            set(d.attrs)
    # spans nest by wall time: every child interval sits inside its parent
    for sp in q.walk():
        for c in sp.children:
            assert c.t0 >= sp.t0 and c.t1 <= sp.t1
    # per-level exclusive times sum back to the query wall time (no child
    # can be double-counted because self_seconds subtracts direct children)
    total = sum(tel.tracer.level_seconds().values())
    assert total == pytest.approx(q.seconds, rel=1e-6)


def test_span_tree_forest_batch():
    tel = Telemetry(enabled=True)
    m = Miner(_pl_graph(), telemetry=tel)
    m.count_many(list(FOUR_MOTIF_SHAPES))
    q = tel.tracer.last("query")
    assert q.attrs["kind"] == "count_many"
    assert q.attrs["queries"] == len(FOUR_MOTIF_SHAPES)
    names = [c.name for c in q.children]
    assert names[0] == "schedule" and names[-1] == "execute"
    ex = q.children[-1]
    assert ex.attrs.get("forest") is True
    levels = [s for s in ex.walk() if s.cat == "level" and s.name != "feed"]
    assert levels, "forest execute must contain per-level spans"
    assert all(s.name.startswith("L") for s in levels)


@needs8
def test_span_tree_sharded():
    tel = Telemetry(enabled=True)
    m = Miner(_pl_graph(), mesh=8, telemetry=tel)
    assert m.count("triangle") == 440
    q = tel.tracer.last("query")
    dispatches = q.find("dispatch")
    assert dispatches
    # tracing must not change the sharded accounting either
    plain = Miner(_pl_graph(), mesh=8)
    plain.count("triangle")
    assert dict(m.runner.stats) == dict(plain.runner.stats)


# ----------------------------------------------------- disabled telemetry

def test_disabled_telemetry_is_free():
    """Tracing off (the default) records nothing; tracing on changes no
    counter — in particular zero extra kernel dispatches."""
    plain = Miner(_pl_graph())
    plain.count("triangle")
    plain.count_many(list(FOUR_MOTIF_SHAPES))
    assert plain.telemetry.tracer.finished == []

    tel = Telemetry(enabled=True)
    traced = Miner(_pl_graph(), telemetry=tel)
    traced.count("triangle")
    traced.count_many(list(FOUR_MOTIF_SHAPES))
    assert dict(traced.runner.stats) == dict(plain.runner.stats)
    assert traced.stats == plain.stats


# -------------------------------------------------------------- exporters

def test_chrome_trace_schema(tmp_path):
    tel = Telemetry(enabled=True)
    m = Miner(_pl_graph(), telemetry=tel)
    m.count("triangle")
    path = tel.write_trace(tmp_path / "trace.json")
    doc = json.loads(path.read_text())          # JSON round-trips
    events = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["spans"] == len(events)
    assert doc["otherData"]["metrics"]["level_kernel_dispatches"] > 0
    assert all(e["ph"] == "X" for e in events)
    assert all(e["dur"] >= 0 for e in events)
    # args must be JSON-scalar (Chrome trace viewers choke on objects)
    for e in events:
        for v in e["args"].values():
            assert isinstance(v, (int, float, str, bool, type(None)))
    # the root event spans every other event on its track
    by_tid = {}
    for e in events:
        by_tid.setdefault(e["tid"], []).append(e)
    for track in by_tid.values():
        root = track[0]
        for e in track[1:]:
            assert e["ts"] >= root["ts"] - 1e-3
            assert e["ts"] + e["dur"] <= root["ts"] + root["dur"] + 1e-3


def test_telemetry_snapshot_and_nullspan():
    tel = Telemetry(enabled=True)
    with tel.tracer.span("outer") as sp:
        with tel.tracer.span("inner"):
            pass
    assert sp.t1 is not None
    snap = tel.snapshot()
    assert snap["spans"]["outer"]["count"] == 1
    assert snap["roots"][0]["spans"] == 2
    # disabled tracer: span() yields None and records nothing
    off = Tracer(enabled=False)
    with off.span("x") as sp:
        assert sp is None
    assert off.finished == []
    assert chrome_trace(off)["traceEvents"] == []
