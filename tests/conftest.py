import os
import sys

# tests see the 1 real device — the 512-device override lives ONLY in
# launch/dryrun.py (spawned as a subprocess where needed).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
