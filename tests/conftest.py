import os
import sys

# tests see the 1 real device — the 512-device override lives ONLY in
# launch/dryrun.py (spawned as a subprocess where needed).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_report_header(config):
    """Make a missing ``hypothesis`` loud instead of silently skipping the
    random-plan/forest property tests (the documented tier-1 flow —
    scripts/tier1.sh — installs requirements-dev.txt first, matching CI)."""
    try:
        import hypothesis
        return f"hypothesis {hypothesis.__version__}: property tests active"
    except ImportError:
        return ("WARNING: hypothesis NOT installed -> property tests SKIP "
                "(seeded twins still run). Documented flow: "
                "`pip install -r requirements-dev.txt` or scripts/tier1.sh "
                "— CI always runs with hypothesis.")
