import os
import sys

import pytest

# tests see the 1 real device — the 512-device override lives ONLY in
# launch/dryrun.py (spawned as a subprocess where needed).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_collection(session):
    """TIER1_REQUIRE_DEPS=1 (set by scripts/tier1.sh == CI) asserts that
    zero tests will skip for a missing dependency: a missing ``hypothesis``
    fails the run outright instead of silently downgrading the property
    tests to their seeded twins."""
    if os.environ.get("TIER1_REQUIRE_DEPS") == "1":
        try:
            import hypothesis  # noqa: F401
        except ImportError:
            raise pytest.UsageError(
                "TIER1_REQUIRE_DEPS=1 but hypothesis is not installed — "
                "the property tests would skip. Install requirements.txt "
                "(scripts/tier1.sh does) or unset TIER1_REQUIRE_DEPS.")


def pytest_report_header(config):
    """Make a missing ``hypothesis`` loud instead of silently skipping the
    random-plan/forest property tests (the documented tier-1 flow —
    scripts/tier1.sh — installs requirements.txt first, matching CI)."""
    try:
        import hypothesis
        return f"hypothesis {hypothesis.__version__}: property tests active"
    except ImportError:
        return ("WARNING: hypothesis NOT installed -> property tests SKIP "
                "(seeded twins still run). Documented flow: "
                "`pip install -r requirements.txt` or scripts/tier1.sh "
                "— CI always runs with hypothesis.")
