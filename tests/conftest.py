import os
import sys

import pytest

# tests see the 1 real device — the 512-device override lives ONLY in
# launch/dryrun.py (spawned as a subprocess where needed).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_collection(session):
    """TIER1_REQUIRE_DEPS=1 (set by scripts/tier1.sh == CI) asserts that
    no test runs on a degraded dependency: a missing ``hypothesis`` fails
    the run outright instead of silently downgrading the property tests
    to the seeded mini-runner (tests/_hypothesis_compat.py)."""
    if os.environ.get("TIER1_REQUIRE_DEPS") == "1":
        try:
            import hypothesis  # noqa: F401
        except ImportError:
            raise pytest.UsageError(
                "TIER1_REQUIRE_DEPS=1 but hypothesis is not installed — "
                "the property tests would run on the seeded fallback "
                "runner only. Install requirements.txt (scripts/tier1.sh "
                "does) or unset TIER1_REQUIRE_DEPS.")


def pytest_report_header(config):
    """Make a missing ``hypothesis`` loud: the property tests still RUN
    (seeded mini-runner in tests/_hypothesis_compat.py — deterministic
    draws, no shrinking), but CI always uses the real hypothesis (the
    documented tier-1 flow — scripts/tier1.sh — installs
    requirements.txt first)."""
    try:
        import hypothesis
        return f"hypothesis {hypothesis.__version__}: property tests active"
    except ImportError:
        return ("WARNING: hypothesis NOT installed -> property tests run "
                "on the seeded mini-runner (deterministic, no shrinking). "
                "Documented flow: `pip install -r requirements.txt` or "
                "scripts/tier1.sh — CI always runs with hypothesis.")
