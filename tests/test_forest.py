"""PlanForest scheduler: trie structure, relaxation/residual correctness,
and the bit-identity contract (fused == independent per-plan execution).

Layers:
  * builder structure — the 4-motif batch must collapse to the documented
    trie (level-2: 6 plan ops -> 3 shared nodes; feed passes 6 -> 2), with
    relaxed constraints reappearing as residuals on the right branches;
  * count identity — ``run_set`` output equals per-plan ``run`` output,
    equals the independent brute-force oracles (census + ESU), on device
    and host compaction, and under tiny chunks (multi-chunk fan-out);
  * emit plans through the forest (FSM's triangle feed) and mixed
    emit+count batches;
  * a hypothesis property over random pattern *sets* (plus its seeded
    hypothesis-free twin): any batch of random valid patterns fused into a
    forest counts exactly what the plans count independently.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.graph import build_csr
from repro.graph.generators import clique_planted, erdos_renyi, powerlaw_cluster
from repro.mining import exhaustive, reference
from repro.mining.engine import WaveRunner
from repro.mining.forest import build_forest
from repro.mining import plan as P

from test_plan import _draw_pattern, _seeded_pattern

GRAPHS = {
    "er": build_csr(erdos_renyi(60, 240, seed=3), 60),
    "plc": build_csr(powerlaw_cluster(50, 4, seed=5), 50),
    "cliq": build_csr(clique_planted(45, 120, (6, 5), seed=1), 45),
}
TINY = build_csr(erdos_renyi(18, 48, seed=7), 18)

FOUR_MOTIF_PLANS = [P.compile_pattern(p) for p in P.FOUR_MOTIFS.values()]


# ---------------------------------------------------------------------------
# builder structure
# ---------------------------------------------------------------------------


def _nodes_at(forest, level, kind):
    out = []

    def walk(n):
        if n.op.level == level and n.op.kind == kind:
            out.append(n)
        for ch in n.children:
            walk(ch)
    for r in forest.all_roots():
        walk(r)
    return out


def test_four_motif_forest_shares_level2():
    forest = build_forest(FOUR_MOTIF_PLANS)
    st_ = forest.sharing_stats()
    assert st_["plan_ops"][("expand", 2)] == 6
    assert st_["forest_ops"][("expand", 2)] == 3
    assert st_["forest_ops"][("count", 3)] == 6
    assert st_["feed_passes"] == {"independent": 6, "fused": 2}
    # five plans ride the half-edge feed, the 4-star alone is directed
    assert len(forest.symmetric_roots) == 2
    assert len(forest.directed_roots) == 1


def test_relaxed_node_pushes_surplus_to_residuals():
    forest = build_forest(FOUR_MOTIF_PLANS)
    wings = [n for n in _nodes_at(forest, 2, "expand")
             if n.op.inter == (1,) and not n.op.sub]
    assert len(wings) == 1                      # clique+diamond+paw share it
    node = wings[0]
    assert node.op.ub == () and node.op.residual == ()   # fully relaxed
    assert len(node.children) == 3
    # the 4-clique branch deferred its v2 < v1 bound: residual on its leaf,
    # re-added to the carried element bound (the leaf consumes the carry)
    clique_leaf = [ch for ch in node.children if ch.op.residual]
    assert len(clique_leaf) == 1
    op = clique_leaf[0].op
    assert op.use_carry and ("lt", 2, 1) in op.residual and 1 in op.ub


def test_forest_liveness_is_union_of_branches():
    forest = build_forest(FOUR_MOTIF_PLANS)
    wings = [n for n in _nodes_at(forest, 2, "expand")
             if n.op.inter == (1,) and not n.op.sub][0]
    # paw's level-3 gathers rows of columns 0 and 1; clique/diamond carry:
    # the shared node must forward the union and produce the carry
    assert set(wings.op.gather_refs) >= {0, 1, 2}
    assert wings.op.carry_out
    assert set(wings.op.out_cols) == {0, 1, 2}


def test_duplicate_plans_share_one_leaf():
    g = TINY
    forest = build_forest([P.compile_pattern(P.TRIANGLE)] * 2)
    runner = WaveRunner(g)
    got = runner.run_set(forest)
    assert got[0] == got[1] == reference.triangle_count(g)
    assert runner.level_execs == {("count", 2): 1}    # counted once


def test_canonical_plan_key_distinguishes_and_matches():
    t1 = P.compile_pattern(P.TRIANGLE)
    t2 = P.compile_pattern(P.TRIANGLE)
    assert t1.canonical_key() == t2.canonical_key()
    assert t1.canonical_key() != P.compile_pattern(P.TRIANGLE_NESTED).canonical_key()


# ---------------------------------------------------------------------------
# count identity: fused == independent == oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(GRAPHS))
def test_fused_four_motif_matches_independent_and_census(name):
    g = GRAPHS[name]
    fused = WaveRunner(g).run_set(build_forest(FOUR_MOTIF_PLANS))
    indep = [WaveRunner(g).run(pl) for pl in FOUR_MOTIF_PLANS]
    assert fused == indep
    assert dict(zip(P.FOUR_MOTIFS, fused)) == reference.four_motif_counts(g)


def test_fused_level2_dispatches_halve():
    g = GRAPHS["plc"]
    rf = WaveRunner(g)
    rf.run_set(build_forest(FOUR_MOTIF_PLANS))
    ri = WaveRunner(g)
    for pl in FOUR_MOTIF_PLANS:
        ri.run(pl)
    fused2 = rf.level_execs[("expand", 2)]
    indep2 = ri.level_execs[("expand", 2)]
    assert fused2 * 2 == indep2                  # 6 -> 3 per chunk sweep
    # terminal work is NOT duplicated by the fan-out
    assert rf.level_execs[("count", 3)] == ri.level_execs[("count", 3)]


def test_fused_four_motif_matches_exhaustive_esu():
    g = GRAPHS["plc"]
    got = dict(zip(P.FOUR_MOTIFS,
                   WaveRunner(g).run_set(build_forest(FOUR_MOTIF_PLANS))))
    for pat in ("diamond", "4-cycle", "4-path", "4-star"):
        assert got[pat] == exhaustive.exhaustive_count(g, pat)
    assert got["paw"] == exhaustive.exhaustive_count(g, "tailed-triangle")


@pytest.mark.parametrize("name", ["er", "cliq"])
def test_forest_device_host_compaction_agree(name):
    g = GRAPHS[name]
    forest = build_forest(FOUR_MOTIF_PLANS)
    dev = WaveRunner(g).run_set(forest)
    host = WaveRunner(g, device_compact=False).run_set(forest)
    assert dev == host


def test_run_set_records_waves():
    """record=True must trace forest runs like single-plan runs: the level-1
    feed plus every fan-out chunk at each interior node's output level."""
    g = TINY
    runner = WaveRunner(g, record=True)
    runner.run_set(build_forest(FOUR_MOTIF_PLANS))
    levels = {lv for lv, _, _ in runner.trace}
    assert 1 in levels and 3 in levels
    assert sum(n.shape[0] for lv, n, _ in runner.trace if lv == 1) > 0


def test_forest_tiny_chunks_agree():
    """Tiny chunks force multi-chunk fan-out at every shared node."""
    g = TINY
    forest = build_forest(FOUR_MOTIF_PLANS)
    assert WaveRunner(g, chunk=128).run_set(forest) == \
        WaveRunner(g).run_set(forest)


def test_session_batches_route_through_forest():
    from repro.mining.apps import shared_session
    g = GRAPHS["er"]
    m = shared_session(g)
    motifs = list(P.FOUR_MOTIF_SHAPES)
    # fused batch == the same queries run independently
    assert m.count_many(motifs) == [m.count(q) for q in motifs]
    t, chain = m.count_many(["triangle", "three-chain"])
    assert [t, chain] == [m.count("triangle"), m.count("three-chain")]
    assert {"triangle": t, "chain": chain} == reference.motif3(g)
    counts = m.count_many([P.TRIANGLE, P.clique_pattern(4)])
    assert counts == [reference.triangle_count(g), reference.clique_count(g, 4)]


# ---------------------------------------------------------------------------
# emit through the forest (FSM feed) + mixed batches
# ---------------------------------------------------------------------------


def test_triangle_emit_through_forest_matches_host_oracle():
    from repro.mining.apps import fsm_pattern_feed, triangle_list_host
    g = GRAPHS["plc"]
    tris = fsm_pattern_feed(g)[0]                # forest-scheduled emit plan
    host = triangle_list_host(g)
    assert tris.shape == host.shape == (reference.triangle_count(g), 3)

    def key(t):
        return t[np.lexsort(t.T[::-1])]
    np.testing.assert_array_equal(key(tris), key(host))


def test_mixed_emit_and_count_batch():
    g = GRAPHS["er"]
    forest = build_forest([P.compile_pattern(P.TRIANGLE, emit=True),
                           P.compile_pattern(P.TRIANGLE),
                           P.compile_pattern(P.THREE_CHAIN_INDUCED)])
    tris, tcount, chains = WaveRunner(g).run_set(forest)
    assert tcount == reference.triangle_count(g)
    assert chains == reference.three_chain_count(g, induced=True)
    assert tris.shape == (tcount, 3)


# ---------------------------------------------------------------------------
# property: random pattern sets fuse without changing any count
# ---------------------------------------------------------------------------


def _assert_forest_matches_independent(pats):
    g = TINY
    plans = [P.compile_pattern(p) for p in pats]
    fused = WaveRunner(g).run_set(build_forest(plans))
    indep = [WaveRunner(g).run(pl) for pl in plans]
    oracle = [reference.pattern_count_oracle(g, p) for p in pats]
    assert fused == indep == oracle, (pats, fused, indep, oracle)
    host = WaveRunner(g, device_compact=False).run_set(build_forest(plans))
    assert host == fused


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_random_pattern_sets_fuse_bit_identically(data):
    nplans = data.draw(st.integers(2, 3), label="nplans")
    pats = [_draw_pattern(data) for _ in range(nplans)]
    _assert_forest_matches_independent(pats)


@pytest.mark.parametrize("seed", range(6))
def test_seeded_random_pattern_sets_fuse_bit_identically(seed):
    """Hypothesis-free twin of the property test (fixed corpus): pairs of
    pseudo-random patterns must fuse without changing any count, on device
    and host compaction."""
    pats = [_seeded_pattern(2 * seed), _seeded_pattern(2 * seed + 1)]
    _assert_forest_matches_independent(pats)
