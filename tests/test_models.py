"""Per-arch smoke tests (reduced configs): forward/train/decode on CPU,
shape checks, NaN guards, and the recurrent-path equivalence properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.models.mamba import (MambaConfig, mamba_apply, mamba_decode,
                                mamba_init, mamba_init_state)
from repro.models.rwkv import RWKVConfig, rwkv_apply, rwkv_init
from repro.models.transformer import param_count


def _batch_for(spec, cfg, B, S):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "targets": jnp.ones((B, S), jnp.int32)}
    if spec.extras:
        for k, v in spec.extras("train_4k", cfg, B, S).items():
            batch[k] = jnp.zeros(v.shape, v.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_loss(arch):
    spec = get_arch(arch)
    model = spec.model(smoke=True)
    cfg = spec.smoke_config
    params, axes = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(spec, cfg, B, S)
    logits, aux = model.apply(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step_decreases_loss(arch):
    spec = get_arch(arch)
    model = spec.model(smoke=True)
    cfg = spec.smoke_config
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(spec, cfg, B, S)
    loss_fn = jax.jit(lambda p: model.loss(p, batch))
    grad_fn = jax.jit(jax.grad(lambda p: model.loss(p, batch)))
    l0 = float(loss_fn(params))
    g = grad_fn(params)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                               for x in jax.tree.leaves(g))))
    assert np.isfinite(gnorm) and gnorm > 0
    params2 = jax.tree.map(lambda p, gg: p - 0.02 * gg.astype(p.dtype),
                           params, g)
    l1 = float(loss_fn(params2))
    assert np.isfinite(l1)
    assert l1 < l0 + 0.1    # small SGD step on a fixed batch can't blow up


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_decode(arch):
    spec = get_arch(arch)
    model = spec.model(smoke=True)
    cfg = spec.smoke_config
    params, _ = model.init(jax.random.PRNGKey(0))
    B = 2
    caches, _ = model.init_cache(B, 64)
    if cfg.first_dense:
        caches["dense"] = model.init_dense_cache(B, 64)[0]
    enc = encp = None
    if cfg.encoder_layers:
        enc, encp = model._encode(
            params, {"frames": jnp.zeros((B, 16, cfg.d_model), jnp.float32)})
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, caches = model.decode_step(params, tok, jnp.int32(pos),
                                           caches, enc, encp)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_decode_matches_forward_gqa():
    """Teacher-forced decode logits == full forward logits (cache path)."""
    spec = get_arch("qwen3-0.6b")
    model = spec.model(smoke=True)
    params, _ = model.init(jax.random.PRNGKey(1))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 100)
    full, _ = model.apply(params, {"tokens": toks})
    caches, _ = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, toks[:, t:t + 1],
                                       jnp.int32(t), caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(dec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_rwkv_chunked_equals_sequential():
    cfg = RWKVConfig(d_model=32, d_ff=64, head_size=8, chunk=4)
    p, _ = rwkv_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 13, 32), jnp.float32)
    y1, s1 = rwkv_apply(p, x, cfg, chunked=True)
    y2, s2 = rwkv_apply(p, x, cfg, chunked=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_mamba_train_equals_decode():
    mc = MambaConfig(d_model=32, d_inner=64, d_state=8, chunk=4)
    mp, _ = mamba_init(jax.random.PRNGKey(3), mc)
    u = jax.random.normal(jax.random.PRNGKey(4), (2, 11, 32), jnp.float32)
    y_full, hT = mamba_apply(mp, u, mc)
    st = mamba_init_state(mc, 2, jnp.float32)
    ys = []
    for t in range(11):
        yt, st = mamba_decode(mp, u[:, t:t + 1], st, mc)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(st[0]), atol=1e-4)


def test_param_counts_match_names():
    expected = {
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "qwen3-moe-235b-a22b": (220e9, 250e9),
        "starcoder2-15b": (14e9, 17e9),
        "gemma2-27b": (25e9, 30e9),
        "stablelm-12b": (11e9, 13.5e9),
        "qwen3-0.6b": (0.5e9, 0.8e9),
        "jamba-1.5-large-398b": (380e9, 420e9),
        "rwkv6-3b": (2.5e9, 3.5e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "seamless-m4t-medium": (0.5e9, 1.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(get_arch(arch).model())
        assert lo <= n <= hi, (arch, n)
