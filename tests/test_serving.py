"""MiningService: the concurrent query service over a pool of sessions.

The service contracts from the api_redesign:
  * **cross-request batching** — heterogeneous requests submitted before
    one tick are merged into a single ``PlanForest`` schedule per traffic
    class: results are bit-identical to independent ``Miner`` runs and
    the fused feed passes are strictly below the sum of the requests'
    independent schedules;
  * **result cache** — repeated queries complete from cache without
    executing, and a ``set_graph`` version bump invalidates every entry;
  * **admission control** — a full queue rejects with the typed error at
    submit time, an expired deadline completes the request with the typed
    timeout;
  * **steady state** — under threaded concurrent submission, a warmed
    service rebuilds zero executables;
  * **mixed pool** — sharded and unsharded workers coexist in one pool
    and agree on counts (mesh leg, needs 8 devices);
  * **stable surface** — ``repro.mining`` exports the supported API and
    the legacy ``apps`` one-shots warn ``DeprecationWarning`` per call.
"""
import threading
import time
import warnings

import jax
import pytest

from repro.graph import build_csr
from repro.graph.generators import erdos_renyi, powerlaw_cluster
from repro.mining import Miner, MinerConfig
from repro.serving import MiningService, RequestRejected, RequestTimeout, \
    ServiceConfig, WorkerSpec

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 devices (XLA_FLAGS="
                            "--xla_force_host_platform_device_count=8)")

G = build_csr(erdos_renyi(60, 240, seed=3), 60)
G2 = build_csr(powerlaw_cluster(50, 4, seed=5), 50)

MIXES = [("triangle",), ("three-chain",), ("tailed-triangle",),
         ("4-clique",), ("paw", "diamond", "4-cycle")]


# ---------------------------------------------------------------------------
# cross-request batching: merged schedule, bit-identical results
# ---------------------------------------------------------------------------


def test_tick_merges_requests_bit_identical():
    svc = MiningService(G, cache_results=False)
    handles = [svc.submit(qs) for qs in MIXES]
    tick = svc.tick()
    assert tick["requests"] == len(MIXES)
    assert tick["executed"] == len(MIXES)
    fp = tick["feed_passes"]
    # the sharing acceptance: merging beats per-request schedules
    assert fp["fused"] < fp["independent"]
    ref = Miner(G)
    for h, qs in zip(handles, MIXES):
        assert h.done and not h.from_cache
        assert h.result() == ref.count_many(list(qs))


def test_single_query_convenience():
    svc = MiningService(G)
    assert svc.query("triangle") == Miner(G).count("triangle")


def test_tick_on_empty_queue_is_noop():
    svc = MiningService(G)
    tick = svc.tick()
    assert tick["requests"] == 0 and tick["executed"] == 0


# ---------------------------------------------------------------------------
# result cache: hits, and invalidation on graph-version bump
# ---------------------------------------------------------------------------


def test_cache_hit_and_version_invalidation():
    svc = MiningService(G, cache_results=True)
    first = svc.query(("triangle", "paw"))
    warm = svc.cache.snapshot()
    assert warm["hits"] == 0 and warm["misses"] == 2

    h = svc.submit(("triangle", "paw"))
    tick = svc.tick()
    assert tick["executed"] == 0            # fully served from cache
    assert h.from_cache and h.result() == first
    assert svc.cache.snapshot()["hits"] == 2

    svc.set_graph(G2)                       # version bump: all entries stale
    snap = svc.cache.snapshot()
    assert snap["entries"] == 0 and snap["invalidations"] == warm["entries"]
    assert svc.query("triangle") == Miner(G2).count("triangle")


def test_partial_cache_hit_shrinks_batch():
    svc = MiningService(G, cache_results=True)
    svc.query(("triangle",))
    h = svc.submit(("triangle", "4-cycle"))   # one cached, one not
    before = svc.cache.snapshot()["hits"]
    svc.tick()
    ref = Miner(G)
    assert h.result() == [ref.count("triangle"), ref.count("4-cycle")]
    assert svc.cache.snapshot()["hits"] == before + 1


# ---------------------------------------------------------------------------
# admission control: queue-full rejection, deadline timeout
# ---------------------------------------------------------------------------


def test_queue_full_rejects_at_submit():
    svc = MiningService(G, max_in_flight=1)
    admitted = svc.submit(("triangle",))
    rejected = svc.submit(("paw",))
    assert rejected.done                    # completed immediately, no wait
    with pytest.raises(RequestRejected):
        rejected.result()
    assert svc.stats["service_rejected"] == 1
    svc.run_until_idle()                    # the admitted one still serves
    assert admitted.result() == [Miner(G).count("triangle")]


def test_deadline_timeout_completes_with_typed_error():
    svc = MiningService(G, timeout_s=0.01)
    h = svc.submit(("triangle",))
    time.sleep(0.05)                        # deadline passes before the tick
    tick = svc.tick()
    assert tick["timeouts"] == 1 and tick["executed"] == 0
    assert h.done
    with pytest.raises(RequestTimeout):
        h.result()
    # a fresh submit with a roomy per-request deadline still serves
    assert svc.submit(("triangle",), timeout_s=60.0).result is not None
    svc.run_until_idle()


# ---------------------------------------------------------------------------
# steady state: zero retraces under threaded concurrent load
# ---------------------------------------------------------------------------


def test_steady_state_zero_retraces_under_concurrent_load():
    svc = MiningService(G, cache_results=False)
    [svc.submit(qs) for qs in MIXES]
    svc.run_until_idle()                    # warm-up: schedules + traces
    before = svc.stats["retraces"]

    results: list = []

    def client(i):
        h = svc.submit(MIXES[i % len(MIXES)])
        results.append((i, h.result(timeout=60.0)))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(10)]
    for t in threads:
        t.start()
    while svc.pending or any(t.is_alive() for t in threads):
        if not svc.tick()["requests"]:
            time.sleep(0.001)
    for t in threads:
        t.join()
    assert len(results) == 10
    ref = Miner(G)
    for i, res in results:
        assert res == ref.count_many(list(MIXES[i % len(MIXES)]))
    assert svc.stats["retraces"] == before  # steady state: 0 new traces


# ---------------------------------------------------------------------------
# mixed sharded/unsharded worker pool (mesh leg)
# ---------------------------------------------------------------------------


@needs8
def test_mixed_pool_routes_by_class_and_counts_agree():
    svc = MiningService(G, workers=(
        WorkerSpec("default", MinerConfig()),
        WorkerSpec("bulk", MinerConfig(mesh=8))))
    assert svc.pool.worker("bulk").mesh is not None
    assert svc.pool.worker("default").mesh is None
    a = svc.submit(("triangle", "paw"))
    b = svc.submit(("triangle", "paw"), traffic_class="bulk")
    svc.tick()
    assert a.result() == b.result() == Miner(G).count_many(
        ["triangle", "paw"])
    # unknown class falls back to the first worker instead of failing
    c = svc.submit(("triangle",), traffic_class="nope")
    svc.run_until_idle()
    assert c.result() == [Miner(G).count("triangle")]


# ---------------------------------------------------------------------------
# stable public surface + deprecated shims
# ---------------------------------------------------------------------------


def test_public_surface_exports():
    import repro.mining as mining
    for name in ("Miner", "MinerConfig", "MiningService", "Pattern",
                 "Motif", "compile_pattern"):
        assert name in mining.__all__
        assert getattr(mining, name) is not None
    assert mining.MiningService is MiningService


def test_service_config_sugar_matches_explicit_config():
    explicit = MiningService(G, ServiceConfig(max_in_flight=2))
    sugar = MiningService(G, max_in_flight=2)
    assert explicit.config == sugar.config


def test_apps_one_shots_warn_deprecation():
    from repro.mining import apps
    with pytest.warns(DeprecationWarning, match="triangle_count is "
                      "deprecated"):
        n = apps.triangle_count(G)
    assert n == Miner(G).count("triangle")
    with pytest.warns(DeprecationWarning, match="four_motif"):
        apps.four_motif(G)
    # the session pool itself is supported API: no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert apps.shared_session(G).count("triangle") == n


# ---------------------------------------------------------------------------
# value traffic: aggregate requests on the ``values`` class
# ---------------------------------------------------------------------------


def _weighted_g():
    from repro.graph import edge_weights, with_edge_values
    from repro.graph.csr import edge_list
    return with_edge_values(G, edge_weights(edge_list(G), seed=7))


def test_aggregate_requests_route_and_match_sessions():
    from repro.serving import VALUES_CLASS
    gw = _weighted_g()
    svc = MiningService(gw)
    counts = svc.submit(["triangle", "4-clique"])
    sums = svc.submit(["triangle", "4-clique"], aggregate="sum")
    maxes = svc.submit("triangle", aggregate="max")
    tick = svc.tick()
    assert tick["executed"] == 3
    assert sums.traffic_class == VALUES_CLASS
    assert counts.traffic_class != VALUES_CLASS
    ref = Miner(gw)
    assert counts.result(0) == [ref.count("triangle"), ref.count("4-clique")]
    assert sums.result(0) == [ref.aggregate("triangle", op="sum"),
                              ref.aggregate("4-clique", op="sum")]
    assert maxes.result(0)[0] == ref.aggregate("triangle", op="max")


def test_aggregate_cache_keys_never_collide_with_counts():
    gw = _weighted_g()
    svc = MiningService(gw)
    count = svc.query("triangle")
    total = svc.query("triangle", aggregate="sum")
    assert count != total          # int count vs f32 dyadic aggregate
    # both repeats come from cache, each under its own key
    c2 = svc.submit("triangle")
    s2 = svc.submit("triangle", aggregate="sum")
    tick = svc.tick()
    assert tick["cached"] == 2 and tick["executed"] == 0
    assert c2.result(0)[0] == count and c2.from_cache
    assert s2.result(0)[0] == total and s2.from_cache
    # a different op is a different key: it executes
    r_min = svc.submit("triangle", aggregate="min")
    assert svc.tick()["executed"] == 1
    assert r_min.result(0)[0] == Miner(gw).aggregate("triangle", op="min")


def test_aggregate_groups_batch_like_count_groups():
    gw = _weighted_g()
    svc = MiningService(gw, cache_results=False)
    handles = [svc.submit(qs, aggregate="sum") for qs in MIXES]
    tick = svc.tick()
    assert tick["executed"] == len(MIXES)
    fp = tick["feed_passes"]
    assert fp["fused"] < fp["independent"]   # cross-request sharing holds
    ref = Miner(gw)
    for h, qs in zip(handles, MIXES):
        assert h.result(0) == [ref.aggregate(q, op="sum") for q in qs]


def test_aggregate_submit_rejects_unknown_op():
    with pytest.raises(ValueError, match="aggregate must be one of"):
        MiningService(_weighted_g()).submit("triangle", aggregate="avg")
