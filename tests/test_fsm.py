"""FSM: MNI support vs brute-force oracle; downward closure; sFSM contrast."""
import numpy as np
import pytest

from repro.graph import build_csr
from repro.graph.generators import erdos_renyi, powerlaw_cluster
from repro.mining.fsm import fsm, random_labels, sfsm
from repro.mining.reference import fsm_oracle


@pytest.mark.parametrize("seed,nlab", [(1, 2), (2, 3), (3, 4)])
def test_fsm_matches_oracle(seed, nlab):
    g = build_csr(erdos_renyi(22, 55, seed=seed), 22)
    labels = random_labels(22, nlab, seed=seed)
    got = fsm(g, labels, min_support=2)
    want = fsm_oracle(g, labels, min_support=2, metric="mni")
    assert got == want


@pytest.mark.parametrize("seed", [1, 4])
def test_sfsm_matches_oracle_modulo_closure_bug(seed):
    """sFSM prunes with GRAMER's count-'support', which VIOLATES downward
    closure — so (faithfully) it may miss patterns the exhaustive oracle
    finds. Assert: every reported value is exact, and every miss is
    explained by an infrequent-by-count sub-pattern (the paper's §VI-B
    criticism, reproduced)."""
    g = build_csr(powerlaw_cluster(20, 3, seed=seed), 20)
    labels = random_labels(20, 3, seed=seed)
    got = sfsm(g, labels, min_support=3)
    want = fsm_oracle(g, labels, min_support=3, metric="count")
    for k, v in got.items():
        assert want.get(k) == v, k
    all_counts = fsm_oracle(g, labels, min_support=0, metric="count")
    from repro.mining.fsm import edge_key, wedge_key
    for k in set(want) - set(got):
        kind, lab = k
        subs = []
        if kind == "wedge":
            la, lb, lc = lab
            subs = [edge_key(la, lb), edge_key(lb, lc)]
        elif kind == "triangle":
            la, lb, lc = lab
            subs = [edge_key(la, lb), edge_key(lb, lc), edge_key(la, lc),
                    wedge_key(lb, la, lc), wedge_key(la, lb, lc),
                    wedge_key(la, lc, lb)]
        elif kind == "star3":
            c, leaves = lab
            subs = [edge_key(c, lf) for lf in leaves]
            subs += [wedge_key(x, c, y)
                     for i, x in enumerate(leaves) for y in leaves[i + 1:]]
        elif kind == "path4":
            a, b, c, d = lab
            subs = [edge_key(a, b), edge_key(b, c), edge_key(c, d),
                    wedge_key(a, b, c), wedge_key(b, c, d)]
        assert any(all_counts.get(s, 0) < 3 for s in subs), \
            f"{k} missed but all sub-patterns frequent"


def test_downward_closure_property():
    """MNI support of any 3-edge pattern <= support of its sub-patterns —
    the property GRAMER's count-based support violates (§VI-B)."""
    from repro.mining.fsm import edge_key, wedge_key
    g = build_csr(erdos_renyi(24, 70, seed=9), 24)
    labels = random_labels(24, 2, seed=9)
    res = fsm(g, labels, min_support=1)
    for key, sup in res.items():
        kind, lab = key
        if kind == "wedge":
            la, lb, lc = lab
            assert sup <= res[edge_key(la, lb)]
            assert sup <= res[edge_key(lb, lc)]
        if kind == "triangle":
            la, lb, lc = lab
            for x, y in [(la, lb), (lb, lc), (la, lc)]:
                assert sup <= res[edge_key(x, y)]


def test_sfsm_violates_downward_closure_somewhere():
    """Embedding counts can EXCEED a sub-pattern's count (e.g. wedges per
    edge) — demonstrating why the paper calls GRAMER's support wrong."""
    g = build_csr(erdos_renyi(24, 80, seed=2), 24)
    labels = np.zeros(24, dtype=np.int32)            # single label
    res = sfsm(g, labels, min_support=1)
    from repro.mining.fsm import edge_key, wedge_key
    e = res[edge_key(0, 0)]
    w = res.get(wedge_key(0, 0, 0), 0)
    assert w > e                                     # more wedges than edges
