"""Fused multi-operand level path vs the per-ref mark fallback.

``WaveRunner(fused_level=True)`` dispatches ONE k-operand kernel per general
level (``ops.xlevel_count``/``xlevel_compact``); ``fused_level=False`` keeps
the per-reference ``xmark`` composition. The acceptance contract of PR 4:
every mining app's counts are bit-identical with the flag on and off (and
equal to the oracles), while the general-level kernel dispatch count drops
from k per level to 1.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.graph import build_csr
from repro.graph.generators import clique_planted, erdos_renyi, powerlaw_cluster
from repro.mining import reference
from repro.mining.apps import triangle_list_host
from repro.mining.engine import WaveRunner
from repro.mining.forest import build_forest
from repro.mining import plan as P

from test_plan import _draw_pattern, _seeded_pattern

GRAPHS = {
    "er": build_csr(erdos_renyi(90, 540, seed=23), 90),
    "plc": build_csr(powerlaw_cluster(70, 5, seed=2), 70),
    "cliq": build_csr(clique_planted(60, 180, (6, 5), seed=4), 60),
}
TINY = build_csr(erdos_renyi(18, 48, seed=7), 18)

# every paper app + the 4-motif family as compiled plans (FSM's feed is the
# triangle emit plan, covered by the emit test below)
APP_PLANS = {
    "T": P.compile_pattern(P.TRIANGLE),
    "TS": P.compile_pattern(P.TRIANGLE_NESTED),
    "TC": P.compile_pattern(P.THREE_CHAIN_INDUCED),
    "TT": P.compile_pattern(P.TAILED_TRIANGLE),
    "4C": P.compile_pattern(P.clique_pattern(4)),
    "5C": P.compile_pattern(P.clique_pattern(5)),
    **{name: P.compile_pattern(p) for name, p in P.FOUR_MOTIFS.items()},
}


def _runs(g, plan, **kw):
    on = WaveRunner(g, fused_level=True, **kw)
    off = WaveRunner(g, fused_level=False, **kw)
    return on.run(plan), off.run(plan), on, off


# fast oracles per app (the permutation oracle is reserved for TINY — it is
# O(n^k · k!) and the census/closed forms already cover these patterns)
_ORACLE = {
    "T": reference.triangle_count,
    "TS": reference.triangle_count,
    "TC": lambda g: reference.three_chain_count(g, induced=True),
    "TT": reference.tailed_triangle_count,
    "4C": lambda g: reference.clique_count(g, 4),
    "5C": lambda g: reference.clique_count(g, 5),
    **{name: (lambda g, _n=name: reference.four_motif_counts(g)[_n])
       for name in P.FOUR_MOTIFS},
}


@pytest.mark.parametrize("name", list(APP_PLANS))
def test_apps_bit_identical_fused_on_off(name):
    g = GRAPHS["er"]
    got_on, got_off, *_ = _runs(g, APP_PLANS[name])
    assert got_on == got_off, (name, got_on, got_off)
    assert got_on == _ORACLE[name](g), name


@pytest.mark.parametrize("gname", list(GRAPHS))
def test_four_motif_forest_fused_on_off(gname):
    """The F4M batch (shared expands + residual-packed branches) through
    run_set with the fused level path on and off, vs independent plans."""
    g = GRAPHS[gname]
    plans = [P.compile_pattern(p) for p in P.FOUR_MOTIFS.values()]
    forest = build_forest(plans)
    f_on = WaveRunner(g, fused_level=True).run_set(forest)
    f_off = WaveRunner(g, fused_level=False).run_set(forest)
    indep = [WaveRunner(g).run(pl) for pl in plans]
    assert f_on == f_off == indep


def test_three_motif_and_fsm_feed_fused_on_off():
    g = GRAPHS["plc"]
    t3 = [P.compile_pattern(P.TRIANGLE),
          P.compile_pattern(P.THREE_CHAIN_INDUCED)]
    assert WaveRunner(g, fused_level=True).run_set(build_forest(t3)) \
        == WaveRunner(g, fused_level=False).run_set(build_forest(t3))
    # FSM's engine feed: the triangle emit plan — embeddings, not counts
    emit = P.compile_pattern(P.TRIANGLE, emit=True)
    e_on, e_off, *_ = _runs(g, emit)
    np.testing.assert_array_equal(e_on, e_off)
    np.testing.assert_array_equal(e_on, triangle_list_host(g))


def test_tiny_chunks_fused_on_off():
    """Tiny chunks force multi-chunk waves + chunk-rounded item buffers
    through the scan compaction."""
    g = GRAPHS["cliq"]
    census = reference.four_motif_counts(g)
    for name in ("4-cycle", "paw"):
        plan = APP_PLANS[name]
        a = WaveRunner(g, chunk=128, fused_level=True).run(plan)
        b = WaveRunner(g, chunk=128, fused_level=False).run(plan)
        assert a == b == census[name]


def test_host_oracle_unaffected_by_fused_level():
    g = GRAPHS["er"]
    plan = APP_PLANS["4-cycle"]
    want = WaveRunner(g).run(plan)
    assert WaveRunner(g, device_compact=False, fused_level=True).run(plan) \
        == WaveRunner(g, device_compact=False, fused_level=False).run(plan) \
        == want


def test_dispatch_count_drops_from_k_to_one():
    """4-cycle's general level (inter + sub refs, k=2) must cost exactly one
    kernel dispatch per executable call on the fused path, k on the
    fallback — the per-operand DMA saving the tentpole claims."""
    g = GRAPHS["er"]
    plan = APP_PLANS["4-cycle"]
    _, _, on, off = _runs(g, plan)
    k3 = len(plan.ops[-1].inter) + len(plan.ops[-1].sub)
    assert k3 == 2                                  # inter (2,), sub (0,)
    n3_on = on.level_execs[("count", 3)]
    n3_off = off.level_execs[("count", 3)]
    assert n3_on == n3_off > 0
    # fallback pays (k-1) extra dispatches per general-level executable call
    assert off.stats["level_kernel_dispatches"] \
        - on.stats["level_kernel_dispatches"] == (k3 - 1) * n3_off


def test_pallas_backend_fused_level_agrees():
    """The interpret-mode Pallas kernels through the engine's fused path
    (the TPU configuration, minus the hardware). One multi-operand pattern
    on a micro graph with a small chunk: interpret mode executes the grid
    as a Python loop, so every extra padded row costs wall clock — the
    k-operand kernel's full parity sweep lives in test_kernels.py."""
    g = build_csr(erdos_renyi(12, 30, seed=5), 12)
    plan = APP_PLANS["4-cycle"]
    got = WaveRunner(g, chunk=128, backend="pallas",
                     fused_level=True).run(plan)
    assert got == reference.pattern_count_oracle(g, plan.pattern)


def _assert_fused_level_invariant(pat):
    g = TINY
    plan = P.compile_pattern(pat)
    on = WaveRunner(g, fused_level=True).run(plan)
    off = WaveRunner(g, fused_level=False).run(plan)
    want = reference.pattern_count_oracle(g, pat)
    assert on == off == want, (pat, on, off, want)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_random_patterns_fused_level_bit_identical(data):
    _assert_fused_level_invariant(_draw_pattern(data))


@pytest.mark.parametrize("seed", range(8))
def test_seeded_random_patterns_fused_level_bit_identical(seed):
    """Hypothesis-free twin (fixed corpus) of the property above."""
    _assert_fused_level_invariant(_seeded_pattern(seed))
