"""Pattern-plan compiler + interpreter: structure, 4-motif oracles, and
device/host agreement for arbitrary compiled plans.

Three layers of checking:
  * compiler unit tests — carry reuse, tail folding, feed selection and
    validation errors on the canned patterns;
  * 4-motif counts vs two independent oracles (brute-force degree-signature
    census in ``reference``, ESU connected-set enumeration in
    ``exhaustive``) on random + generator graphs;
  * a hypothesis property: any randomly generated valid ``Pattern`` compiles
    to a ``WavePlan`` whose device-compacted and host-oracle executions
    agree with each other and with the permutation-enumeration oracle.
"""
import itertools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.graph import build_csr
from repro.graph.generators import clique_planted, erdos_renyi, powerlaw_cluster
from repro.mining import exhaustive, reference
from repro.mining.apps import fsm_pattern_feed, shared_session, \
    triangle_list_host
from repro.mining.engine import WaveRunner
from repro.mining import plan as P

GRAPHS = {
    "er": build_csr(erdos_renyi(60, 240, seed=3), 60),
    "plc": build_csr(powerlaw_cluster(50, 4, seed=5), 50),
    "cliq": build_csr(clique_planted(45, 120, (6, 5), seed=1), 45),
}
TINY = build_csr(erdos_renyi(18, 48, seed=7), 18)


def _four_motif(g):
    names = list(P.FOUR_MOTIF_SHAPES)
    return dict(zip(names, shared_session(g).count_many(names)))


# ---------------------------------------------------------------------------
# compiler structure
# ---------------------------------------------------------------------------


def test_clique_plan_reuses_carry_every_level():
    pl = P.compile_pattern(P.clique_pattern(5))
    assert pl.symmetric
    assert [op.kind for op in pl.ops] == ["expand", "expand", "count"]
    assert not pl.ops[0].use_carry
    assert all(op.use_carry for op in pl.ops[1:])
    for op in pl.ops:
        assert op.inter in ((1,), (2,), (3,))   # one new INTER ref per level
        assert op.ub == op.inter                # bound = newest vertex


def test_tailed_triangle_folds_to_degree_tail():
    pl = P.compile_pattern(P.TAILED_TRIANGLE)
    assert not pl.symmetric                     # no (1,0) restriction
    assert len(pl.ops) == 1
    op = pl.ops[0]
    assert op.kind == "count" and op.tail == (1, 2)
    assert op.inter == (1,) and op.ub == (0,)


def test_three_chain_compiles_sub_and_lower_bound():
    op = P.compile_pattern(P.THREE_CHAIN_INDUCED).ops[0]
    assert op.sub == (1,) and op.lb == (1,) and not op.ub


def test_cycle4_cannot_reuse_carry():
    pl = P.compile_pattern(P.CYCLE4)
    assert [op.use_carry for op in pl.ops] == [False, False]
    assert pl.ops[0].out_cols == (0, 1, 2)      # level 3 references them all


def test_star4_reuses_carry_for_sub_level():
    op = P.compile_pattern(P.STAR4).ops[1]
    assert op.use_carry and op.sub == (2,) and op.ub == (2,)


def test_emit_plan_forwards_all_columns():
    pl = P.compile_pattern(P.TRIANGLE, emit=True)
    assert pl.ops[-1].kind == "emit"
    assert pl.ops[-1].out_cols == (0, 1, 2)


def test_pattern_validation_errors():
    with pytest.raises(ValueError):             # disconnected matching order
        P.pattern("bad", 4, [(0, 1), (0, 2)])
    with pytest.raises(ValueError):             # v0-v1 not an edge
        P.pattern("bad", 3, [(0, 2), (1, 2)])
    with pytest.raises(ValueError):             # wrong feed orientation
        P.compile_pattern(P.pattern("bad", 3, [(0, 1), (0, 2), (1, 2)],
                                    restrictions=[(0, 1)]))
    with pytest.raises(ValueError):             # restriction cycle
        P.compile_pattern(P.pattern("bad", 3, [(0, 1), (0, 2), (1, 2)],
                                    restrictions=[(1, 2), (2, 1)]))


# ---------------------------------------------------------------------------
# 4-motif mining vs independent oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(GRAPHS))
def test_four_motif_matches_bruteforce_census(name):
    g = GRAPHS[name]
    assert _four_motif(g) == reference.four_motif_counts(g)


def test_four_motif_matches_exhaustive_esu():
    g = GRAPHS["plc"]
    got = _four_motif(g)
    for pat in ("diamond", "4-cycle", "4-path", "4-star"):
        assert got[pat] == exhaustive.exhaustive_count(g, pat)
    assert got["paw"] == exhaustive.exhaustive_count(g, "tailed-triangle")


@pytest.mark.parametrize("name", ["er", "cliq"])
def test_four_motif_device_host_compaction_agree(name):
    g = GRAPHS[name]
    for pat in P.FOUR_MOTIFS.values():
        dev = shared_session(g).count(pat)
        host = shared_session(g, device_compact=False).count(pat)
        assert dev == host, pat.name


def test_tail_count_sum_exact_past_int32():
    """The degree-tail multiplier must stay exact when one chunk's product
    sum crosses 2^31 (the pre-refactor host path multiplied in int64; the
    device path returns per-chunk (hi, lo) int32 partials). On K_n the last
    16384-edge chunk sums ~16384·n·(n-3) ≈ 3e9 > 2^31, and the total has a
    closed form: TT(K_n) = (n-3)(n-2)·n(n-1)/2."""
    n = 450
    g = build_csr(np.array(list(itertools.combinations(range(n), 2))), n)
    want = (n - 3) * (n - 2) * n * (n - 1) // 2
    assert shared_session(g, chunk=16384).count("tailed-triangle") == want


def test_pattern_oracle_consistent_with_references():
    g = TINY
    assert reference.pattern_count_oracle(g, P.TRIANGLE) \
        == reference.triangle_count(g)
    assert reference.pattern_count_oracle(g, P.clique_pattern(4)) \
        == reference.clique_count(g, 4)
    assert reference.pattern_count_oracle(g, P.TAILED_TRIANGLE) \
        == reference.tailed_triangle_count(g)


# ---------------------------------------------------------------------------
# device-resident triangle enumeration (FSM feed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(GRAPHS))
def test_triangle_list_device_matches_host_oracle(name):
    g = GRAPHS[name]
    dev = fsm_pattern_feed(g)[0]
    host = triangle_list_host(g)
    assert dev.shape == host.shape == (reference.triangle_count(g), 3)
    # same triangles (chunk orders differ): compare as sorted row sets
    def key(t):
        return t[np.lexsort(t.T[::-1])]
    np.testing.assert_array_equal(key(dev), key(host))


def test_triangle_list_uses_device_compaction():
    g = GRAPHS["er"]
    runner = WaveRunner(g)
    tris = runner.run(P.compile_pattern(P.TRIANGLE, emit=True))
    assert runner.stats["device_compactions"] > 0
    assert runner.stats["host_compactions"] == 0
    assert tris.shape[0] == reference.triangle_count(g)


# ---------------------------------------------------------------------------
# property: any compiled plan agrees across compaction modes + oracle
# ---------------------------------------------------------------------------


def _draw_pattern(data) -> P.Pattern:
    k = data.draw(st.integers(3, 4), label="k")
    edges = {(0, 1)}
    for lvl in range(2, k):                    # keep matching order connected
        edges.add((data.draw(st.integers(0, lvl - 1), label=f"anchor{lvl}"),
                   lvl))
    for i, j in itertools.combinations(range(k), 2):
        if (i, j) not in edges and data.draw(st.booleans(), label=f"e{i}{j}"):
            edges.add((i, j))
    # restrictions: subset of pairs oriented by a random total order => acyclic
    perm = data.draw(st.permutations(list(range(k))), label="order")
    rank = {v: i for i, v in enumerate(perm)}
    restr = []
    for i, j in itertools.combinations(range(k), 2):
        if data.draw(st.booleans(), label=f"r{i}{j}"):
            lo, hi = (i, j) if rank[i] > rank[j] else (j, i)
            if (lo, hi) == (0, 1):
                continue                       # feed orientation must be (1,0)
            restr.append((lo, hi))
    induced = data.draw(st.booleans(), label="induced")
    return P.pattern("random", k, sorted(edges), restrictions=restr,
                     induced=induced)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_random_plans_agree_with_oracle_both_modes(data):
    pat = _draw_pattern(data)
    g = TINY
    want = reference.pattern_count_oracle(g, pat)
    dev = shared_session(g).count(pat)
    host = shared_session(g, device_compact=False).count(pat)
    assert dev == host == want, (pat, dev, host, want)


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_random_plans_tiny_chunks_agree(data):
    """Tiny chunks force multi-chunk waves + chunk-rounded item buffers."""
    pat = _draw_pattern(data)
    g = TINY
    want = reference.pattern_count_oracle(g, pat)
    assert shared_session(g, chunk=128).count(pat) == want, pat


def _seeded_pattern(seed: int) -> P.Pattern:
    """Deterministic stand-in for the hypothesis draw (runs without the
    package installed; same property, fixed corpus)."""
    import random
    rng = random.Random(seed)

    class _Draw:
        def draw(self, strat, label=None):
            return strat(rng)
    def int_st(lo, hi):
        return lambda r: r.randint(lo, hi)

    def bool_st(r):
        return r.random() < 0.5

    def perm_st(xs):
        return lambda r: r.sample(xs, len(xs))

    class _St:
        integers = staticmethod(int_st)
        booleans = staticmethod(lambda: bool_st)
        permutations = staticmethod(perm_st)
    global st
    real_st, st = st, _St()
    try:
        return _draw_pattern(_Draw())
    finally:
        st = real_st


@pytest.mark.parametrize("seed", range(10))
def test_seeded_random_plans_agree_with_oracle(seed):
    """Hypothesis-free twin of the property test: 10 pseudo-random patterns
    (k ∈ {3,4}, random adjacency/restrictions/inducedness) must agree across
    device/host compaction and with the permutation-enumeration oracle."""
    pat = _seeded_pattern(seed)
    g = TINY
    want = reference.pattern_count_oracle(g, pat)
    dev = shared_session(g).count(pat)
    host = shared_session(g, device_compact=False).count(pat)
    assert dev == host == want, (pat, dev, host, want)
