"""Import hypothesis if available, else degrade property tests to skips.

The property suites (test_kernels / test_sparse / test_stream_isa) mix
hypothesis `@given` tests with plain parametrized sweeps. Without this shim a
missing `hypothesis` turns all three modules into collection *errors*, taking
the non-property tests down with them. With it:

  * hypothesis installed  -> everything runs, unchanged semantics
  * hypothesis missing    -> `@given` tests skip at call time with a clear
                             reason; every other test still collects and runs

The stub only implements what module-level strategy definitions need:
strategy factories returning chainable dummies (`.map`/`.filter`/`.flatmap`),
a no-op `settings`, and a `given` that swaps the test body for a skip.
"""
from __future__ import annotations

import functools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable placeholder for a hypothesis SearchStrategy."""

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

    class _Strategies:
        def __getattr__(self, name):
            def factory(*args, **kwargs):
                return _Strategy()
            return factory

    st = _Strategies()

    def given(*_args, **_kwargs):
        def decorate(fn):
            @functools.wraps(fn)
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (see requirements.txt)")
            # drop hypothesis-bound params so pytest doesn't demand fixtures
            skipper.__wrapped__ = None
            skipper.__signature__ = __import__("inspect").Signature()
            return skipper
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
