"""Import hypothesis if available, else run property tests on a seeded twin.

The property suites (test_kernels / test_sparse / test_stream_isa /
test_plan / test_forest / test_fused_level) mix hypothesis ``@given`` tests
with plain parametrized sweeps. Without this shim a missing ``hypothesis``
turns those modules into collection *errors*, taking the non-property tests
down with them. With it:

  * hypothesis installed  -> everything runs, unchanged semantics
  * hypothesis missing    -> ``@given`` tests run under a deterministic
                             mini-runner: each strategy draws from a
                             ``random.Random`` seeded on the test's
                             qualified name, for ``max_examples``
                             iterations. Weaker than hypothesis (no
                             shrinking, no coverage-guided search, fixed
                             corpus) but the properties are genuinely
                             exercised instead of silently skipped.

CI never relies on the fallback: scripts/tier1.sh installs requirements.txt
and sets TIER1_REQUIRE_DEPS=1, which makes conftest fail the run outright
if the real hypothesis is missing.

The mini-runner implements only what the suites use: ``integers``,
``booleans``, ``floats``, ``lists``, ``permutations``, ``none``,
``one_of``, ``sampled_from``, ``data`` and the chainable
``map``/``filter``/``flatmap`` combinators.
"""
from __future__ import annotations

import functools
import inspect
import random

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function ``rng -> value`` with hypothesis' combinators."""

        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("mini-hypothesis: filter rejected 1000 "
                                 "consecutive draws")
            return _Strategy(draw)

        def flatmap(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng))._draw(rng))

    class _Data:
        """Stand-in for the object ``st.data()`` injects: interactive
        draws pull from the test's seeded stream (labels are ignored —
        they only matter for hypothesis' reporting)."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy._draw(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements._draw(rng) for _ in range(size)]
            return _Strategy(draw)

        @staticmethod
        def permutations(values):
            def draw(rng):
                out = list(values)
                rng.shuffle(out)
                return out
            return _Strategy(draw)

        @staticmethod
        def none():
            return _Strategy(lambda rng: None)

        @staticmethod
        def one_of(*strategies):
            return _Strategy(lambda rng: rng.choice(strategies)._draw(rng))

        @staticmethod
        def sampled_from(values):
            values = list(values)
            return _Strategy(lambda rng: rng.choice(values))

        @staticmethod
        def data():
            return _Strategy(lambda rng: _Data(rng))

    st = _Strategies()

    def given(*gargs, **gkwargs):
        def decorate(fn):
            @functools.wraps(fn)
            def runner(*a, **k):
                n = getattr(runner, "_mini_max_examples", 10)
                rng = random.Random(
                    f"mini:{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    vals = [s._draw(rng) for s in gargs]
                    kvals = {name: s._draw(rng)
                             for name, s in gkwargs.items()}
                    try:
                        fn(*a, *vals, **k, **kvals)
                    except Exception:
                        print(f"mini-hypothesis falsified {fn.__qualname__} "
                              f"on example {i}: args={vals!r} "
                              f"kwargs={kvals!r}")
                        raise
            # hide the strategy-bound params so pytest doesn't demand
            # fixtures for them; drop __wrapped__ so introspection stops here
            runner.__wrapped__ = None
            runner.__signature__ = inspect.Signature()
            return runner
        return decorate

    def settings(*_args, **kwargs):
        max_examples = kwargs.get("max_examples", 10)

        def decorate(fn):
            fn._mini_max_examples = max_examples
            return fn
        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
