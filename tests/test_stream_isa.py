"""Stream ISA semantics: Table I instructions vs python-set oracles, plus
the representation invariants I1-I4 (hypothesis property tests)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import isa
from repro.core.stream import (LANE, SENTINEL, Stream, StreamTable,
                               make_stream, round_capacity, stream_from_slice,
                               to_host)

sorted_sets = st.lists(st.integers(0, 10_000), max_size=300).map(
    lambda xs: np.array(sorted(set(xs)), dtype=np.int32))
bounds = st.one_of(st.none(), st.integers(0, 10_000))


def check_invariants(s: Stream):
    keys = np.asarray(s.keys)
    n = int(s.length)
    assert s.capacity % LANE == 0                       # I4
    assert 0 <= n <= s.capacity                         # I3
    assert np.all(keys[n:] == SENTINEL)                 # I2
    if n > 1:
        assert np.all(np.diff(keys[:n]) > 0)            # I1 strictly sorted


@settings(max_examples=40, deadline=None)
@given(sorted_sets, sorted_sets, bounds)
def test_inter_matches_set_semantics(a, b, bound):
    sa, sb = make_stream(a), make_stream(b)
    out = isa.s_inter(sa, sb, bound)
    check_invariants(out)
    want = np.intersect1d(a, b)
    if bound is not None:
        want = want[want < bound]
    np.testing.assert_array_equal(to_host(out), want)
    assert int(isa.s_inter_c(sa, sb, bound)) == len(want)


@settings(max_examples=40, deadline=None)
@given(sorted_sets, sorted_sets, bounds)
def test_sub_matches_set_semantics(a, b, bound):
    sa, sb = make_stream(a), make_stream(b)
    out = isa.s_sub(sa, sb, bound)
    check_invariants(out)
    want = np.setdiff1d(a, b)
    if bound is not None:
        want = want[want < bound]
    np.testing.assert_array_equal(to_host(out), want)
    assert int(isa.s_sub_c(sa, sb, bound)) == len(want)


@settings(max_examples=25, deadline=None)
@given(sorted_sets, sorted_sets)
def test_union_identity(a, b):
    sa, sb = make_stream(a), make_stream(b)
    assert int(isa.s_union_count(sa, sb)) == len(np.union1d(a, b))


@settings(max_examples=25, deadline=None)
@given(sorted_sets, sorted_sets)
def test_vinter_mac_is_sparse_dot(a, b):
    va = np.arange(len(a), dtype=np.float32) + 1
    vb = 2.0 * (np.arange(len(b), dtype=np.float32) + 1)
    sa, sb = make_stream(a, values=va), make_stream(b, values=vb)
    got = float(isa.s_vinter(sa, sb, op="mac"))
    da = dict(zip(a.tolist(), va))
    db = dict(zip(b.tolist(), vb))
    want = sum(da[k] * db[k] for k in set(da) & set(db))
    assert got == pytest.approx(want, rel=1e-5)


def test_vinter_max_min():
    a = make_stream([1, 3, 5], values=[1., 10., 2.])
    b = make_stream([3, 5, 7], values=[4., 1., 9.])
    assert float(isa.s_vinter(a, b, op="max")) == pytest.approx(10. + 2.)
    assert float(isa.s_vinter(a, b, op="min")) == pytest.approx(4. + 1.)


def test_vinter_requires_values():
    a, b = make_stream([1, 2]), make_stream([2, 3])
    with pytest.raises(TypeError):
        isa.s_vinter(a, b)


def test_fetch_and_eos():
    s = make_stream([10, 20, 30])
    assert int(isa.s_fetch(s, 1)) == 20
    assert int(isa.s_fetch(s, 3)) == SENTINEL     # EOS
    assert int(isa.s_fetch(s, 1000)) == SENTINEL


def test_stream_from_slice_is_s_read():
    mem = np.arange(0, 100, 2, dtype=np.int32)    # sorted memory
    s = stream_from_slice(np.asarray(mem), 5, 7, capacity=7)
    np.testing.assert_array_equal(to_host(s), mem[5:12])
    check_invariants(s)


def test_stream_table_smt_semantics():
    t = StreamTable(max_active=2)
    s1 = t.register(make_stream([1]))
    s2 = t.register(make_stream([2]))
    with pytest.raises(RuntimeError):              # stall-on-full
        t.register(make_stream([3]))
    t.release(s1)                                  # S_FREE
    with pytest.raises(KeyError):                  # use-after-free
        t.get(s1)
    t.register(make_stream([4]))                   # slot reusable
    assert int(to_host(t.get(s2))[0]) == 2


def test_round_capacity():
    assert round_capacity(0) == LANE
    assert round_capacity(1) == LANE
    assert round_capacity(LANE) == LANE
    assert round_capacity(LANE + 1) == 2 * LANE
