"""Sharding rules: divisibility-aware resolution, ZeRO axes, batch specs."""
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (Axes, DEFAULT_RULES, FSDP_RULES,
                                        abstract_mesh, logical_to_physical,
                                        constrain)
from repro.train.optimizer import zero_axes


def mk_mesh(shape, names):
    # abstract mesh: resolution logic only needs axis sizes, no devices
    return abstract_mesh(shape, names)


def test_divisibility_drop():
    mesh = mk_mesh((16, 16), ("data", "model"))
    # kv_heads=4 does not divide 16 -> replicated
    spec = logical_to_physical(Axes("batch", "seq", "kv_heads", "head_dim"),
                               mesh, DEFAULT_RULES, (256, 128, 4, 64))
    assert spec == P("data", None, None, None)
    # kv_heads=16 divides -> sharded
    spec = logical_to_physical(Axes("batch", "seq", "kv_heads", "head_dim"),
                               mesh, DEFAULT_RULES, (256, 128, 16, 64))
    assert spec == P("data", None, "model", None)


def test_axis_used_once():
    mesh = mk_mesh((16, 16), ("data", "model"))
    spec = logical_to_physical(Axes("vocab", "d_ff"), mesh, DEFAULT_RULES,
                               (160, 160))
    # both want 'model'; only the first gets it
    assert spec == P("model", None)


def test_multi_pod_batch():
    mesh = mk_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = logical_to_physical(Axes("batch", "seq", "embed"), mesh,
                               DEFAULT_RULES, (256, 4096, 1024))
    assert spec == P(("pod", "data"), None, None)
    spec_f = logical_to_physical(Axes("embed", "d_ff"), mesh, FSDP_RULES,
                                 (1024, 4096))
    assert spec_f == P("data", "model")


def test_zero_axes_picks_replicated_dim():
    mesh = mk_mesh((16, 16), ("data", "model"))
    za = zero_axes(Axes("embed", "d_ff"), (1024, 4096), mesh, DEFAULT_RULES)
    # d_ff takes model; embed (replicated, divisible) gets the opt axes
    assert za == ("opt", "d_ff")
    spec = logical_to_physical(za, mesh, DEFAULT_RULES, (1024, 4096))
    assert spec == P("data", "model")


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "embed") is x
