"""S_VINTER applications (paper §VI-I) vs dense oracles."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.sparse import from_dense, random_csf, spmsp_matmul, ttv


def _rand_sparse_dense(m, n, density, seed):
    rng = np.random.default_rng(seed)
    return np.where(rng.random((m, n)) < density,
                    rng.normal(size=(m, n)), 0.0).astype(np.float32)


@settings(max_examples=8, deadline=None)
@given(st.floats(0.02, 0.4), st.integers(0, 100))
def test_spmm_matches_dense(density, seed):
    a_d = _rand_sparse_dense(40, 30, density, seed)
    b_d = _rand_sparse_dense(30, 25, density, seed + 1)
    c = spmsp_matmul(from_dense(a_d), from_dense(b_d, "csc"), backend="xla")
    np.testing.assert_allclose(c, a_d @ b_d, atol=1e-4)


def test_spmm_pallas_backend():
    a_d = _rand_sparse_dense(30, 20, 0.15, 3)
    b_d = _rand_sparse_dense(20, 18, 0.15, 4)
    c = spmsp_matmul(from_dense(a_d), from_dense(b_d, "csc"),
                     row_block=8, col_block=8, backend="pallas")
    np.testing.assert_allclose(c, a_d @ b_d, atol=1e-4)


@pytest.mark.parametrize("sparse_vec", [False, True])
def test_ttv_matches_dense(sparse_vec):
    t = random_csf((12, 9, 30), 250, seed=6)
    rng = np.random.default_rng(8)
    if sparse_vec:
        keys = np.sort(rng.choice(30, size=11, replace=False)).astype(np.int32)
        vals = rng.normal(size=11).astype(np.float32)
        vec = np.zeros(30, np.float32)
        vec[keys] = vals
    else:
        keys = np.arange(30, dtype=np.int32)
        vals = rng.normal(size=30).astype(np.float32)
        vec = vals
    ii, jj, vv = ttv(t, keys, vals, backend="xla")
    dense = np.zeros((12, 9, 30), np.float32)
    for f in range(t.num_fibers):
        lo, hi = t.fiber_ptr[f], t.fiber_ptr[f + 1]
        dense[t.i_ids[f], t.j_ids[f], t.k_ids[lo:hi]] = t.vals[lo:hi]
    want = dense @ vec
    got = np.zeros((12, 9), np.float32)
    got[ii, jj] = vv
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# pallas <-> XLA backend parity through the shared ops.xvinter entry
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.floats(0.05, 0.3), st.integers(0, 50))
def test_spmm_backend_parity(density, seed):
    a_d = _rand_sparse_dense(35, 25, density, seed)
    b_d = _rand_sparse_dense(25, 20, density, seed + 1)
    a, b = from_dense(a_d), from_dense(b_d, "csc")
    cx = spmsp_matmul(a, b, backend="xla")
    cp = spmsp_matmul(a, b, row_block=8, col_block=8, backend="pallas")
    np.testing.assert_allclose(cp, cx, rtol=1e-5, atol=1e-6)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 50))
def test_ttv_backend_parity(seed):
    t = random_csf((10, 8, 24), 160, seed=seed)
    rng = np.random.default_rng(seed + 1)
    keys = np.sort(rng.choice(24, size=9, replace=False)).astype(np.int32)
    vals = rng.normal(size=9).astype(np.float32)
    outs = {}
    for backend in ("xla", "pallas"):
        ii, jj, vv = ttv(t, keys, vals, fiber_block=64, backend=backend)
        dense = np.zeros((10, 8), np.float32)
        dense[np.asarray(ii), np.asarray(jj)] = np.asarray(vv)
        outs[backend] = dense
    np.testing.assert_allclose(outs["pallas"], outs["xla"],
                               rtol=1e-5, atol=1e-6)
