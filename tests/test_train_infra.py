"""Optimizer, checkpoint round-trips, fault-tolerance control plane, data
pipeline determinism, compression numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault_tolerance import (HeartbeatMonitor, StepGuard,
                                               balanced_vertex_partition,
                                               elastic_remesh)
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticLMData
from repro.train.optimizer import (OptConfig, _dequantize, _quantize,
                                   adamw_init, adamw_update)


def test_adamw_decreases_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg.lr, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(37, 300)).astype(np.float32))
    q, s = _quantize(x)
    back = _dequantize(q, s, x.shape)
    err = np.abs(np.asarray(back) - np.asarray(x))
    blockmax = np.abs(np.asarray(x)).max()
    assert err.max() <= blockmax / 127.0 + 1e-6


def test_adamw8bit_tracks_fp32():
    cfgs = [OptConfig(lr=0.05, weight_decay=0.0, state_bits=b)
            for b in (32, 8)]
    p0 = {"w": jnp.asarray(np.random.default_rng(1)
                           .normal(size=(64,)).astype(np.float32))}
    outs = []
    for cfg in cfgs:
        p = dict(p0)
        st = adamw_init(p, cfg)
        for _ in range(50):
            g = {"w": 2 * p["w"]}
            p, st, _ = adamw_update(g, st, p, cfg.lr, cfg)
        outs.append(np.asarray(p["w"]))
    assert np.abs(outs[0] - outs[1]).max() < 0.05


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_last=2)
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "b": {"c": jnp.ones((4,), jnp.int32)}}
    cm.save(3, params, data_state={"step": 3, "seed": 0})
    cm.save(7, params, data_state={"step": 7, "seed": 0})
    cm.save(11, params, data_state={"step": 11, "seed": 0})
    assert cm.steps() == [7, 11]          # pruned to keep_last
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        params)
    got, _, manifest = cm.restore(None, like)
    assert manifest["step"] == 11
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(params["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(params["b"]["c"]))


def test_step_guard():
    g = StepGuard(max_consecutive=2)
    assert g.ok({"loss": 1.0, "gnorm": 1.0})
    assert not g.ok({"loss": float("nan"), "gnorm": 1.0})
    assert not g.ok({"loss": 1.0, "gnorm": float("inf")})
    assert g.should_restore
    assert g.ok({"loss": 1.0, "gnorm": 1.0})
    assert not g.should_restore


def test_heartbeat_and_stragglers():
    hb = HeartbeatMonitor(num_workers=4, timeout=10.0)
    now = 1000.0
    for w in range(4):
        hb.beat(w, step_time=1.0 if w != 2 else 5.0, now=now)
    assert hb.dead(now=now + 5) == []
    hb.beat(0, now=now + 20)
    dead = hb.dead(now=now + 20)
    assert set(dead) == {1, 2, 3}
    assert hb.stragglers() == [2]


def test_elastic_remesh():
    shape, names, dropped = elastic_remesh(32, 16, model_parallel=16)
    assert shape == (32, 16) and dropped == 0
    shape, names, dropped = elastic_remesh(23, 16, model_parallel=16)
    assert shape == (16, 16) and dropped == (23 * 16 - 256)
    with pytest.raises(RuntimeError):
        elastic_remesh(0, 8)


def test_balanced_partition():
    deg = np.random.default_rng(3).integers(1, 100, size=500)
    assign = balanced_vertex_partition(deg, 8)
    cost = deg.astype(float) ** 2
    loads = np.bincount(assign, weights=cost, minlength=8)
    assert loads.max() / loads.mean() < 1.15


def test_data_pipeline_deterministic():
    d1 = SyntheticLMData(vocab_size=97, seq_len=16, global_batch=4, seed=5)
    d2 = SyntheticLMData(vocab_size=97, seq_len=16, global_batch=4, seed=5)
    b1, b2 = d1.batch_at(42), d2.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # restore path
    d2.restore({"step": 9, "seed": 5})
    assert d2.step == 9
    # bigram structure is learnable: targets mostly follow the affine map
    t, y = b1["tokens"], b1["targets"]
    match = ((t * 31 + 17) % 97 == y).mean()
    assert match > 0.8


def test_compressed_mean_single_device():
    """Wire-format exactness: int8 psum on a 1-device mesh == quantised id."""
    from repro.distributed.compression import compressed_mean
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import make_mesh_compat
    mesh = make_mesh_compat((1,), ("pod",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,))
                    .astype(np.float32))

    def body(x):
        return compressed_mean(x, "pod")[0]

    got = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                    check_rep=False)(x)
    err = np.abs(np.asarray(got) - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127 + 1e-6
