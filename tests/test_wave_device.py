"""Device-resident wavefront pipeline vs the host compaction oracle.

The fast path (WaveRunner + ops.xinter_compact) must reproduce the host
``compact`` oracle item-for-item: same work-item order (np.nonzero row-major),
same extension vertices, same prefix rows, same final counts — across random
CSR graphs, sentinel-padded tails and bound=0 padding items.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.batch import batch_compact_items, batch_inter
from repro.core.stream import SENTINEL, round_capacity
from repro.graph import build_csr
from repro.graph.generators import clique_planted, erdos_renyi, powerlaw_cluster
from repro.kernels.ops import xinter_compact
from repro.mining import reference
from repro.mining.engine import WaveRunner, compact

RNG = np.random.default_rng(11)

GRAPHS = {
    "er": build_csr(erdos_renyi(140, 900, seed=13), 140),
    "plc": build_csr(powerlaw_cluster(110, 5, seed=7), 110),
    "cliq": build_csr(clique_planted(80, 240, (7, 6, 5), seed=9), 80),
}


def _random_rows(batch, cap, hi=3000, rng=RNG):
    """Sorted sentinel-padded rows + survivor counts, incl. empty rows."""
    rows = np.full((batch, cap), SENTINEL, np.int32)
    counts = np.zeros((batch,), np.int32)
    for i in range(batch):
        if rng.random() < 0.15:
            continue                      # bound=0 / dead padding item
        n = int(rng.integers(1, cap + 1))
        rows[i, :n] = np.sort(rng.choice(hi, size=n, replace=False))
        counts[i] = n
    return rows, counts


@pytest.mark.parametrize("batch,cap", [(8, 128), (33, 256), (128, 128)])
def test_batch_compact_items_matches_host_oracle(batch, cap):
    rows, counts = _random_rows(batch, cap)
    src, verts, total, maxc = batch_compact_items(
        jnp.asarray(rows), jnp.asarray(counts), batch * cap)
    total = int(total)
    col = np.arange(cap)
    ii, jj = np.nonzero(col[None, :] < counts[:, None])
    assert total == len(ii)
    assert int(maxc) == int(counts.max())
    np.testing.assert_array_equal(np.asarray(src)[:total], ii)
    np.testing.assert_array_equal(np.asarray(verts)[:total], rows[ii, jj])
    # padding items are bound-0: they must contribute nothing downstream
    assert np.all(np.asarray(verts)[total:] == 0)
    assert np.all(np.asarray(src)[total:] == 0)


def test_batch_compact_items_chunk_rounded_buffer():
    rows, counts = _random_rows(16, 128)
    out_items = 16 * 128 + 512            # buffer larger than B*cap
    src, verts, total, _ = batch_compact_items(
        jnp.asarray(rows), jnp.asarray(counts), out_items)
    assert src.shape == (out_items,) and verts.shape == (out_items,)
    assert np.all(np.asarray(verts)[int(total):] == 0)


def test_batch_compact_items_all_dead():
    rows = np.full((12, 128), SENTINEL, np.int32)
    counts = np.zeros((12,), np.int32)
    src, verts, total, maxc = batch_compact_items(
        jnp.asarray(rows), jnp.asarray(counts), 12 * 128)
    assert int(total) == 0 and int(maxc) == 0
    assert np.all(np.asarray(verts) == 0)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_xinter_compact_matches_inter_plus_host_compact(backend):
    a_rows, _ = _random_rows(24, 256, hi=600)
    b_rows, _ = _random_rows(24, 384, hi=600)
    bounds = RNG.integers(0, 600, 24).astype(np.int32)
    a, b = jnp.asarray(a_rows), jnp.asarray(b_rows)
    rows, counts, src, verts, total, maxc = xinter_compact(
        a, b, jnp.asarray(bounds), backend=backend)
    o_rows, o_counts = batch_inter(a, b, jnp.asarray(bounds))
    np.testing.assert_array_equal(np.asarray(rows),
                                  np.asarray(o_rows)[:, : rows.shape[1]])
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(o_counts))
    wave = compact(np.asarray(o_rows), np.asarray(o_counts))
    total = int(total)
    if wave is None:
        assert total == 0
        return
    np.testing.assert_array_equal(np.asarray(verts)[:total], wave.verts)
    cap2 = round_capacity(int(maxc))
    got_rows = np.asarray(rows)[np.asarray(src)[:total], :cap2]
    np.testing.assert_array_equal(got_rows, wave.rows)


def _trace_of(g, k, device_compact, chunk=None):
    runner = WaveRunner(g, chunk=chunk, device_compact=device_compact,
                        record=True)
    count = runner.clique(k)
    return count, runner.trace, runner.stats


@pytest.mark.parametrize("name", list(GRAPHS))
@pytest.mark.parametrize("k", [4, 5])
def test_clique_waves_bit_identical_device_vs_host(name, k):
    g = GRAPHS[name]
    want = reference.clique_count(g, k)
    c_dev, t_dev, s_dev = _trace_of(g, k, device_compact=True)
    c_host, t_host, s_host = _trace_of(g, k, device_compact=False)
    assert c_dev == c_host == want
    assert s_dev["device_compactions"] > 0 and s_dev["host_compactions"] == 0
    assert s_host["host_compactions"] > 0 and s_host["device_compactions"] == 0
    assert len(t_dev) == len(t_host)
    for (lv_d, rows_d, verts_d), (lv_h, rows_h, verts_h) in zip(t_dev, t_host):
        assert lv_d == lv_h
        np.testing.assert_array_equal(verts_d, verts_h)
        np.testing.assert_array_equal(rows_d, rows_h)


@pytest.mark.parametrize("name", list(GRAPHS))
def test_clique_waves_identical_with_tiny_chunks(name):
    """Small chunks force multi-chunk waves + chunk-rounded item buffers."""
    g = GRAPHS[name]
    c_dev, t_dev, _ = _trace_of(g, 4, device_compact=True, chunk=128)
    c_host, t_host, _ = _trace_of(g, 4, device_compact=False, chunk=128)
    assert c_dev == c_host == reference.clique_count(g, 4)
    assert len(t_dev) == len(t_host)
    for (lv_d, rows_d, verts_d), (lv_h, rows_h, verts_h) in zip(t_dev, t_host):
        assert lv_d == lv_h
        np.testing.assert_array_equal(verts_d, verts_h)
        np.testing.assert_array_equal(rows_d, rows_h)


def test_all_seven_apps_agree_with_reference():
    """The seven mining apps on the device-resident runner vs reference."""
    from repro.mining.apps import shared_session
    from repro.mining.plan import clique_pattern
    g = GRAPHS["er"]
    m = shared_session(g)
    assert m.count("triangle") == reference.triangle_count(g)
    assert m.count("triangle-nested") == reference.triangle_count(g)
    deg = np.asarray(g.degrees, dtype=np.int64)
    assert int((deg * (deg - 1) // 2).sum()) == reference.three_chain_count(g)
    assert (m.count("three-chain")
            == reference.three_chain_count(g, induced=True))
    assert m.count("tailed-triangle") == reference.tailed_triangle_count(g)
    t, chain = m.count_many(["triangle", "three-chain"])
    assert {"triangle": t, "chain": chain} == reference.motif3(g)
    for k in (4, 5):
        assert m.count(clique_pattern(k)) == reference.clique_count(g, k)
        assert (shared_session(g, device_compact=False)
                .count(clique_pattern(k)) == reference.clique_count(g, k))


def test_executable_cache_reuses_across_levels_and_graphs():
    g = GRAPHS["cliq"]
    runner = WaveRunner(g)
    runner.clique(5)
    first = dict(runner.stats)
    assert first["exec_misses"] > 0
    runner2 = WaveRunner(g)
    runner2._exec = runner._exec          # shared cache, same shapes
    runner2.stats["exec_misses"] = 0
    runner2.clique(5)
    assert runner2.stats["exec_misses"] == 0
    assert runner2.stats["exec_hits"] > 0


def test_exec_misses_equal_unique_shapes():
    """One trace per (kind, shape) key — degree buckets never re-trace."""
    g = GRAPHS["plc"]
    runner = WaveRunner(g, device_compact=True)
    runner.clique(5)
    runner.count_edges()
    assert runner.stats["exec_misses"] == len(runner._exec)
