"""End-to-end system behaviour: the launch drivers run as real processes
(train with crash/restart, mine with baseline agreement, serve)."""
import os
import subprocess
import sys


ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def run(args, timeout=600):
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=ENV, timeout=timeout, cwd=ROOT)
    return out


def test_train_driver_runs_and_loss_finite():
    out = run(["repro.launch.train", "--arch", "qwen3-0.6b", "--steps", "5",
               "--batch", "2", "--seq", "16"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[train] done" in out.stdout
    assert "nan" not in out.stdout.lower()


def test_train_crash_restart_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    out1 = run(["repro.launch.train", "--arch", "qwen3-0.6b", "--steps", "10",
                "--batch", "2", "--seq", "16", "--ckpt", ck,
                "--ckpt-every", "4", "--inject-failure", "5"])
    assert out1.returncode == 17               # injected crash
    out2 = run(["repro.launch.train", "--arch", "qwen3-0.6b", "--steps", "10",
                "--batch", "2", "--seq", "16", "--ckpt", ck,
                "--ckpt-every", "4"])
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "restored step" in out2.stdout
    # resumed past the crash point, not from zero
    assert "[train] step 0 " not in out2.stdout


def test_mine_driver_engine_equals_baseline():
    out = run(["repro.launch.mine", "--app", "T", "--dataset", "citeseer",
               "--baseline"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "speedup" in out.stdout


def test_serve_driver():
    out = run(["repro.launch.serve", "--arch", "rwkv6-3b", "--batch", "2",
               "--tokens", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout


def test_serve_driver_mining_session():
    """One resident Miner serving the app mix: the steady-state round must
    execute from cache alone (the driver asserts 0 retraces itself)."""
    out = run(["repro.launch.serve", "--mine", "citeseer", "--rounds", "2"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "queries/s" in out.stdout
    assert "0 retraces" in out.stdout
