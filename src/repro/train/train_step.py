"""jit-compiled train / serve steps with full sharding annotations.

``make_train_step`` builds the pjit'd update for (model, optimizer, mesh):
in/out shardings come from the logical-axes trees; params and optimizer
state are donated; gradients may optionally go through the int8 cross-pod
compressed all-reduce (distributed/compression.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import (DEFAULT_RULES, ShardingRules,
                                        mesh_context,
                                        named_sharding, shard_params_tree,
                                        Axes)
from .optimizer import OptConfig, adamw_update, opt_state_shardings


def lr_schedule(step, base_lr: float, warmup: int = 100,
                total: int = 10_000, min_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def make_train_step(model, mesh, rules: ShardingRules = DEFAULT_RULES,
                    opt_cfg: OptConfig = OptConfig(),
                    total_steps: int = 10_000,
                    compress_pods: bool = False):
    """Returns (train_step, shardings) — train_step(params, opt_state, batch,
    step) -> (params, opt_state, metrics)."""

    def step_fn(params, opt_state, batch, step):
        with mesh_context(mesh, rules):
            def loss_fn(p):
                return model.loss(p, batch)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            if compress_pods and "pod" in mesh.axis_names:
                from repro.distributed.compression import tree_compressed_mean
                grads = tree_compressed_mean(grads, mesh, "pod")
            lr = lr_schedule(step, opt_cfg.lr, total=total_steps)
            new_params, new_state, gnorm = adamw_update(
                grads, opt_state, params, lr, opt_cfg)
            metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
            return new_params, new_state, metrics

    return step_fn


def shardings_for(model, mesh, rules: ShardingRules = DEFAULT_RULES,
                  opt_cfg: OptConfig = OptConfig()):
    """(param_shardings, opt_shardings, param_shapes, axes) for a model."""
    from repro.models.transformer import shapes_and_axes
    shapes, axes = shapes_and_axes(model)
    p_shard = shard_params_tree(shapes, axes, mesh, rules)
    o_shard = opt_state_shardings(shapes, axes, mesh, rules, opt_cfg)
    return p_shard, o_shard, shapes, axes


def batch_shardings(batch_spec: dict, mesh, rules=DEFAULT_RULES):
    """Shard every batch input over ('pod','data') on dim 0 — except
    M-RoPE positions whose batch dim is dim 1."""
    out = {}
    for k, v in batch_spec.items():
        if k == "mrope_positions":
            out[k] = named_sharding(Axes(None, "batch", None), mesh, rules,
                                    tuple(v.shape))
        else:
            names = ("batch",) + (None,) * (len(v.shape) - 1)
            out[k] = named_sharding(Axes(*names), mesh, rules, tuple(v.shape))
    return out


def jit_train_step(model, mesh, rules=DEFAULT_RULES, opt_cfg=OptConfig(),
                   batch_spec: dict | None = None, total_steps: int = 10_000,
                   compress_pods: bool = False):
    """Fully-specified pjit train step (donated params/state)."""
    p_shard, o_shard, shapes, axes = shardings_for(model, mesh, rules, opt_cfg)
    fn = make_train_step(model, mesh, rules, opt_cfg, total_steps,
                         compress_pods)
    b_shard = batch_shardings(batch_spec, mesh, rules) if batch_spec else None
    rep = named_sharding(Axes(), mesh, rules)
    metric_shard = {"loss": rep, "gnorm": rep, "lr": rep}
    return jax.jit(
        fn,
        in_shardings=(p_shard, o_shard, b_shard, rep),
        out_shardings=(p_shard, o_shard, metric_shard),
        donate_argnums=(0, 1),
    ), (p_shard, o_shard, shapes, axes)
