"""Step-granular checkpointing with elastic restore (re-mesh on load).

Layout:  <dir>/step_<N>/
            manifest.json        tree structure, shapes, dtypes, step,
                                 data-pipeline state, mesh it was saved from
            arrays.npz           one entry per flattened leaf

Writes are atomic (tmp dir + rename); ``keep_last`` old steps are pruned.
``restore(..., mesh=new_mesh)`` places every leaf with the shardings
resolved against the *new* mesh — this is the elastic shrink/grow path: a
checkpoint from 512 chips restores onto 256 (or 8, or 1) without format
changes, because leaves are stored unsharded (single-process container) and
resharding is a device_put. On a real multi-host fleet the same manifest
drives per-host shard files; the resolver logic is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np



def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists from jax 0.5; the tree_util
    # spelling works on every version this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [v for _, v in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state=None, data_state=None,
             extra: dict | None = None) -> str:
        tree = {"params": params}
        if opt_state is not None:
            tree["opt"] = opt_state
        keys, leaves, _ = _flatten_with_paths(tree)
        tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": keys,
            "time": time.time(),
            "has_opt": opt_state is not None,
            "data_state": data_state or {},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic publish
        self._prune()
        return final

    def _prune(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # --------------------------------------------------------------- restore
    def restore(self, step: int | None, params_like, opt_like=None,
                mesh=None, param_shardings=None, opt_shardings=None):
        """Load a checkpoint into the (possibly different) current mesh.

        params_like/opt_like provide the target tree structure; shardings
        (when given with a mesh) re-place every leaf — the elastic path.
        Returns (params, opt_state, manifest).
        """
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves = [data[f"a{i}"] for i in range(len(manifest["keys"]))]
        tree = {"params": params_like}
        if opt_like is not None:
            tree["opt"] = opt_like
        _, like_leaves, treedef = _flatten_with_paths(tree)
        assert len(like_leaves) == len(leaves), \
            f"checkpoint has {len(leaves)} leaves, target {len(like_leaves)}"
        shard_tree = None
        if mesh is not None and param_shardings is not None:
            shard_tree = {"params": param_shardings}
            if opt_like is not None:
                shard_tree["opt"] = opt_shardings
        if shard_tree is not None:
            flat_sh = jax.tree.leaves(
                shard_tree, is_leaf=lambda x: hasattr(x, "spec"))
            placed = [jax.device_put(a.astype(lk.dtype), s)
                      for a, lk, s in zip(leaves, like_leaves, flat_sh)]
        else:
            placed = [jax.numpy.asarray(a.astype(lk.dtype))
                      for a, lk in zip(leaves, like_leaves)]
        restored = jax.tree.unflatten(treedef, placed)
        params = restored["params"]
        opt = restored.get("opt")
        return params, opt, manifest
