from .optimizer import adamw_init, adamw_update, OptConfig
from .data import SyntheticLMData
from .train_step import make_train_step, lr_schedule
from .checkpoint import CheckpointManager

__all__ = ["adamw_init", "adamw_update", "OptConfig", "SyntheticLMData",
           "make_train_step", "lr_schedule", "CheckpointManager"]
