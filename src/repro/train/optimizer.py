"""AdamW with ZeRO-1 sharding and optional 8-bit state (block-quantised).

No optax in this container — implemented from scratch.

* ZeRO-1: the optimizer-state shardings are the parameter shardings with an
  extra ('data','pod') assignment on the first still-replicated, dividing
  dimension (``zero_shardings``). XLA then keeps m/v fully sharded and
  all-gathers nothing (the update is elementwise).

* 8-bit state (``state_bits=8``): m and v are stored as int8 with per-block
  float32 scales (block = last-dim groups of 128), dynamically dequantised
  inside the update. This is what lets the 398B jamba config hold
  master + m + v within 16 GB/chip on a single pod — see EXPERIMENTS.md
  §Dry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (Axes, ShardingRules, is_axes,
                                        logical_to_physical, named_sharding)

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_bits: int = 32          # 32 | 8
    master_weights: bool = False  # params ride bf16; fp32 master lives here
    #                               (halves FSDP all-gather bytes + weight
    #                               memory; §Perf hillclimb)


# ---------------------------------------------------------------------------
# 8-bit block quantisation
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array):
    """float -> (int8, scales). Blocks along the last dim (padded)."""
    shape = x.shape
    n = shape[-1] if shape else 1
    nb = max(1, -(-n // BLOCK))
    pad = nb * BLOCK - n
    xp = jnp.pad(x.reshape(-1, n), ((0, 0), (0, pad)))
    xb = xp.reshape(-1, nb, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1, nb * BLOCK)[:, :n].reshape(shape), \
        scale[..., 0].reshape(x.reshape(-1, n).shape[0], nb)


def _dequantize(q: jax.Array, scale: jax.Array, shape, floor: bool = False):
    """int8 blocks -> float. ``floor=True`` clamps magnitudes below half an
    ULP up to scale/2 — used for the sqrt-second-moment so a tiny v can
    never dequantise to 0 and explode the Adam step (the error direction is
    then always a *smaller* step, never a larger one)."""
    n = shape[-1] if shape else 1
    nb = scale.shape[-1]
    pad = nb * BLOCK - n
    qp = jnp.pad(q.reshape(-1, n).astype(jnp.float32), ((0, 0), (0, pad)))
    xb = qp.reshape(-1, nb, BLOCK)
    if floor:
        xb = jnp.maximum(jnp.abs(xb), 0.5)
    x = xb * scale[..., None]
    return x.reshape(-1, nb * BLOCK)[:, :n].reshape(shape)


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------

def adamw_init(params, cfg: OptConfig):
    def leaf(p):
        if cfg.state_bits == 8:
            q, s = _quantize(jnp.zeros_like(p, jnp.float32))
            out = {"m_q": q, "m_s": s, "v_q": q, "v_s": s}
        else:
            out = {"m": jnp.zeros_like(p, jnp.float32),
                   "v": jnp.zeros_like(p, jnp.float32)}
        if cfg.master_weights:
            out["master"] = p.astype(jnp.float32)
        return out
    return {"mu": jax.tree.map(leaf, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, cfg: OptConfig):
    count = state["count"] + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(g, mu, p):
        g = g.astype(jnp.float32) * clip
        if cfg.state_bits == 8:
            m = _dequantize(mu["m_q"], mu["m_s"], g.shape)
            # v rides in sqrt-space: quadratic dynamic-range compression +
            # floored dequant => Adam denominator can never hit zero
            v = jnp.square(_dequantize(mu["v_q"], mu["v_s"], g.shape,
                                       floor=True))
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        base = mu["master"] if cfg.master_weights else p.astype(jnp.float32)
        new_master = base - lr * (upd + cfg.weight_decay * base)
        new_p = new_master.astype(p.dtype)
        if cfg.state_bits == 8:
            mq, ms = _quantize(m)
            vq, vs = _quantize(jnp.sqrt(v))
            out = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        else:
            out = {"m": m, "v": v}
        if cfg.master_weights:
            out["master"] = new_master
        return new_p, out

    flat_g, tdef = jax.tree.flatten(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_p = tdef.flatten_up_to(params)
    out = [leaf(g, mu, p) for g, mu, p in zip(flat_g, flat_mu, flat_p)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}, gnorm


# ---------------------------------------------------------------------------
# ZeRO-1 sharding for the state
# ---------------------------------------------------------------------------

def zero_axes(axes: Axes, shape, mesh, rules: ShardingRules) -> Axes:
    """Param logical axes -> state logical axes with ZeRO 'opt' on the first
    dim that resolves to replicated and divides the opt axes product."""
    spec = logical_to_physical(axes, mesh, rules, shape)
    sizes = dict(mesh.shape)
    opt_axes = rules.get("opt") or ()
    opt_size = 1
    for a in opt_axes:
        opt_size *= sizes.get(a, 1)
    out = list(axes)
    for d, (name, resolved) in enumerate(zip(axes, tuple(spec) + (None,) * 9)):
        if resolved is None and shape[d] % max(opt_size, 1) == 0 and opt_size > 1:
            out[d] = "opt"
            break
    return Axes(*out)


def opt_state_shardings(params_shapes, param_axes, mesh, rules: ShardingRules,
                        cfg: OptConfig):
    """NamedSharding tree matching adamw_init's structure."""
    flat_s, _ = jax.tree.flatten(params_shapes)
    flat_a = jax.tree.flatten(param_axes, is_leaf=is_axes)[0]

    def one(sds, axes):
        zaxes = zero_axes(axes, tuple(sds.shape), mesh, rules)
        base = named_sharding(zaxes, mesh, rules, tuple(sds.shape))
        if cfg.state_bits == 8:
            # scales are 2D (rows, blocks): shard replicated (small)
            rep = named_sharding(Axes(None, None), mesh, rules)
            out = {"m_q": base, "m_s": rep, "v_q": base, "v_s": rep}
        else:
            out = {"m": base, "v": base}
        if cfg.master_weights:
            out["master"] = base
        return out

    leaves = [one(s, a) for s, a in zip(flat_s, flat_a)]
    tdef = jax.tree.structure(params_shapes)
    rep0 = named_sharding(Axes(), mesh, rules)
    return {"mu": jax.tree.unflatten(tdef, leaves), "count": rep0}
