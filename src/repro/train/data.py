"""Deterministic synthetic data pipeline (no external corpora offline).

Checkpointable by construction: every batch is a pure function of
(seed, step), so the pipeline "state" is a single integer that rides in the
checkpoint manifest. Restart/elastic-reshard resumes bit-exactly, and any
host can generate any shard (straggler work reassignment is trivial).

The token stream has learnable structure (noisy affine bigram chain) so the
end-to-end example's loss demonstrably falls below the unigram entropy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    step: int = 0                      # pipeline state (checkpointed)

    def batch_at(self, step: int) -> dict:
        """Pure: batch for a given step (host numpy, device-put by caller)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        a = 31 % V or 1
        c = 17 % V
        x = np.empty((B, S + 1), dtype=np.int64)
        x[:, 0] = rng.integers(0, V, size=B)
        noise_mask = rng.random((B, S)) < self.noise
        noise_tok = rng.integers(0, V, size=(B, S))
        for t in range(1, S + 1):
            nxt = (x[:, t - 1] * a + c) % V
            x[:, t] = np.where(noise_mask[:, t - 1], noise_tok[:, t - 1], nxt)
        return {"tokens": x[:, :-1].astype(np.int32),
                "targets": x[:, 1:].astype(np.int32)}

    def __iter__(self):
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])


def input_spec_batch(vocab_size: int, seq_len: int, global_batch: int,
                     extras: dict | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    spec = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "targets": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if extras:
        spec.update(extras)
    return spec
