"""Pattern-plan compiler: declarative patterns -> stream-op level programs.

This module is the software twin of the paper's nested-intersection
translator (§IV-F). There, S_NESTINTER is decoded into a *translation
buffer* holding a µop sequence — one bounded stream instruction per
candidate extension, each naming its operand streams (R1/R2), its bound
register (R3) and whether it counts or materialises. Here a ``Pattern``
(adjacency matrix + AutoMine-style symmetry-breaking restrictions) is
compiled once, on the host, into a ``WavePlan`` whose per-level ``LevelOp``
records play exactly that role for the wavefront engine
(``mining.engine.WaveRunner.run``):

  paper §IV-F translation buffer          ``LevelOp`` field
  --------------------------------        ---------------------------------
  µop opcode (S_INTER / S_SUB)            ``inter`` / ``sub`` column lists
  R1 operand (running stream)             ``use_carry`` / ``base`` column
  R2 operand (neighbor stream S_READ)     each column in ``inter``/``sub``
  R3 bound register (early termination)   ``ub`` (+ ``lb``, beyond-paper)
  count vs materialise disposition        ``kind``: count / expand / emit
  closed-form retire (stream len reuse)   ``tail`` degree-factor multiplier

A ``LevelOp`` for level ``l`` selects candidates for pattern vertex v_l out
of one *base* stream — either the parent level's materialised survivor
stream (``use_carry``, the S-Cache-resident operand reuse of §IV-D) or a
freshly gathered neighbor list N(v_base) — by AND-ing membership masks:

  keep = base∈N(v_j) ∀j∈inter  ∧  base∉N(v_j) ∀j∈sub
         ∧ base < min(v_u: u∈ub) ∧ base > max(v_w: w∈lb) ∧ base ≠ v_e ∀e∈exclude

``sub`` columns realise *induced* (non-edge) constraints; ``ub``/``lb``
realise the declared symmetry-breaking restrictions; ``exclude`` keeps the
embedding injective where neither adjacency nor an order constraint already
implies it.  The compiler additionally performs:

  * **carry reuse** — level l starts from the parent's survivor stream when
    every constraint that defined the parent stream is implied by level l's
    own constraint set (clique chains hit this on every level, which is how
    the generic interpreter reproduces the hand-coded clique schedule
    executable-for-executable);
  * **tail folding** — a final level whose candidate set is one neighbor
    list minus statically-known members collapses to a closed-form
    ``deg(v_b) - c`` multiplier fused into the previous level's count (the
    paper's stream-length reuse; tailed-triangle's ``deg(v1) - 2``);
  * **liveness** — ``out_cols`` / ``gather_refs`` record which prefix
    columns deeper levels still reference, so the engine forwards (and
    meta-sizes) only those.

Beyond the ordered ``Pattern``, this module also models the *unordered*
shape a user actually asks for: a ``Motif`` is adjacency (+ inducedness)
only — no matching order, no hand-written symmetry-breaking restrictions.
``matching_orders`` enumerates every connected matching order of a motif
and derives each order's restrictions automatically from the automorphism
group (``auto_restrictions``: keep exactly the lexicographically largest
matched sequence of every embedding orbit, so each subgraph is counted
once and ``div`` is always 1). The batch-aware choice *between* those
orders — AutoMine's compilation loop, maximising shared canonical prefixes
across a pattern set — lives in ``mining.forest.schedule_patterns``; the
``FOUR_MOTIFS`` dict (and the per-motif names ``DIAMOND``/``CYCLE4``/
``PAW_INDUCED``/``PATH4``/``STAR4``) are resolved lazily from the
``FOUR_MOTIF_SHAPES`` adjacency-only definitions through that search, so
no 4-motif schedule is hand-ordered anywhere.

Nothing in this module touches a device: a ``WavePlan`` is a pure host
datum, and compiling the same ``Pattern`` twice yields structurally equal
(hashable) ops, so ``WaveRunner``'s executable cache keys on them directly.
"""
from __future__ import annotations

import dataclasses
import itertools

# FOUR_MOTIFS / DIAMOND / CYCLE4 / PAW_INDUCED / PATH4 / STAR4 are module
# attributes too, resolved lazily via __getattr__ (schedule search).
__all__ = [
    "Pattern", "LevelOp", "WavePlan", "compile_pattern", "pattern",
    "clique_pattern", "Motif", "motif", "auto_restrictions",
    "matching_orders", "resolve_query", "TRIANGLE", "TRIANGLE_NESTED",
    "THREE_CHAIN_INDUCED", "TAILED_TRIANGLE", "FOUR_MOTIF_SHAPES",
]


# ---------------------------------------------------------------------------
# declarative pattern model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A vertex pattern in matching order (AutoMine-style schedule).

    ``adj``          k×k symmetric boolean adjacency (no self loops); index
                     i is the i-th matched vertex.
    ``restrictions`` symmetry-breaking constraints ``(i, j)`` ≡ v_i < v_j;
                     must be consistent with some total order (acyclic) and
                     any constraint between vertices 0 and 1 must be
                     ``(1, 0)`` (the engine's half-edge feed yields v1 < v0).
    ``induced``      non-edges of ``adj`` become S_SUB constraints.
    ``div``          residual automorphism count the raw total over-counts
                     by when the restrictions break symmetry only partially
                     (the Fig. 4a nested-triangle stream divides by 6).
    """

    name: str
    adj: tuple[tuple[bool, ...], ...]
    restrictions: tuple[tuple[int, int], ...] = ()
    induced: bool = False
    div: int = 1

    @property
    def k(self) -> int:
        return len(self.adj)


def pattern(name: str, k: int, edges, restrictions=(), induced: bool = False,
            div: int = 1) -> Pattern:
    """Build a validated ``Pattern`` from an edge list over vertices 0..k-1."""
    adj = [[False] * k for _ in range(k)]
    for i, j in edges:
        if i == j:
            raise ValueError(f"{name}: self loop ({i},{j})")
        adj[i][j] = adj[j][i] = True
    p = Pattern(name=name, adj=tuple(tuple(r) for r in adj),
                restrictions=tuple((int(i), int(j)) for i, j in restrictions),
                induced=induced, div=div)
    _validate(p)
    return p


def clique_pattern(k: int) -> Pattern:
    """k-clique: complete adjacency, descending chain v_{i+1} < v_i."""
    return pattern(f"{k}-clique", k, itertools.combinations(range(k), 2),
                   restrictions=[(i + 1, i) for i in range(k - 1)])


# ---------------------------------------------------------------------------
# unordered motif shapes + automatic symmetry breaking
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Motif:
    """An unordered pattern *shape*: adjacency + inducedness, nothing else.

    A ``Motif`` is what a query names ("count paws") before any schedule
    decision is made: it carries no matching order and no hand-written
    symmetry-breaking restrictions. ``matching_orders`` lowers it to the
    candidate ``Pattern``s (one per structurally distinct matching order,
    restrictions derived from the automorphism group), and the forest
    scheduler picks between them per batch."""

    name: str
    adj: tuple[tuple[bool, ...], ...]
    induced: bool = False

    @property
    def k(self) -> int:
        return len(self.adj)


def motif(name: str, k: int, edges, induced: bool = False) -> Motif:
    """Build a validated ``Motif`` from an edge list over vertices 0..k-1."""
    adj = [[False] * k for _ in range(k)]
    for i, j in edges:
        if i == j:
            raise ValueError(f"{name}: self loop ({i},{j})")
        adj[i][j] = adj[j][i] = True
    return Motif(name=name, adj=tuple(tuple(r) for r in adj),
                 induced=induced)


def _automorphisms(adj) -> list[tuple[int, ...]]:
    """All adjacency-preserving vertex permutations (brute force; k <= 5
    for every mining pattern, so k! stays trivial)."""
    k = len(adj)
    return [perm for perm in itertools.permutations(range(k))
            if all(adj[i][j] == adj[perm[i]][perm[j]]
                   for i in range(k) for j in range(k))]


def auto_restrictions(adj) -> tuple[tuple[int, int], ...]:
    """Symmetry-breaking restrictions for a matching order, derived from
    the automorphism group.

    For each non-identity automorphism σ, let i be the first position σ
    moves; requiring v_{σ(i)} < v_i keeps exactly the lexicographically
    *largest* matched sequence of each embedding orbit (positions before i
    are fixed by σ, so the orbit comparison is decided at i). Every
    embedding is therefore counted exactly once — no residual ``div`` —
    and since σ(i) > i always, every restriction points at a lower level
    (acyclic, and any v0/v1 constraint is the half-edge feed's (1, 0)).
    Transitively implied restrictions are pruned."""
    k = len(adj)
    ident = tuple(range(k))
    restr = set()
    for sig in _automorphisms(adj):
        if sig == ident:
            continue
        i = min(p for p in range(k) if sig[p] != p)
        restr.add((sig[i], i))            # v_sig(i) < v_i, and sig(i) > i
    for e in sorted(restr):               # transitive reduction
        if e in _closure(k, restr - {e}):
            restr.discard(e)
    return tuple(sorted(restr))


def matching_orders(m: Motif) -> tuple[Pattern, ...]:
    """All structurally distinct matching orders of ``m`` as ``Pattern``s.

    Enumerates vertex permutations that yield a valid matching order (v0-v1
    an edge, every later vertex adjacent to an earlier one), attaches each
    order's ``auto_restrictions``, and dedupes by compiled canonical plan
    key — orders that perform identical work item-for-item collapse to one
    candidate (a k-clique has exactly one)."""
    k = len(m.adj)
    out: list[Pattern] = []
    seen: set[tuple] = set()
    for perm in itertools.permutations(range(k)):
        radj = tuple(tuple(m.adj[perm[a]][perm[b]] for b in range(k))
                     for a in range(k))
        if not radj[0][1]:
            continue
        if any(not any(radj[lvl][j] for j in range(lvl))
               for lvl in range(2, k)):
            continue
        p = Pattern(name=m.name, adj=radj,
                    restrictions=auto_restrictions(radj),
                    induced=m.induced, div=1)
        key = compile_pattern(p).canonical_key()
        if key in seen:
            continue
        seen.add(key)
        out.append(p)
    if not out:
        raise ValueError(f"{m.name}: no connected matching order")
    return tuple(out)


# ---------------------------------------------------------------------------
# compiled plan model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LevelOp:
    """One translation-buffer entry: how to extend prefixes to vertex ``level``.

    All column references are prefix indices < ``level``. Hashable by value:
    the engine's executable cache keys on (op, capacities, chunk).
    """

    level: int
    use_carry: bool               # base = parent's materialised survivors
    base: int                     # else base = N(v_base) (column in inter set)
    inter: tuple[int, ...]        # S_INTER refs beyond the base
    sub: tuple[int, ...]          # S_SUB refs (induced non-edges)
    ub: tuple[int, ...]           # candidate < min over these columns (R3)
    lb: tuple[int, ...]           # candidate > max over these columns
    exclude: tuple[int, ...]      # explicit injectivity: candidate != v_e
    kind: str                     # 'expand' | 'count' | 'emit'
    tail: tuple[int, int] | None  # (col, c): weight each count by deg(v_col)-c
    out_cols: tuple[int, ...]     # prefix columns forwarded to deeper levels
    gather_refs: tuple[int, ...]  # columns deeper levels gather rows for
    carry_out: bool               # next level starts from our survivors
    # SVPU value disposition (count leaves only; compile_pattern(...,
    # aggregate=...)). ``agg`` names the reduction over embedding values —
    # 'sum' | 'max' | 'min' — where an embedding's value is the product of
    # its pattern-edge weights. The leaf computes that product locally:
    # ``agg_scale_edges`` are the prefix-prefix pattern edges (both
    # endpoints < level, incl. the (0,1) feed edge) folded into a per-item
    # scale via CSR weight lookups; ``agg_cand_cols`` are candidate-adjacent
    # prefix columns no INTER ref of THIS op covers (carry-reuse hides
    # them), looked up per (item, slot). A count leaf has agg None and both
    # tuples empty — its LevelOp hash/eq is what it always was.
    agg: str | None = None
    agg_scale_edges: tuple[tuple[int, int], ...] = ()
    agg_cand_cols: tuple[int, ...] = ()
    # deferred per-item constraints, installed by the forest scheduler when a
    # shared ancestor was *relaxed* (its bound/injectivity surplus dropped so
    # several patterns could share one expand). Entries ('lt', i, j) ≡ require
    # v_i < v_j, ('ne', i, j) ≡ require v_i != v_j; i, j < level. An item
    # failing a residual contributes nothing: the engine folds residuals into
    # the per-row bound operand (bound := 0), so whole rows die inside the
    # kernels' tile schedule. compile_pattern never emits residuals — a
    # single-plan LevelOp always has residual == ().
    residual: tuple[tuple[str, int, int], ...] = ()

    def row_refs(self) -> tuple[int, ...]:
        """Columns whose neighbor rows this op gathers."""
        refs = (() if self.use_carry else (self.base,)) + self.inter + self.sub
        return tuple(sorted(set(refs)))

    def val_refs(self) -> tuple[int, ...]:
        """Columns whose *values* this op reads (gather starts, bounds, ...)."""
        refs = set(self.row_refs()) | set(self.ub) | set(self.lb) \
            | set(self.exclude)
        if self.tail is not None:
            refs.add(self.tail[0])
        for _, i, j in self.residual:
            refs.add(i)
            refs.add(j)
        for i, j in self.agg_scale_edges:
            refs.add(i)
            refs.add(j)
        refs |= set(self.agg_cand_cols)
        return tuple(sorted(refs))

    def stream_key(self) -> tuple:
        """What defines the *survivor stream* (not which items stay live):
        ops with equal stream keys materialise element-identical streams and
        can share one expand + compaction in a ``PlanForest``."""
        return (self.level, self.use_carry, self.base, self.inter, self.sub)

    def semantic_key(self) -> tuple:
        """Canonical form: every field with count/stream semantics, none of
        the liveness bookkeeping (``out_cols``/``gather_refs``/``carry_out``
        are schedule-dependent and recomputed by the forest builder). Two ops
        with equal semantic keys are interchangeable work."""
        return (self.level, self.use_carry, self.base, self.inter, self.sub,
                self.ub, self.lb, self.exclude, self.kind, self.tail,
                tuple(sorted(self.residual)), self.agg,
                self.agg_scale_edges, self.agg_cand_cols)


@dataclasses.dataclass(frozen=True)
class WavePlan:
    """A compiled stream program: level-1 feed spec + one op per level ≥ 2."""

    pattern: Pattern
    symmetric: bool               # half-edge feed (v1 < v0) vs directed
    ops: tuple[LevelOp, ...]
    div: int = 1

    @property
    def k(self) -> int:
        return self.pattern.k

    def canonical_key(self) -> tuple:
        """Stable plan hash: feed orientation + per-level semantic keys +
        retire division. Plans with equal canonical keys perform identical
        work item-for-item (whatever their ``Pattern`` was named) —
        ``apps.pattern_set_run`` memoises built ``PlanForest``s on the batch
        of these keys, and inside a forest such plans collapse onto fully
        shared paths."""
        return (self.symmetric, tuple(op.semantic_key() for op in self.ops),
                self.div)


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------


def _closure(k: int, restrictions) -> set[tuple[int, int]]:
    """Transitive closure of the strict order v_i < v_j; raises on cycles."""
    less = set(restrictions)
    changed = True
    while changed:
        changed = False
        for (a, b), (c, d) in itertools.product(tuple(less), tuple(less)):
            if b == c and (a, d) not in less:
                less.add((a, d))
                changed = True
    for i in range(k):
        if (i, i) in less:
            raise ValueError("restrictions contain a cycle")
    return less


def _validate(p: Pattern) -> None:
    k = p.k
    if k < 3:
        raise ValueError("patterns need k >= 3 (k=2 is the edge feed itself)")
    for i in range(k):
        if p.adj[i][i]:
            raise ValueError("self loop in pattern adjacency")
        for j in range(k):
            if p.adj[i][j] != p.adj[j][i]:
                raise ValueError("pattern adjacency must be symmetric")
    if not p.adj[0][1]:
        raise ValueError("matching order must start on an edge (v0, v1)")
    for lvl in range(2, k):
        if not any(p.adj[lvl][j] for j in range(lvl)):
            raise ValueError(
                f"{p.name}: vertex {lvl} not adjacent to any earlier vertex "
                "(matching order must keep the pattern connected)")
    for i, j in p.restrictions:
        if not (0 <= i < k and 0 <= j < k and i != j):
            raise ValueError(f"bad restriction ({i},{j})")
    if (0, 1) in p.restrictions:
        raise ValueError(
            "restriction between v0 and v1 must be (1, 0): the half-edge "
            "feed enumerates v1 < v0")


# compiled-plan memo: the schedule search and the session compile stage both
# revisit patterns; Pattern/WavePlan are immutable so sharing is free
_PLAN_CACHE: dict[tuple[Pattern, bool, str | None], WavePlan] = {}

AGG_OPS = ("sum", "max", "min")


def compile_pattern(p: Pattern, emit: bool = False,
                    aggregate: str | None = None) -> WavePlan:
    """Lower a ``Pattern`` to a ``WavePlan`` (§IV-F translation, on host).

    ``emit=True`` compiles an enumeration program: the final level
    materialises embeddings instead of counting (FSM's triangle feed).
    ``aggregate`` ('sum'/'max'/'min') compiles a *weighted* program: the
    count leaf becomes an SVPU aggregate leaf reducing per-embedding edge-
    weight products (tail folding is disabled — a folded closed-form count
    cannot carry per-edge values — and earlier ops forward whatever prefix
    columns the leaf's weight lookups reference). The plan's *stream* structure
    is otherwise identical to the unweighted plan's, which is what lets a
    forest fuse weighted and unweighted queries onto shared expands.
    Compilation is memoised (host-pure, immutable output).
    """
    if aggregate is not None and aggregate not in AGG_OPS:
        raise ValueError(f"unknown aggregate {aggregate!r}; use one of "
                         f"{AGG_OPS}")
    if aggregate is not None and emit:
        raise ValueError("aggregate plans are count programs (emit=False)")
    if aggregate is not None and p.div != 1:
        raise ValueError(
            f"{p.name}: aggregate needs fully symmetry-broken schedules "
            "(div == 1) — a residual automorphism factor divides counts but "
            "not max/min aggregates")
    cached = _PLAN_CACHE.get((p, emit, aggregate))
    if cached is not None:
        return cached
    _validate(p)
    k = p.k
    less = _closure(k, p.restrictions)
    # v1 < v0 (declared or implied) => the half-edge feed already enumerates
    # exactly the valid (v0, v1) pairs; otherwise feed all directed edges
    symmetric = (1, 0) in less
    # effective constraint sets per level (for carry implication checks)
    eff_i: dict[int, set] = {}
    eff_s: dict[int, set] = {}
    eff_ub: dict[int, set] = {}
    eff_lb: dict[int, set] = {}
    raw_ops: list[dict] = []
    for lvl in range(2, k):
        icols = {j for j in range(lvl) if p.adj[lvl][j]}
        scols = {j for j in range(lvl)
                 if not p.adj[lvl][j]} if p.induced else set()
        ub = {j for (i, j) in p.restrictions if i == lvl and j < lvl}
        lb = {j for (j, i) in p.restrictions if i == lvl and j < lvl}
        ordered = {j for j in range(lvl)
                   if (lvl, j) in less or (j, lvl) in less}
        exclude = {j for j in range(lvl)
                   if j not in icols and j not in ordered}
        eff_i[lvl], eff_s[lvl], eff_ub[lvl], eff_lb[lvl] = \
            icols, scols, ub, lb
        # ---- carry reuse: is the parent's survivor stream a superset? ----
        use_carry = False
        if lvl > 2:
            pi, ps, pub, plb = eff_i[lvl - 1], eff_s[lvl - 1], \
                eff_ub[lvl - 1], eff_lb[lvl - 1]
            ub_ok = all(any(u2 == u or (u2, u) in less for u2 in ub)
                        for u in pub)
            lb_ok = all(any(w2 == w or (w, w2) in less for w2 in lb)
                        for w in plb)
            use_carry = (raw_ops[-1]["kind"] == "expand" and pi <= icols
                         and ps <= scols and ub_ok and lb_ok)
        if use_carry:
            inter = icols - eff_i[lvl - 1]
            sub = scols - eff_s[lvl - 1]
            base = -1
        else:
            inter = set(icols)
            base = min(inter)
            inter.discard(base)
            sub = set(scols)
        raw_ops.append(dict(
            level=lvl, use_carry=use_carry, base=base,
            inter=tuple(sorted(inter)), sub=tuple(sorted(sub)),
            ub=tuple(sorted(ub)), lb=tuple(sorted(lb)),
            exclude=tuple(sorted(exclude)),
            kind=("emit" if emit else "count") if lvl == k - 1 else "expand",
            tail=None))
    # ---- tail folding: closed-form final level -> degree multiplier ----
    last = raw_ops[-1]
    if (not emit and aggregate is None and len(raw_ops) >= 2
            and last["kind"] == "count"
            and not last["sub"] and not last["ub"] and not last["lb"]
            and last["use_carry"] is False and not last["inter"]):
        lvl, b = last["level"], last["base"]
        # every earlier vertex must be statically a member of N(v_b), so the
        # exclusion count is a compile-time constant (non-induced only:
        # an induced pattern would have sub refs and fail the guard above)
        if b <= lvl - 2 and all(p.adj[j][b] for j in range(lvl) if j != b):
            raw_ops.pop()
            raw_ops[-1]["kind"] = "count"
            raw_ops[-1]["tail"] = (b, lvl - 1)
    # ---- value disposition: stamp the count leaf with SVPU agg fields ----
    if aggregate is not None:
        leaf = raw_ops[-1]
        lvl = leaf["level"]
        leaf["agg"] = aggregate
        # pattern edges wholly inside the prefix (incl. the (0,1) feed edge):
        # folded into a per-item scale via CSR weight lookups at the leaf
        leaf["agg_scale_edges"] = tuple(
            (i, j) for i in range(lvl) for j in range(i + 1, lvl)
            if p.adj[i][j])
        # candidate-adjacent prefix columns whose matched value the leaf's
        # own kernel refs do NOT observe (carry reuse: the membership test
        # happened at an ancestor level) — looked up per (item, slot)
        covered = set(leaf["inter"]) \
            | (set() if leaf["use_carry"] else {leaf["base"]})
        leaf["agg_cand_cols"] = tuple(sorted(
            {j for j in range(lvl) if p.adj[lvl][j]} - covered))
    # ---- liveness: which columns do deeper levels still touch? ----
    ops: list[LevelOp] = []
    for idx, ro in enumerate(raw_ops):
        deeper = raw_ops[idx + 1:]
        needed: set[int] = set()
        rows_needed: set[int] = set()
        for d in deeper:
            drows = (set() if d["use_carry"] else {d["base"]}) \
                | set(d["inter"]) | set(d["sub"])
            dvals = drows | set(d["ub"]) | set(d["lb"]) | set(d["exclude"])
            if d["tail"] is not None:
                dvals.add(d["tail"][0])
            for a, b in d.get("agg_scale_edges", ()):
                dvals.add(a)
                dvals.add(b)
            dvals |= set(d.get("agg_cand_cols", ()))
            needed |= {c for c in dvals if c <= ro["level"]}
            rows_needed |= {c for c in drows if c <= ro["level"]}
        if emit:
            needed |= set(range(ro["level"] + 1))   # embeddings output all
        ops.append(LevelOp(
            level=ro["level"], use_carry=ro["use_carry"], base=ro["base"],
            inter=ro["inter"], sub=ro["sub"], ub=ro["ub"], lb=ro["lb"],
            exclude=ro["exclude"], kind=ro["kind"], tail=ro["tail"],
            agg=ro.get("agg"),
            agg_scale_edges=ro.get("agg_scale_edges", ()),
            agg_cand_cols=ro.get("agg_cand_cols", ()),
            out_cols=tuple(sorted(needed)),
            gather_refs=tuple(sorted(rows_needed)),
            carry_out=(idx + 1 < len(raw_ops)
                       and raw_ops[idx + 1]["use_carry"])))
    plan = WavePlan(pattern=p, symmetric=symmetric, ops=tuple(ops),
                    div=1 if emit else p.div)
    _PLAN_CACHE[(p, emit, aggregate)] = plan
    return plan


# ---------------------------------------------------------------------------
# canned patterns — the paper's apps + the 4-motif family, declaratively
# ---------------------------------------------------------------------------

# triangle, each counted once: v2 < v1 < v0 (§VI-B "T")
TRIANGLE = pattern("triangle", 3, [(0, 1), (0, 2), (1, 2)],
                   restrictions=[(1, 0), (2, 1)])

# paper-faithful Fig. 4a S_NESTINTER stream: unbounded, every triangle
# reached 6x, one division at retire ("TS")
TRIANGLE_NESTED = pattern("triangle-nested", 3, [(0, 1), (0, 2), (1, 2)],
                          div=6)

# induced three-chain a—m—b with (a,b) ∉ E; v0 = center m, leaf order
# broken with v2 > v1 — a *lower* bound level ("TC")
THREE_CHAIN_INDUCED = pattern("three-chain-induced", 3, [(0, 1), (0, 2)],
                              restrictions=[(1, 2)], induced=True)

# non-induced tailed triangle (paper "TT"): triangle {0,1,2} + tail (1,3);
# the wing swap v0<->v2 broken with v2 < v0. The tail level folds to the
# closed-form deg(v1) - 2 multiplier at compile time.
TAILED_TRIANGLE = pattern("tailed-triangle", 4,
                          [(0, 1), (0, 2), (1, 2), (1, 3)],
                          restrictions=[(2, 0)])

# the six connected 4-vertex motifs as *unordered shapes* (induced counts).
# Vertex numbering here is arbitrary — matching order and symmetry-breaking
# restrictions are derived automatically (auto_restrictions + the forest
# scheduler's matching-order search), so nothing below is hand-scheduled.
FOUR_MOTIF_SHAPES: dict[str, Motif] = {
    "4-clique": motif("4-clique", 4,
                      itertools.combinations(range(4), 2), induced=True),
    "diamond": motif("diamond", 4,
                     [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3)], induced=True),
    "4-cycle": motif("4-cycle", 4,
                     [(0, 1), (1, 2), (2, 3), (0, 3)], induced=True),
    "paw": motif("paw", 4, [(0, 1), (0, 2), (1, 2), (2, 3)], induced=True),
    "4-path": motif("4-path", 4, [(0, 1), (1, 2), (2, 3)], induced=True),
    "4-star": motif("4-star", 4, [(0, 1), (0, 2), (0, 3)], induced=True),
}

# named query surface for the session API (mining.session.Miner): strings a
# query may use, each resolving to a paper-faithful Pattern (fixed schedule)
# or a Motif (schedule chosen by the batch-aware matching-order search)
_NAMED_QUERIES: dict[str, object] = {
    "triangle": TRIANGLE,
    "triangle-nested": TRIANGLE_NESTED,
    "three-chain": THREE_CHAIN_INDUCED,
    "three-chain-induced": THREE_CHAIN_INDUCED,
    "tailed-triangle": TAILED_TRIANGLE,
    "5-clique": clique_pattern(5),
    **FOUR_MOTIF_SHAPES,
}


def resolve_query(q):
    """Resolve a session query — a name, ``Motif`` or ``Pattern`` — to the
    ``Motif``/``Pattern`` object the compile/schedule stages consume."""
    if isinstance(q, (Motif, Pattern)):
        return q
    try:
        return _NAMED_QUERIES[q]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown pattern query {q!r}; use a Pattern, a Motif or one of "
            f"{sorted(_NAMED_QUERIES)}") from None


# per-motif names + FOUR_MOTIFS resolve lazily through the schedule search
# (mining.forest.schedule_patterns) the first time they are touched — the
# search needs build_forest, which imports this module
_SCHEDULED_NAMES = {"DIAMOND": "diamond", "CYCLE4": "4-cycle",
                    "PAW_INDUCED": "paw", "PATH4": "4-path",
                    "STAR4": "4-star"}


def __getattr__(name: str):
    if name == "FOUR_MOTIFS" or name in _SCHEDULED_NAMES:
        from .forest import schedule_patterns
        pats = schedule_patterns(list(FOUR_MOTIF_SHAPES.values()))
        four = dict(zip(FOUR_MOTIF_SHAPES, pats))
        globals()["FOUR_MOTIFS"] = four
        for attr, motif_name in _SCHEDULED_NAMES.items():
            globals()[attr] = four[motif_name]
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
