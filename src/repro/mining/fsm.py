"""Frequent subgraph mining with MNI (minimum-image) support (§VI-B).

Patterns: all connected vertex-labelled patterns with <= 3 edges —
  edge (2 vertices), wedge (path of 3), triangle, 3-star, path of 4.
Matching is *non-induced* subgraph isomorphism (GraMi/Peregrine semantics).

Support:
  MNI(P) = min over pattern vertices u of |{φ(u) : φ an embedding}|  — the
  minimum-image metric [Bringmann & Nijssen], which satisfies the Downward
  Closure Property the paper insists on (§VI-B).
  sFSM uses the *embedding count* instead — GRAMER's incorrect support that
  violates downward closure; implemented for the comparison experiments only.

Downward closure prunes candidates: a k-edge candidate is evaluated only if
all its (k-1)-edge sub-patterns were frequent.

Engineering: domains are boolean masks over V computed vectorised from
neighbor-label count tables; embeddings come from the wavefront engine's
FSM pattern batch (``apps.fsm_pattern_feed``) — the engine-fed plans merged
into one ``PlanForest`` and executed in a single feed pass on a
``mining.session.Miner`` (pass ``miner=`` to reuse a caller-held session;
repeated FSM sweeps over one graph then retrace nothing). Today the batch
is the compiled triangle *emit* plan, whose worklists are compacted on
device (``ops.xinter_compact`` src output) so the embedding feed never
round-trips through host ``np.nonzero``; further engine-fed patterns join
the batch (and share its canonical prefixes) via ``apps.FSM_FEED_PLANS``.
Only path-4 domains use a per-edge host loop (FSM support calculation is
host-dominated — the paper's own observation for why FSM sees the smallest
speedup, Fig. 9).
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.graph.csr import CSRGraph
from .apps import fsm_pattern_feed

# ---------------------------------------------------------------------------
# canonical pattern keys
# ---------------------------------------------------------------------------


def edge_key(la: int, lb: int):
    return ("edge", tuple(sorted((la, lb))))


def wedge_key(la: int, lb: int, lc: int):
    """lb is the center label."""
    lo, hi = sorted((la, lc))
    return ("wedge", (lo, lb, hi))


def triangle_key(la, lb, lc):
    return ("triangle", tuple(sorted((la, lb, lc))))


def star3_key(center, leaves):
    return ("star3", (center, tuple(sorted(leaves))))


def path4_key(la, lb, lc, ld):
    seq = (la, lb, lc, ld)
    return ("path4", min(seq, seq[::-1]))


def random_labels(num_vertices: int, num_labels: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, num_labels, size=num_vertices, dtype=np.int32)


# ---------------------------------------------------------------------------
# shared precomputation
# ---------------------------------------------------------------------------


class _Ctx:
    def __init__(self, g: CSRGraph, labels: np.ndarray):
        self.g = g
        self.labels = np.asarray(labels, dtype=np.int32)
        self.num_labels = int(self.labels.max()) + 1 if self.labels.size else 0
        self.indptr = np.asarray(g.indptr)
        self.indices = np.asarray(g.indices)[: g.num_edges]
        self.src = np.repeat(np.arange(g.num_vertices, dtype=np.int32),
                             np.diff(self.indptr).astype(np.int64))
        # nbr_label_count[v, l] = # neighbors of v with label l
        self.nlc = np.zeros((g.num_vertices, self.num_labels), dtype=np.int32)
        np.add.at(self.nlc, (self.src, self.labels[self.indices]), 1)

    def nbrs(self, v) -> np.ndarray:
        return self.indices[self.indptr[v]: self.indptr[v + 1]]


def _support(domains: dict) -> int:
    return min((int(m.sum()) for m in domains.values()), default=0)


# ---------------------------------------------------------------------------
# per-pattern evaluators: return (mni_support, embedding_count)
# ---------------------------------------------------------------------------


def _eval_edge(ctx: _Ctx, la: int, lb: int):
    L = ctx.labels
    src_l, dst_l = L[ctx.src], L[ctx.indices]
    if la == lb:
        dom = np.zeros(ctx.g.num_vertices, bool)
        sel = (src_l == la) & (dst_l == la)
        dom[ctx.src[sel]] = True
        count = int(sel.sum()) // 2
        return _support({("end", la): dom}), count
    dom_a = np.zeros(ctx.g.num_vertices, bool)
    dom_b = np.zeros(ctx.g.num_vertices, bool)
    sel = (src_l == la) & (dst_l == lb)
    dom_a[ctx.src[sel]] = True
    dom_b[ctx.indices[sel]] = True
    return _support({("end", la): dom_a, ("end", lb): dom_b}), int(sel.sum())


def _eval_wedge(ctx: _Ctx, la: int, lb: int, lc: int):
    L, nlc = ctx.labels, ctx.nlc
    if la == lc:
        center = (L == lb) & (nlc[:, la] >= 2)
        cnt = nlc[center][:, la].astype(np.int64)
        count = int((cnt * (cnt - 1) // 2).sum())
        leaf = np.zeros(ctx.g.num_vertices, bool)
        sel = (L[ctx.indices] == la) & center[ctx.src]
        leaf[ctx.indices[sel]] = True
        return _support({("center",): center, ("leaf", la): leaf}), count
    center = (L == lb) & (nlc[:, la] >= 1) & (nlc[:, lc] >= 1)
    count = int((nlc[center][:, la].astype(np.int64)
                 * nlc[center][:, lc].astype(np.int64)).sum())
    doms = {("center",): center}
    for ll in (la, lc):
        leaf = np.zeros(ctx.g.num_vertices, bool)
        sel = (L[ctx.indices] == ll) & center[ctx.src]
        leaf[ctx.indices[sel]] = True
        doms[("leaf", ll)] = leaf
    return _support(doms), count


def _eval_triangle(ctx: _Ctx, tris: np.ndarray, la, lb, lc):
    want = tuple(sorted((la, lb, lc)))
    L = ctx.labels
    tl = np.sort(L[tris], axis=1)
    sel = np.all(tl == np.asarray(want, dtype=L.dtype)[None, :], axis=1)
    matched = tris[sel]
    doms = {}
    for ll in set(want):
        dom = np.zeros(ctx.g.num_vertices, bool)
        vs = matched[L[matched] == ll]
        dom[vs] = True
        doms[("v", ll)] = dom
    return _support(doms), int(matched.shape[0])


def _eval_star3(ctx: _Ctx, center_l: int, leaves: tuple[int, int, int]):
    import math
    L, nlc = ctx.labels, ctx.nlc
    mult = {lab: leaves.count(lab) for lab in set(leaves)}
    ok = L == center_l
    for lab, m in mult.items():
        ok &= nlc[:, lab] >= m
    count = 0
    if ok.any():
        per = np.ones(int(ok.sum()), dtype=np.int64)
        for lab, m in mult.items():
            c = nlc[ok][:, lab].astype(np.int64)
            num = np.ones_like(c)          # C(c, m), vectorised
            for i in range(m):
                num = num * (c - i)
            per *= num // math.factorial(m)
        count = int(per.sum())
    doms = {("center",): ok}
    for lab in set(leaves):
        leaf = np.zeros(ctx.g.num_vertices, bool)
        sel = (L[ctx.indices] == lab) & ok[ctx.src]
        leaf[ctx.indices[sel]] = True
        doms[("leaf", lab)] = leaf
    return _support(doms), count


def _eval_path4(ctx: _Ctx, canon: tuple[int, int, int, int]):
    la, lb, lc, ld = canon
    palindrome = canon == canon[::-1]
    L = ctx.labels
    dom = [np.zeros(ctx.g.num_vertices, bool) for _ in range(4)]
    count = 0
    sel = np.nonzero((L[ctx.src] == lb) & (L[ctx.indices] == lc))[0]
    for e in sel:
        b, c = int(ctx.src[e]), int(ctx.indices[e])
        nb, nc = ctx.nbrs(b), ctx.nbrs(c)
        a_cand = nb[(L[nb] == la) & (nb != c)]
        d_cand = nc[(L[nc] == ld) & (nc != b)]
        if a_cand.size == 0 or d_cand.size == 0:
            continue
        if la == ld:
            common = np.intersect1d(a_cand, d_cand, assume_unique=True)
            pairs = a_cand.size * d_cand.size - common.size
        else:
            common = np.empty(0, dtype=a_cand.dtype)
            pairs = a_cand.size * d_cand.size
        if pairs <= 0:
            continue
        count += pairs
        dom[1][b] = True
        dom[2][c] = True
        # a qualifies unless its only partner choice is itself
        if la == ld:
            ok_a = np.ones(a_cand.size, bool)
            if d_cand.size == 1:
                ok_a &= a_cand != d_cand[0]
            dom[0][a_cand[ok_a]] = True
            ok_d = np.ones(d_cand.size, bool)
            if a_cand.size == 1:
                ok_d &= d_cand != a_cand[0]
            dom[3][d_cand[ok_d]] = True
        else:
            dom[0][a_cand] = True
            dom[3][d_cand] = True
    if palindrome:
        assert count % 2 == 0
        count //= 2
    doms = {(i,): dom[i] for i in range(4)}
    return _support(doms), count


# ---------------------------------------------------------------------------
# the miner
# ---------------------------------------------------------------------------


def _mine(g: CSRGraph, labels: np.ndarray, min_support: int, max_edges: int,
          metric: str, miner=None):
    """metric='mni' (fsm) or 'count' (sfsm); ``miner`` is an optional
    ``mining.session.Miner`` the engine feed runs on."""
    ctx = _Ctx(g, labels)
    ls = sorted(set(ctx.labels.tolist()))
    results: dict = {}
    measure = {}

    def value(sup, cnt):
        return sup if metric == "mni" else cnt

    # --- level 1: edges ---
    freq_edges = set()
    for la, lb in itertools.combinations_with_replacement(ls, 2):
        sup, cnt = _eval_edge(ctx, la, lb)
        v = value(sup, cnt)
        measure[edge_key(la, lb)] = v
        if v >= min_support:
            freq_edges.add(edge_key(la, lb))
            results[edge_key(la, lb)] = v
    if max_edges == 1 or not freq_edges:
        return results

    # --- level 2: wedges (downward closure on both edges) ---
    freq_wedges = set()
    for lb in ls:                      # center
        for la, lc in itertools.combinations_with_replacement(ls, 2):
            if edge_key(la, lb) not in freq_edges or \
               edge_key(lb, lc) not in freq_edges:
                continue
            sup, cnt = _eval_wedge(ctx, la, lb, lc)
            v = value(sup, cnt)
            k = wedge_key(la, lb, lc)
            measure[k] = v
            if v >= min_support:
                freq_wedges.add(k)
                results[k] = v
    if max_edges == 2 or not freq_wedges:
        return results

    # --- level 3 ---
    tris = fsm_pattern_feed(g, miner=miner)[0]   # session triangle emit
    # triangles: all 3 edges + all 3 wedges frequent
    for la, lb, lc in itertools.combinations_with_replacement(ls, 3):
        edges_ok = all(edge_key(x, y) in freq_edges
                       for x, y in [(la, lb), (lb, lc), (la, lc)])
        wedges_ok = all(wedge_key(x, m, y) in freq_wedges
                        for x, m, y in [(lb, la, lc), (la, lb, lc), (la, lc, lb)])
        if not (edges_ok and wedges_ok):
            continue
        sup, cnt = _eval_triangle(ctx, tris, la, lb, lc)
        v = value(sup, cnt)
        k = triangle_key(la, lb, lc)
        if v >= min_support:
            results[k] = v
    # 3-stars
    for center in ls:
        for leaves in itertools.combinations_with_replacement(ls, 3):
            if not all(edge_key(center, lf) in freq_edges for lf in leaves):
                continue
            if not all(wedge_key(x, center, y) in freq_wedges
                       for x, y in itertools.combinations(leaves, 2)):
                continue
            sup, cnt = _eval_star3(ctx, center, leaves)
            v = value(sup, cnt)
            if v >= min_support:
                results[star3_key(center, leaves)] = v
    # 4-paths
    seen = set()
    for la in ls:
        for lb in ls:
            for lc in ls:
                for ld in ls:
                    k = path4_key(la, lb, lc, ld)
                    if k in seen:
                        continue
                    seen.add(k)
                    canon = k[1]
                    a, b, c, d = canon
                    if edge_key(a, b) not in freq_edges or \
                       edge_key(b, c) not in freq_edges or \
                       edge_key(c, d) not in freq_edges:
                        continue
                    if wedge_key(a, b, c) not in freq_wedges or \
                       wedge_key(b, c, d) not in freq_wedges:
                        continue
                    sup, cnt = _eval_path4(ctx, canon)
                    v = value(sup, cnt)
                    if v >= min_support:
                        results[k] = v
    return results


def fsm(g: CSRGraph, labels: np.ndarray, min_support: int,
        max_edges: int = 3, miner=None) -> dict:
    """FSM with MNI support (downward-closure sound)."""
    return _mine(g, labels, min_support, max_edges, "mni", miner=miner)


def sfsm(g: CSRGraph, labels: np.ndarray, min_support: int,
         max_edges: int = 3, miner=None) -> dict:
    """simple-FSM: GRAMER's embedding-count support (comparison only)."""
    return _mine(g, labels, min_support, max_edges, "count", miner=miner)
