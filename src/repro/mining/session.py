"""Miner session API: a graph-resident query engine.

IntersectX's core claim is that stream state — the SMT, the S-Cache, the
cached stream registers — persists *across* intersections, so repeated
queries over one graph amortise all data movement. The one-shot entry
points this repo grew up with (``WaveRunner.run(plan)``, ``run_set``, the
per-app wrappers in ``mining.apps``) re-stage the graph and re-derive
every schedule per call. A ``Miner`` is the session that owns a graph for
its lifetime and serves any number of queries against it:

    m = Miner(graph)
    m.count("triangle")                 # -> int
    m.count_many(["4-clique", "diamond", "4-cycle",
                  "paw", "4-path", "4-star"])   # -> list[int], one pass
    m.embeddings("triangle")            # -> (N, 3) int32 matrix

Every query runs through an explicit three-stage pipeline, each stage
memoised for the session's lifetime:

**compile** — a query (a name from ``plan._NAMED_QUERIES``, a ``Motif``
shape, or an explicit ``Pattern``) lowers to a ``WavePlan`` via
``plan.compile_pattern``. Plans are cached per (query, emit) pair.

**schedule** — for batches, the automatic matching-order search
(``forest.schedule_patterns``) picks each ``Motif``'s matching order to
maximise shared canonical prefixes across the batch (explicit ``Pattern``
queries are fixed points), then ``forest.build_forest`` merges the
compiled plans into a ``PlanForest``. Forests are cached on the batch's
canonical plan keys, so a repeated batch re-derives nothing.

**execute** — the ``WaveRunner`` machinery interprets the plan/forest,
with two session-level residency guarantees: the graph's CSR buffers are
staged to device ONCE at construction (``jax.device_put``), and every
jitted executable lives in the session's ``ExecutableCache``, so repeated
queries never retrace. A ``Miner`` is single-threaded (no locking around
the cache or the runner's mutable stats): a concurrent server gives each
worker its own session — per-worker warm-up, zero retraces after it.

Executable-cache key
--------------------

This section is THE definition of the executable-cache key — every other
docstring (``MinerConfig``, ``ExecutableCache``, ``mining.shard``) points
here instead of restating it. ``ExecutableCache`` keys are::

    (mesh/shape signature) + (chunk, backend, device_compact, fused_level)
        + (kind, LevelOp, capacity signature, ...)

segment by segment:

* **mesh/shape signature** — ``mesh_signature(mesh)``: platform + device
  count, extended with the actual mesh axes ``((name, size), ...)`` for a
  sharded session (see the mesh contract below). Isolates executables
  compiled for different device topologies; the sharded runner
  additionally prefixes its per-executable keys with
  ``("mesh", axis, shards)`` so sharded and unsharded traces can never
  collide.
* **runner config** — the ``MinerConfig`` execution knobs that change
  compiled shapes or kernel paths: ``chunk``, ``backend``,
  ``device_compact``, ``fused_level``. ``mesh``/``mesh_axis``/
  ``feed_partition`` enter through the mesh segment and the feed
  partitioner instead; ``telemetry`` is deliberately NOT part of any key
  (tracing must never force a retrace — gated in ci_gate ``--telemetry``).
* **per-executable key** — the runner's trailing segment:
  ``(kind, LevelOp, capacity signature, ...)``. LevelOps hash by value,
  so structurally equal levels of different patterns share one trace.

A cache *miss* is a retrace — ``Miner.stats`` exposes hit/miss counters,
and the session-reuse contract (tested in tests/test_session.py, gated in
benchmarks/ci_gate.py) is that a repeated query produces **zero** new
traces.

Mesh contract (sharded sessions)
--------------------------------

``Miner(g, mesh=S)`` (S > 1) mines data-parallel over a 1-D device mesh:

* **mesh** — ``distributed.sharding.make_mining_mesh(S, axis=mesh_axis)``
  over the first S visible devices; ``mesh_axis`` defaults to ``"mine"``
  and is the only axis. On CPU, fake devices come from
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
* **cache key** — ``mesh_signature(mesh)`` appends the axis spec
  ``((name, size), ...)`` to the platform/device-count signature, and the
  sharded runner additionally prefixes its per-executable keys with
  ``("mesh", axis, shards)``: sharded and unsharded traces can never
  collide, and a repeated sharded query is still 0 retraces.
* **partials layout** — the graph is replicated (``PartitionSpec()``);
  wave buffers are sharded on the mining axis as S back-to-back per-shard
  blocks; count leaves ``psum`` their (hi, lo) partials as four 16-bit
  limbs (exact at any mesh size, reassembled host-side); expand levels
  return per-shard ``(S, m)`` boundary meta (live totals drive lockstep
  chunking, capacities take the max over shards); emit gathers per-shard
  survivor blocks. Counts are bit-identical to the unsharded session.
* **feed** — ``shard.shard_edge_steps`` deals each degree bucket's edges
  round-robin across shards (``feed_partition="contiguous"`` keeps the
  hub-pinning foil); per-shard feed items ride
  ``stats["runner"]["shard_feed_items"]`` — backed by a *labeled* counter
  series (``metrics.counter("shard_feed_items", shard=s)``), so exporters
  see one series per shard while the legacy list shape is preserved.

Observability
-------------

Every session carries a ``repro.obs.Telemetry``: ``miner.telemetry``.

* **metrics** — ``telemetry.metrics`` is the registry backing every
  counter in ``miner.stats`` (session pipeline counters AND the runner's
  dispatch/sync counters — the legacy dicts are derived views, identical
  key order and values). ``telemetry.prometheus_text()`` renders the
  whole registry; labeled series (per-shard feed items) export one sample
  per label set.
* **tracing** — pass ``telemetry=Telemetry(enabled=True)`` (or call
  ``miner.telemetry.enable()``) and every query records a span tree:
  ``query`` → ``compile``/``schedule``/``execute`` → per-``feed`` and
  per-level ``L{l}:{kind}`` spans → ``dispatch`` spans timed around the
  kernel call + ``block_until_ready`` (op kind, items, capacities,
  exec-cache hit/miss). Export with ``telemetry.write_trace(path)``
  (Chrome-trace JSON — chrome://tracing / ui.perfetto.dev) or aggregate
  with ``telemetry.snapshot()`` / ``tracer.level_seconds()``. Disabled
  (the default), the engine takes the untraced branch: no spans, no
  extra synchronization, no extra kernel dispatches.
* **jax profiler** — ``with miner.telemetry.jax_profile(logdir): ...``
  wraps a query in ``jax.profiler`` start/stop for an XLA-level trace.

Value streams (SVPU, §IV-E)
---------------------------

A session over a *weighted* graph — one built with per-edge f32 values
(``graph.build_csr(..., edge_values=...)`` or ``graph.with_edge_values``)
— additionally serves **aggregate queries**::

    m = Miner(with_edge_values(g, weights))
    m.aggregate("triangle")                  # Σ over triangles of Π edge w
    m.aggregate("4-clique", op="max")        # heaviest clique's weight
    m.aggregate_many(["triangle", "4-clique"], op="min")

The contract, stage by stage:

* **semantics** — an embedding's value is the product of its pattern-edge
  weights; ``aggregate`` reduces embedding values with ``op`` (``'sum'`` /
  ``'max'`` / ``'min'``). Zero embeddings aggregate to ``0.0`` for every
  op. Queries must resolve to fully symmetry-broken schedules (``div ==
  1``; ``Motif`` queries always are, ``triangle-nested`` is not).
* **value alignment** — edge values live in a CSR-aligned plane: the
  session stages them with the keys, once (``padded_value_rows`` gathers
  value rows under the SAME permutation as the sorted key rows, tested in
  tests/test_values.py).
* **zero extra feed passes** — the aggregate leaf rides the unweighted
  plan's dispatches: same stream structure (``LevelOp.stream_key()``
  ignores the value disposition), same membership kernels
  (``kernels.ops.xlevel_agg`` shares ``xlevel_count``'s tile schedule), so
  ``stats["runner"]["feed_chunks"]`` and ``level_kernel_dispatches`` for a
  weighted query equal its unweighted twin's (gated in ci_gate --values).
* **0 retraces on repeat** — aggregate executables are exec-cache keyed
  like every other level (the LevelOp's ``agg`` fields are part of its
  value hash), so a repeated ``aggregate`` call traces nothing new.
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from typing import Callable, Sequence

import jax
import numpy as np

from repro.graph.csr import CSRGraph
from repro.obs import LegacyStatsView, Telemetry
from .engine import WaveRunner
from .forest import PlanForest, build_forest, schedule_patterns
from .plan import Motif, WavePlan, compile_pattern, resolve_query

__all__ = ["ExecutableCache", "Miner", "MinerConfig", "mesh_signature"]


def mesh_signature(mesh=None) -> tuple:
    """Device-topology component of the executable-cache key: platform +
    device count, extended with the actual mesh axes ``((name, size), ...)``
    when the session mines over a device mesh. Meshes with different axis
    names or sizes therefore never share an executable, and the unsharded
    signature (no mesh segment) can never equal a sharded one."""
    sig: tuple = (jax.default_backend(), jax.device_count())
    if mesh is not None:
        sig += tuple((str(a), int(s)) for a, s in dict(mesh.shape).items())
    return sig


class ExecutableCache:
    """Session-lifetime cache of jitted executables, with hit/miss stats.

    Lifted out of ``WaveRunner`` so executables survive the runner that
    built them: every entry is keyed by the full signature documented in
    the module docstring, making the cache safe to share across runners
    (and, later, across meshes). ``misses`` counts traces actually built —
    the session's *retrace* counter."""

    def __init__(self, prefix: tuple = (), mesh=None):
        self.prefix = prefix + (mesh_signature(mesh),)
        self._entries: dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key: tuple, build: Callable):
        """Return (executable, freshly_built?) for ``key``."""
        key = self.prefix + key
        fn = self._entries.get(key)
        if fn is None:
            fn = self._entries[key] = build()
            self.misses += 1
            return fn, True
        self.hits += 1
        return fn, False

    def __len__(self) -> int:
        return len(self._entries)

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}


@dataclasses.dataclass(frozen=True)
class MinerConfig:
    """The ONE way to configure a session — every construction knob lives
    here (``Miner(g, **kwargs)`` is sugar that builds/extends a config).

    The execution knobs are fixed for the session's lifetime because they
    are part of every executable's cache key — see the module docstring's
    "Executable-cache key" section for the full key and which fields land
    in which segment. ``telemetry`` is observability wiring, not an
    execution knob: it is excluded from equality and never enters a cache
    key (tracing must not retrace)."""

    chunk: int | None = None          # wave chunk; None = auto-sized
    backend: str = "auto"             # kernel backend (pallas/xla/auto)
    device_compact: bool = True       # False: host np.nonzero oracle path
    fused_level: bool = True          # k-operand fused level kernels
    mesh: int | None = None           # >1: shard over that many devices
    mesh_axis: str = "mine"           # mesh axis name (cache-key relevant)
    feed_partition: str = "round_robin"  # edge-feed dealing (shard.py)
    # session observability (repro.obs); None = fresh disabled Telemetry
    telemetry: Telemetry | None = dataclasses.field(
        default=None, compare=False, repr=False)

    @classmethod
    def from_args(cls, args, **overrides) -> "MinerConfig":
        """Build a config from a parsed launcher namespace
        (``launch.cli`` flag names, shared by mine.py / serve.py):
        ``--shards N`` → ``mesh`` (``N > 1``), ``--trace OUT`` → a
        tracing-enabled ``Telemetry``. Missing attributes fall back to
        the field defaults, so any ``argparse.Namespace`` that carries a
        subset of the flags works. ``overrides`` win over flags."""
        shards = int(getattr(args, "shards", 0) or 0)
        cfg = cls(
            chunk=getattr(args, "chunk", None),
            mesh=shards if shards > 1 else None,
            telemetry=Telemetry(
                enabled=bool(getattr(args, "trace", ""))),
        )
        return dataclasses.replace(cfg, **overrides) if overrides else cfg


class Miner:
    """A graph-resident mining session: compile → schedule → execute.

    Owns the graph (device-staged once), the compiled-plan and forest
    caches, and the executable cache for its whole lifetime. See the
    module docstring for the pipeline contract.
    """

    # session pipeline counters, in their historical insertion order
    _SESSION_KEYS = ("queries", "plan_hits", "plan_misses",
                     "schedule_hits", "schedule_misses")

    def __init__(self, graph: CSRGraph, config: MinerConfig | None = None,
                 telemetry: Telemetry | None = None, **overrides):
        # every knob lives in MinerConfig; bare kwargs (including the
        # historical ``telemetry=`` / ``mesh=`` arguments) are sugar that
        # builds or extends one
        if telemetry is not None:
            overrides["telemetry"] = telemetry
        if config is None:
            config = MinerConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        # one Telemetry per session, shared with the runner: every counter
        # (session pipeline + runner dispatch/sync) lands in one registry
        # and every span of a traced query lands in one tracer
        self.telemetry = (config.telemetry if config.telemetry is not None
                          else Telemetry())
        if config.mesh is not None and int(config.mesh) > 1:
            from repro.distributed.sharding import make_mining_mesh
            from .shard import ShardedWaveRunner
            self.mesh = make_mining_mesh(int(config.mesh),
                                         axis=config.mesh_axis)
            self.exec_cache = ExecutableCache(mesh=self.mesh)
            self._runner = ShardedWaveRunner(
                graph, self.mesh, axis=config.mesh_axis,
                feed_partition=config.feed_partition, chunk=config.chunk,
                backend=config.backend,
                device_compact=config.device_compact,
                fused_level=config.fused_level, exec_cache=self.exec_cache,
                telemetry=self.telemetry)
            # the runner replicated the CSR buffers across the mesh
            self.graph: CSRGraph = self._runner.g
        else:
            # stage the CSR buffers to device once per session — queries
            # only ever ship scalars and per-chunk vertex ids after this
            self.mesh = None
            self.graph = jax.device_put(graph)
            self.exec_cache = ExecutableCache()
            self._runner = WaveRunner(
                self.graph, chunk=config.chunk, backend=config.backend,
                device_compact=config.device_compact,
                fused_level=config.fused_level, exec_cache=self.exec_cache,
                telemetry=self.telemetry)
        self._plans: dict[tuple, WavePlan] = {}
        self._forests: dict[tuple, PlanForest] = {}
        self.metrics = self.telemetry.metrics
        self._stats = LegacyStatsView()
        self._sct = {k: self._stats.expose_counter(k, self.metrics)
                     for k in self._SESSION_KEYS}

    # ------------------------------------------------------------ compile
    def compile(self, query, emit: bool = False,
                aggregate: str | None = None) -> WavePlan:
        """Stage 1: lower one query to a ``WavePlan`` (cached).

        ``Motif`` queries are scheduled standalone (batch-aware order
        choice happens in ``schedule``); explicit ``Pattern``s and named
        paper patterns keep their declared matching order. ``aggregate``
        compiles the weighted (SVPU value) program — see the module
        docstring's "Value streams" section."""
        tr = self.telemetry.tracer
        with (tr.span("compile", query=str(query), emit=emit)
              if tr.enabled else nullcontext()):
            resolved = resolve_query(query)
            key = (resolved, emit, aggregate)
            plan = self._plans.get(key)
            if plan is not None:
                self._sct["plan_hits"].inc()
                return plan
            self._sct["plan_misses"].inc()
            if isinstance(resolved, Motif):
                resolved = schedule_patterns([resolved])[0]
            plan = compile_pattern(resolved, emit=emit, aggregate=aggregate)
            self._plans[key] = plan
            return plan

    # ----------------------------------------------------------- schedule
    def schedule(self, queries: Sequence, emit: bool = False,
                 aggregate: str | None = None) -> PlanForest:
        """Stage 2: batch matching-order search + forest merge (cached).

        Returns the ``PlanForest`` for the batch: ``Motif`` members get
        their order from the shared-prefix search (jointly, with any
        explicit ``Pattern`` members as fixed context), and the compiled
        plans merge into one prefix trie. Cached on the resolved batch, so
        repeated and permuted-config queries skip both the search and the
        merge."""
        tr = self.telemetry.tracer
        with (tr.span("schedule", queries=len(queries), emit=emit)
              if tr.enabled else nullcontext()):
            resolved = tuple(resolve_query(q) for q in queries)
            key = (resolved, emit, aggregate)
            forest = self._forests.get(key)
            if forest is not None:
                self._sct["schedule_hits"].inc()
                return forest
            self._sct["schedule_misses"].inc()
            # Motifs are searched jointly; Pattern members are fixed points
            # of the search but still shape its score (they sit in the
            # trial trie). The order search ignores the value disposition —
            # agg plans share the unweighted plans' stream structure.
            pats = schedule_patterns(resolved)
            plans = []
            for r, p in zip(resolved, pats):
                plan = compile_pattern(p, emit=emit, aggregate=aggregate)
                self._plans.setdefault((r, emit, aggregate), plan)
                plans.append(plan)
            forest = build_forest(plans)
            self._forests[key] = forest
            return forest

    # ------------------------------------------------------------ execute
    def _query_span(self, kind: str, **attrs):
        """Root span of one traced query (no-op when tracing is off)."""
        tr = self.telemetry.tracer
        if not tr.enabled:
            return nullcontext()
        return tr.span("query", kind=kind, **attrs)

    def count(self, query) -> int:
        """Count embeddings of one pattern query."""
        self._sct["queries"].inc()
        with self._query_span("count", query=str(query)):
            return self._runner.run(self.compile(query))

    def count_many(self, queries: Sequence) -> list[int]:
        """Count a batch of pattern queries in one fused forest pass.

        Results are positional and bit-identical to per-query ``count``
        calls on the same scheduled patterns."""
        self._sct["queries"].inc()
        with self._query_span("count_many", queries=len(queries)):
            return self._runner.run_set(self.schedule(queries))

    def _require_values(self) -> None:
        if self.graph.edge_values is None:
            raise ValueError(
                "aggregate queries need a weighted graph — build with "
                "edge_values (graph.build_csr(..., edge_values=...) or "
                "graph.with_edge_values)")

    def aggregate(self, query, op: str = "sum") -> float:
        """Reduce embedding values of one query with ``op`` ('sum' / 'max' /
        'min'); an embedding's value is the product of its pattern-edge
        weights. See the module docstring's "Value streams" section."""
        self._require_values()
        self._sct["queries"].inc()
        with self._query_span("aggregate", query=str(query), op=op):
            return self._runner.run(self.compile(query, aggregate=op))

    def aggregate_many(self, queries: Sequence, op: str = "sum") -> list:
        """Aggregate a batch of queries in one fused forest pass (same
        sharing as ``count_many``: aggregate leaves ride the shared
        expands, results positional)."""
        self._require_values()
        self._sct["queries"].inc()
        with self._query_span("aggregate_many", queries=len(queries), op=op):
            return self._runner.run_set(self.schedule(queries, aggregate=op))

    def embeddings(self, query) -> np.ndarray:
        """Enumerate embeddings of one query as an (N, k) int32 matrix."""
        self._sct["queries"].inc()
        with self._query_span("embeddings", query=str(query)):
            return self._runner.run(self.compile(query, emit=True))

    def run_plans(self, plans: Sequence[WavePlan]) -> list:
        """Execute pre-compiled plans (FSM's feed, power users): one plan
        runs directly, several fuse through a cached forest."""
        self._sct["queries"].inc()
        plans = list(plans)
        with self._query_span("run_plans", plans=len(plans)):
            if len(plans) == 1:
                return [self._runner.run(plans[0])]
            key = ("plans", tuple(p.canonical_key() for p in plans))
            forest = self._forests.get(key)
            if forest is None:
                self._sct["schedule_misses"].inc()
                forest = self._forests[key] = build_forest(plans)
            else:
                self._sct["schedule_hits"].inc()
            return self._runner.run_set(forest)

    # -------------------------------------------------------------- stats
    @property
    def runner(self) -> WaveRunner:
        """The session's execute-stage interpreter (stats, level_execs)."""
        return self._runner

    @property
    def stats(self) -> dict:
        """Session counters: pipeline-stage cache hits/misses, the
        executable cache (``exec_cache.misses`` == retraces), and the
        runner's dispatch/sync counters. Every scalar here is derived
        from ``self.metrics`` (legacy view — identical keys and values to
        the dicts this property historically assembled)."""
        # mirror the executable cache into gauges at snapshot time, so a
        # registry export (prometheus/trace) carries the retrace counters
        cache = self.exec_cache.snapshot()
        for k, v in cache.items():
            self.metrics.gauge(f"exec_cache_{k}").set(v)
        return {
            **self._stats,
            "mesh": mesh_signature(self.mesh),
            "exec_cache": cache,
            "retraces": self.exec_cache.misses,
            "runner": dict(self._runner.stats),
        }
