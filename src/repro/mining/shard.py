"""Mesh-sharded mining: data-parallel wavefronts over a 1-D device mesh.

The wavefront interpreter (``mining.engine.WaveRunner``) is embarrassingly
parallel over the level-1 edge feed: every edge's pattern-tree descent is
independent, and every per-level executable is already written as a pure
body over (prefix columns, carry, live count). ``ShardedWaveRunner``
exploits exactly that: it reuses the *unmodified* level bodies and wraps
each one's ``_jit_*`` dispatch hook in ``jax.experimental.shard_map`` over
a mesh axis (default ``"mine"``), so each device runs the identical wave
program on its local feed block:

  * the CSR graph is replicated (``PartitionSpec()``) — staged once per
    session, every shard intersects against its own copy;
  * wave buffers — prefix-column values, carries, compacted (src, verts)
    worklists — are sharded on the mining axis: a global ``(S * items,)``
    buffer holds ``S`` per-shard blocks back to back;
  * count leaves reduce their (hi, lo) partials with ``jax.lax.psum``
    over the mining axis. Per-shard hi words can reach 2^30, so an 8-way
    int32 psum could wrap: partials are split into four 16-bit limbs
    *before* the psum (limb sums stay far below 2^31) and reassembled
    exactly on the host (``WaveRunner._finalize``);
  * expand levels return their level-boundary meta per shard (an (S, m)
    row block): per-shard live totals drive lockstep chunking (every
    shard walks ``ceil(max_totals / chunk)`` steps; shards past their own
    total carry bound-0 padding and contribute nothing), while next-level
    capacities take the max over shards — capacities are upper bounds, so
    the widening is lossless;
  * emit levels gather per-shard survivor blocks on the host (one bulk
    pull per chunk, then a per-shard slice to each live total).

Orchestration stays on the host and stays *identical* to the single-device
interpreter — same plan descent, same forest fan-out, same residual packs —
because the only per-shard state it tracks is the live-total vector
(``_pack_total``). Counts are therefore bit-identical to the single-device
session: the same integer summands, grouped differently.

The level-1 feed is dealt by ``shard_edge_steps``: per degree bucket,
edges are round-robin dealt across shards (CSR edge order is sorted by
source vertex, so a hub's edge run would land on one shard under a
contiguous split — the dealt assignment bounds the per-step imbalance at
one item). ``stats["shard_feed_items"]`` exposes the per-shard feed item
counts so the balance is measurable; ``mode="contiguous"`` keeps the
chunk-granular contiguous assignment as the measurable foil.

Use via the session API (``Miner(g, mesh=8)``); the mesh itself comes from
``repro.distributed.sharding.make_mining_mesh`` and its axes are part of
every executable-cache key (``session.mesh_signature``), so sharded and
unsharded executables never collide and repeated sharded queries retrace
nothing.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.stream import round_capacity
from repro.graph.csr import CSRGraph
from .engine import WaveRunner, _pow2cap, directed_edges, half_edges

__all__ = ["ShardedWaveRunner", "shard_edge_steps"]

FEED_PARTITIONS = ("round_robin", "contiguous")


def shard_edge_steps(g: CSRGraph, chunk: int, shards: int,
                     symmetric: bool = True, mode: str = "round_robin"):
    """Level-1 feed for an ``shards``-way mesh: yields lockstep super-steps
    ``(cap, v0, v1, n)`` where ``v0``/``v1`` are (shards * nb,) int32 arrays
    holding one nb-item block per shard back to back, and ``n`` is the
    (shards,) per-shard live count.

    Per degree bucket of E edges the block width is
    ``nb = min(chunk, pow2cap(ceil(E / shards)))`` — the bucket's work
    divided across the mesh, so a sharded pass takes ~``1/shards`` the
    super-steps of the single-device feed (the dispatch-scaling contract
    gated in benchmarks/ci_gate.py). Each super-step spans
    ``shards * nb`` consecutive bucket edges:

    * ``round_robin`` (default): shard s takes ``step_edges[s::shards]``.
      CSR edge order groups a vertex's edges consecutively, so dealing
      spreads every hub's run across the whole mesh; per-step imbalance
      is at most one item.
    * ``contiguous``: shard s takes the s-th contiguous nb-slice — the
      hub-pinning foil (a partial step loads low shards and leaves high
      shards empty) kept for the load-balance benchmark.

    Both modes enumerate the same edge multiset; only the edge -> shard
    assignment differs, so counts are unaffected.
    """
    if mode not in FEED_PARTITIONS:
        raise ValueError(f"feed_partition must be one of {FEED_PARTITIONS}, "
                         f"got {mode!r}")
    edges = half_edges(g) if symmetric else directed_edges(g)
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    caps = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    for cap in np.unique(caps):
        sel = edges[caps == cap]
        e = sel.shape[0]
        nb = min(chunk, _pow2cap(max(-(-e // shards), 1)))
        span = shards * nb
        for lo in range(0, e, span):
            blk = sel[lo: lo + span]
            v0 = np.zeros((shards, nb), np.int32)
            v1 = np.zeros((shards, nb), np.int32)
            n = np.zeros((shards,), np.int32)
            for s in range(shards):
                part = blk[s::shards] if mode == "round_robin" \
                    else blk[s * nb: (s + 1) * nb]
                k = part.shape[0]
                n[s] = k
                v0[s, :k] = part[:, 0]
                v1[s, :k] = part[:, 1]
            yield int(cap), v0.reshape(-1), v1.reshape(-1), n


class ShardedWaveRunner(WaveRunner):
    """``WaveRunner`` with every executable wrapped in ``shard_map``.

    See the module docstring for the sharding contract. Only the dispatch
    hooks (``_jit_*``), the feed, and the boundary-meta plumbing differ
    from the base interpreter — the traced level bodies are shared, so the
    two runners cannot drift semantically.
    """

    def __init__(self, g: CSRGraph, mesh, *, axis: str = "mine",
                 feed_partition: str = "round_robin",
                 chunk: int | None = None, backend: str = "auto",
                 device_compact: bool = True, record: bool = False,
                 fused_level: bool = True, exec_cache=None, telemetry=None):
        if not device_compact:
            raise ValueError(
                "ShardedWaveRunner requires device_compact=True: the host "
                "np.nonzero oracle is inherently single-device")
        if record:
            raise ValueError(
                "ShardedWaveRunner does not support record=True (wave "
                "traces are per-shard; record on the single-device runner)")
        if axis not in dict(mesh.shape):
            raise ValueError(f"axis {axis!r} not in mesh axes "
                             f"{tuple(dict(mesh.shape))}")
        if feed_partition not in FEED_PARTITIONS:
            raise ValueError(f"feed_partition must be one of "
                             f"{FEED_PARTITIONS}, got {feed_partition!r}")
        # pallas kernel calls inside shard_map are unvalidated here; 'auto'
        # resolves to the xla lowering, explicit 'pallas' is honoured
        super().__init__(g, chunk=chunk,
                         backend="xla" if backend == "auto" else backend,
                         device_compact=True, record=False,
                         fused_level=fused_level, exec_cache=exec_cache,
                         telemetry=telemetry)
        self.mesh = mesh
        self.axis = axis
        self.feed_partition = feed_partition
        self._shards = int(dict(mesh.shape)[axis])
        self._exec_prefix = ("mesh", axis, self._shards)
        self._psh = P(axis)          # sharded on the mining axis
        self._prp = P()              # replicated
        self._rep_sharding = NamedSharding(mesh, self._prp)
        self._feed_sharding = NamedSharding(mesh, self._psh)
        # replicate the CSR buffers across the mesh once per runner
        self.g = jax.device_put(g, self._rep_sharding)
        # mesh-only metrics: the psum counter joins the legacy view as a
        # plain counter; the per-shard feed tallies are a LABELED series
        # (one counter per shard) whose legacy key derives the historical
        # list-of-ints shape from the series
        self._ct["psum_reductions"] = self.stats.expose_counter(
            "psum_reductions", self.metrics)
        self._shard_feed = [self.metrics.counter("shard_feed_items", shard=s)
                            for s in range(self._shards)]
        self.stats.expose("shard_feed_items",
                          lambda: [c.value for c in self._shard_feed])

    # ----------------------------------------------------------- dispatch
    def _shmap(self, body: Callable, in_specs, out_specs) -> Callable:
        return jax.jit(shard_map(body, mesh=self.mesh,
                                 in_specs=in_specs, out_specs=out_specs,
                                 check_rep=False))

    def _level_in_specs(self, op):
        """(g, vals, carry, n) specs shared by count/expand/emit hooks:
        replicated graph, sharded prefix-value columns, sharded carry (a
        replicated zero scalar when the level has none), per-shard n."""
        psh, prp = self._psh, self._prp
        return (prp, (psh,) * len(self._in_cols(op)),
                psh if op.use_carry else prp, psh)

    def _jit_count(self, op, body):
        axis = self.axis

        def wrapped(g, vals, carry, n):
            part = body(g, vals, carry, n)
            # 16-bit limb split BEFORE the psum: per-shard hi can reach
            # 2^30, limb sums stay < 2^19 (hi) / 2^31 (lo) at any mesh size
            limbs = jnp.stack([part[0] >> 16, part[0] & 0xFFFF,
                               part[1] >> 16, part[1] & 0xFFFF])
            return jax.lax.psum(limbs, axis)
        return self._shmap(wrapped, self._level_in_specs(op), self._prp)

    def _jit_agg(self, op, body):
        axis = self.axis
        red = {"sum": jax.lax.psum, "max": jax.lax.pmax,
               "min": jax.lax.pmin}[op.agg]

        def wrapped(g, vals, carry, n):
            part = body(g, vals, carry, n)      # (2,) f32 [value, live]
            # value reduces with the leaf's own op (a dead shard carries the
            # op identity, so pmax/pmin absorb it); live always psums —
            # finalize gates the identity out when the whole mesh is dead
            return jnp.stack([red(part[0], axis),
                              jax.lax.psum(part[1], axis)])
        return self._shmap(wrapped, self._level_in_specs(op), self._prp)

    def _jit_expand(self, op, body, want_count):
        def wrapped(g, vals, carry, n):
            rows2, src, verts, meta = body(g, vals, carry, n)
            # per-shard meta row: host sees the (shards, m) block
            return rows2, src, verts, meta.reshape(1, -1)
        psh = self._psh
        return self._shmap(wrapped, self._level_in_specs(op),
                           (psh, psh, psh, psh))

    def _jit_emit(self, op, body):
        def wrapped(g, vals, carry, n):
            emb, total = body(g, vals, carry, n)
            return emb, total.reshape(1)
        psh = self._psh
        return self._shmap(wrapped, self._level_in_specs(op), (psh, psh))

    def _jit_chunk(self, op, body):
        psh, prp = self._psh, self._prp
        ncv = len([c for c in op.out_cols if c < op.level])
        out = ((psh,) * ncv, psh) + ((psh,) if op.carry_out else ())
        return self._shmap(body, (psh, psh, psh, (psh,) * ncv, prp, psh),
                           out)

    def _jit_rpack(self, body, nrefs):
        def wrapped(rvals, src, verts, total):
            src2, verts2, tot = body(rvals, src, verts, total)
            return src2, verts2, tot.reshape(1)
        psh = self._psh
        return self._shmap(wrapped, ((psh,) * nrefs, psh, psh, psh),
                           (psh, psh, psh))

    def _bump(self, op, host: bool = False) -> None:
        super()._bump(op, host)
        if op.kind == "count":
            self._ct["psum_reductions"].inc()

    # --------------------------------------------------------------- feed
    def _edge_feed(self, symmetric: bool = True):
        """Sharded level-1 feed: per-shard edge blocks are laid out back to
        back and ``device_put`` with the mining-axis sharding (still
        double-buffered — step N+1's shard transfers dispatch while the
        mesh computes step N). ``n`` is the per-shard live-count vector."""
        sh = self._feed_sharding
        feed = self._shard_feed

        def gen():
            for cap, v0, v1, n in shard_edge_steps(
                    self.g, self.chunk, self._shards, symmetric,
                    self.feed_partition):
                for s in range(self._shards):
                    feed[s].inc(int(n[s]))
                yield (cap, jax.device_put(v0, sh), jax.device_put(v1, sh),
                       v1, n)
        return self._double_buffered(gen(), frozenset())

    # ------------------------------------------------- boundary-meta plumbing
    def _pack_total(self, tot):
        tot = np.asarray(tot, dtype=np.int64).reshape(-1)
        return tot, bool(tot.max() > 0)

    def _expand_device(self, op, caps_sig, cap_base, out_cap, out_items,
                       vals, carry_in, n, want_count: bool = False):
        """Sharded twin of the base meta sync: ``meta`` arrives as one
        (shards, m) row block. Per-shard live totals come back as a vector
        (they drive lockstep chunking); capacities take the max over shards
        (upper bounds — lossless); ride partials are summed exactly on the
        host (they already crossed in the meta sync, no extra collective)."""
        self._bump(op)
        fn = self._plan_expand_fn(op, caps_sig, cap_base, out_cap, out_items,
                                  want_count)
        rows2, src, verts2, meta = self._dispatch(
            op, fn, (self.g, vals, carry_in, n), items=n, caps_sig=caps_sig)
        meta = np.asarray(meta).astype(np.int64)        # (shards, m)
        if want_count:
            meta, rpart = meta[:, :-2], meta[:, -2:].sum(axis=0)
            ride = np.asarray(rpart)                     # (hi_sum, lo_sum)
        else:
            ride = None
        totals = meta[:, 0]
        maxc = int(meta[:, 1].max())
        dmaxs = meta[:, 2:].max(axis=0)
        self._ct["host_syncs"].inc()
        self._ct["device_compactions"].inc()
        self._ct["items"].inc(int(totals.sum()))
        self._h_wave_items.observe(int(totals.sum()))
        if int(totals.max()) == 0:
            return None
        caps2 = {c: _pow2cap(max(int(d), 1))
                 for c, d in zip(op.gather_refs, dmaxs)}
        cap2 = round_capacity(maxc) if op.carry_out else 0
        return rows2, src, verts2, totals, caps2, cap2, ride

    def _expand_chunks(self, op, b, out_cap, cap2, rows2, src, verts2, cols,
                       totals):
        """Lockstep worklist chunking: every shard slices the SAME [lo, lo +
        chunk) window of its local compacted worklist; the per-shard live
        width ``m`` masks shards already past their own total (their padding
        items carry bound 0 downstream). ``ceil(max_totals / chunk)`` steps
        — the shard with the most survivors sets the wavefront length."""
        cfn = self._plan_chunk_fn(op, b, out_cap, cap2, self.chunk)
        fwdvals = tuple(cols[c] for c in op.out_cols if c < op.level)
        totals = np.asarray(totals, dtype=np.int64).reshape(-1)
        for lo in range(0, int(totals.max()), self.chunk):
            m = np.clip(totals - lo, 0, self.chunk).astype(np.int32)
            if op.carry_out:
                outs, vch, carry2 = cfn(rows2, src, verts2, fwdvals, lo, m)
            else:
                outs, vch = cfn(rows2, src, verts2, fwdvals, lo, m)
                carry2 = None
            cols2 = dict(zip([c for c in op.out_cols if c < op.level], outs))
            if op.level in op.out_cols:
                cols2[op.level] = vch
            yield cols2, carry2, vch, m

    def _plan_emit(self, op, caps_sig, cap_base, out_cap, out_items, cols,
                   vals, carry_in, n) -> list:
        """Terminal emit: one bulk embedding pull, then per-shard survivor
        blocks sliced to each shard's live total."""
        self._bump(op)
        fn = self._plan_emit_fn(op, caps_sig, cap_base, out_cap, out_items)
        emb, totals = self._dispatch(op, fn, (self.g, vals, carry_in, n),
                                     items=n, caps_sig=caps_sig)
        totals = np.asarray(totals, dtype=np.int64).reshape(-1)
        self._ct["device_compactions"].inc()
        self._ct["items"].inc(int(totals.sum()))
        self._h_wave_items.observe(int(totals.sum()))
        if int(totals.max()) == 0:
            return []
        emb = np.asarray(emb)
        blocks = []
        for s, t in enumerate(totals):
            if t:
                blocks.append(emb[s * out_items: s * out_items + int(t)])
        return [np.concatenate(blocks, axis=0)]
