"""Public mining API — the stable surface.

``repro.mining`` is the package boundary user code imports from; the
names in ``__all__`` are the supported query API:

* ``Miner`` / ``MinerConfig`` — the graph-resident session (compile ->
  schedule -> execute, every stage cached; ``MinerConfig`` is the one
  place construction knobs live).
* ``MiningService`` — the concurrent service over a pool of sessions
  (``repro.serving``): thread-safe submit, cross-request forest batching
  per tick, result cache, admission control.
* ``Pattern`` / ``Motif`` / ``compile_pattern`` (+ the ``pattern`` /
  ``motif`` builders) — the declarative query language the session
  resolves.
* ``PlanForest`` / ``build_forest`` / ``schedule_patterns`` — the
  multi-pattern fusion layer (power users; the session calls these).

The historical one-shot helpers (``apps.triangle_count`` and friends)
are deprecated shims over ``Miner`` — importable, but each call emits a
``DeprecationWarning``. ``apps.shared_session`` (the per-graph session
pool behind them) remains supported.
"""
from . import reference
from .exhaustive import exhaustive_count
from .forest import PlanForest, build_forest, schedule_patterns
from .fsm import fsm, sfsm
from .plan import (FOUR_MOTIF_SHAPES, FOUR_MOTIFS, Motif, Pattern, WavePlan,
                   compile_pattern, motif, pattern, resolve_query)
from .session import ExecutableCache, Miner, MinerConfig

__all__ = [
    # the session + service query API (the stable core)
    "Miner", "MinerConfig", "MiningService",
    # the query language
    "Pattern", "Motif", "WavePlan", "compile_pattern", "motif", "pattern",
    "resolve_query", "FOUR_MOTIFS", "FOUR_MOTIF_SHAPES",
    # fusion layer (power users)
    "PlanForest", "build_forest", "schedule_patterns", "ExecutableCache",
    # workloads over the session
    "fsm", "sfsm", "exhaustive_count", "reference",
]

# legacy names re-exported for source compatibility; the one-shot helpers
# among them warn on each CALL (importing does not). shared_session stays
# supported — it is the session pool, not a one-shot shim.
_APPS_REEXPORTS = (
    "clique_count", "four_motif", "pattern_count", "pattern_embeddings",
    "pattern_set_count", "pattern_set_run", "shared_session",
    "tailed_triangle_count", "three_chain_count", "three_motif",
    "triangle_count", "triangle_count_nested", "triangle_list",
)


def __getattr__(name: str):
    if name == "MiningService":
        # lazy: repro.serving imports this package (sessions, patterns) —
        # resolving the service on first touch keeps the surface flat
        # without an import cycle
        from repro.serving import MiningService
        return MiningService
    if name in _APPS_REEXPORTS or name == "apps":
        from . import apps
        return apps if name == "apps" else getattr(apps, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
