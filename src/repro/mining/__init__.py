from .apps import (
    clique_count,
    tailed_triangle_count,
    three_chain_count,
    three_motif,
    triangle_count,
    triangle_count_nested,
)
from .fsm import fsm, sfsm
from .exhaustive import exhaustive_count
from . import reference

__all__ = [
    "triangle_count", "triangle_count_nested", "three_chain_count",
    "tailed_triangle_count", "three_motif", "clique_count",
    "fsm", "sfsm", "exhaustive_count", "reference",
]
