from .apps import (
    clique_count,
    four_motif,
    pattern_count,
    pattern_embeddings,
    pattern_set_count,
    pattern_set_run,
    tailed_triangle_count,
    three_chain_count,
    three_motif,
    triangle_count,
    triangle_count_nested,
    triangle_list,
)
from .plan import FOUR_MOTIFS, Pattern, WavePlan, compile_pattern, pattern
from .forest import PlanForest, build_forest
from .fsm import fsm, sfsm
from .exhaustive import exhaustive_count
from . import reference

__all__ = [
    "triangle_count", "triangle_count_nested", "three_chain_count",
    "tailed_triangle_count", "three_motif", "clique_count", "four_motif",
    "pattern_count", "pattern_embeddings", "pattern_set_count",
    "pattern_set_run", "triangle_list",
    "Pattern", "WavePlan", "compile_pattern", "pattern", "FOUR_MOTIFS",
    "PlanForest", "build_forest",
    "fsm", "sfsm", "exhaustive_count", "reference",
]
