from .apps import (
    clique_count,
    four_motif,
    pattern_count,
    pattern_embeddings,
    pattern_set_count,
    pattern_set_run,
    shared_session,
    tailed_triangle_count,
    three_chain_count,
    three_motif,
    triangle_count,
    triangle_count_nested,
    triangle_list,
)
from .plan import (FOUR_MOTIF_SHAPES, FOUR_MOTIFS, Motif, Pattern, WavePlan,
                   compile_pattern, motif, pattern)
from .forest import PlanForest, build_forest, schedule_patterns
from .session import ExecutableCache, Miner, MinerConfig
from .fsm import fsm, sfsm
from .exhaustive import exhaustive_count
from . import reference

__all__ = [
    "triangle_count", "triangle_count_nested", "three_chain_count",
    "tailed_triangle_count", "three_motif", "clique_count", "four_motif",
    "pattern_count", "pattern_embeddings", "pattern_set_count",
    "pattern_set_run", "triangle_list", "shared_session",
    "Motif", "Pattern", "WavePlan", "compile_pattern", "motif", "pattern",
    "FOUR_MOTIFS", "FOUR_MOTIF_SHAPES",
    "PlanForest", "build_forest", "schedule_patterns",
    "ExecutableCache", "Miner", "MinerConfig",
    "fsm", "sfsm", "exhaustive_count", "reference",
]
