"""The paper's mining applications (§VI-B) + 4-motif mining, as patterns.

Every function here is now a **deprecated thin shim** over the session API
(``mining.session.Miner``): each delegates to a module-level per-graph
session (``shared_session``), so the old one-shot surface keeps its exact
behaviour for existing tests/benchmarks while gaining session semantics —
the graph is staged to device once, executables are cached across calls,
and multi-pattern batches are scheduled by the automatic matching-order
search. New code should hold a ``Miner`` directly:

    from repro.mining.session import Miner
    m = Miner(g)
    m.count("triangle"); m.count_many(["diamond", "paw"]) ...

The only hand-written paths left are genuine closed forms (non-induced
three-chain = Σ C(deg, 2)) and the host ``triangle_list_host`` oracle the
device enumeration is property-tested against.

All counts are exact and each embedding is counted once (symmetry breaking
via compiled upper/lower-bound restrictions, Fig. 2b's R3 operand), except
the explicitly paper-faithful *nested* variants which reproduce the
Fig. 4a unbounded S_NESTINTER dataflow and divide by the automorphism
count (``Pattern.div``).

Definitions (verified against brute-force oracles in tests):
  triangle           unordered vertex triples, mutually adjacent
  three-chain        non-induced: paths a—m—b (a<b);  induced: additionally
                     (a,b) ∉ E   (3-motif uses the induced count)
  tailed triangle    triangle {v0,v1,v2} + edge (v1,v3), v3 ∉ {v0,v2}; the
                     pattern automorphism (v0<->v2) is broken with v2 < v0
  k-clique           complete subgraphs of size k, counted once
  4-motif            induced counts of the six connected 4-vertex motifs
                     (4-path, 4-star, 4-cycle, paw, diamond, 4-clique)
"""
from __future__ import annotations

import warnings
import weakref
from collections import OrderedDict

import numpy as np

from repro.graph.csr import CSRGraph
from .engine import Wave, choose_chunk, compact, expand, half_edges, \
    pair_wave
from .forest import PlanForest
from .plan import FOUR_MOTIF_SHAPES, Pattern, TAILED_TRIANGLE, \
    THREE_CHAIN_INDUCED, TRIANGLE, TRIANGLE_NESTED, WavePlan, \
    clique_pattern, compile_pattern
from .session import Miner

def _deprecated(name: str) -> None:
    """One-shot shim warning: every call on the legacy surface points at
    the stable API (``repro.mining.Miner`` / ``MiningService``). Emitted
    per call, not per import, so merely importing this module (the FSM
    feed lives here) stays silent."""
    warnings.warn(
        f"repro.mining.apps.{name} is deprecated; hold a session instead: "
        "repro.mining.Miner(g).count(...) (or MiningService for "
        "concurrent traffic)", DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# the module-level session pool backing the deprecated one-shot surface
# ---------------------------------------------------------------------------

# (id(graph), chunk, device_compact) -> (weakref to graph, Miner). The
# weakref guards against id() reuse after the original graph is collected;
# a small LRU bounds how many sessions (device stagings + exec caches) the
# shim surface keeps alive at once.
_SESSION_POOL: OrderedDict = OrderedDict()
_SESSION_POOL_CAP = 8


def shared_session(g: CSRGraph, chunk: int | None = None,
                   device_compact: bool = True) -> Miner:
    """Get-or-create the module-level ``Miner`` for (graph, config).

    This is what makes the legacy free functions sessions in disguise:
    every call over the same graph and config lands on one ``Miner``, so
    graph staging, compiled plans, schedules and executables are all
    reused across calls."""
    key = (id(g), chunk, device_compact)
    ent = _SESSION_POOL.get(key)
    if ent is not None and ent[0]() is g:
        _SESSION_POOL.move_to_end(key)
        return ent[1]
    miner = Miner(g, chunk=chunk, device_compact=device_compact)
    _SESSION_POOL[key] = (weakref.ref(g), miner)
    while len(_SESSION_POOL) > _SESSION_POOL_CAP:
        _SESSION_POOL.popitem(last=False)
    return miner


def pattern_count(g: CSRGraph, pat: Pattern, chunk: int | None = None,
                  device_compact: bool = True) -> int:
    """Deprecated shim: ``Miner.count`` on the shared session."""
    _deprecated("pattern_count")
    return shared_session(g, chunk, device_compact).count(pat)


def pattern_embeddings(g: CSRGraph, pat: Pattern, chunk: int | None = None,
                       device_compact: bool = True) -> np.ndarray:
    """Deprecated shim: ``Miner.embeddings`` on the shared session."""
    _deprecated("pattern_embeddings")
    return shared_session(g, chunk, device_compact).embeddings(pat)


def pattern_set_run(g: CSRGraph, plans: list[WavePlan] | PlanForest,
                    chunk: int | None = None,
                    device_compact: bool = True) -> list:
    """Deprecated shim: run a batch of compiled plans (or a pre-built
    ``PlanForest``) as one fused pass on the shared session. Results come
    back per plan, in order — ints for counting plans, (N, k) matrices for
    emit plans — bit-identical to independent ``Miner.count`` runs."""
    _deprecated("pattern_set_run")
    miner = shared_session(g, chunk, device_compact)
    if isinstance(plans, PlanForest):
        return miner.runner.run_set(plans)
    return miner.run_plans(plans)


def pattern_set_count(g: CSRGraph, pats: list[Pattern],
                      chunk: int | None = None,
                      device_compact: bool = True) -> list[int]:
    """Deprecated shim: ``Miner.count_many`` on the shared session."""
    _deprecated("pattern_set_count")
    return shared_session(g, chunk, device_compact).count_many(pats)


def triangle_count(g: CSRGraph, chunk: int | None = None,
                   device_compact: bool = True) -> int:
    """Symmetry-broken triangle counting: one bounded intersection per half
    edge (v0 > v1), bound v1 => each triangle v0 > v1 > v2 counted once."""
    _deprecated("triangle_count")
    return shared_session(g, chunk, device_compact).count(TRIANGLE)


def triangle_count_nested(g: CSRGraph, chunk: int | None = None) -> int:
    """Paper-faithful Fig. 4a: Σ_v S_NESTINTER(N(v)) counts each triangle 6x.

    The per-vertex nested instruction flattens to one unbounded intersection
    per *directed* edge — exactly the µop stream §IV-F's translator emits —
    and ``TRIANGLE_NESTED.div`` divides the automorphisms out at retire."""
    _deprecated("triangle_count_nested")
    return shared_session(g, chunk).count(TRIANGLE_NESTED)


def three_chain_count(g: CSRGraph, induced: bool = False,
                      chunk: int | None = None) -> int:
    """Three-chain (path) counting.

    non-induced: Σ_m C(deg m, 2) — closed form (no intersection needed; the
    stream engine is exercised by the induced variant).
    induced: the compiled SUB + lower-bound plan (b ∈ N(m), b ∉ N(a), b > a).
    """
    _deprecated("three_chain_count")
    deg = np.asarray(g.degrees, dtype=np.int64)
    non_induced = int((deg * (deg - 1) // 2).sum())
    if not induced:
        return non_induced
    return shared_session(g, chunk).count(THREE_CHAIN_INDUCED)


def tailed_triangle_count(g: CSRGraph, chunk: int | None = None) -> int:
    """Fig. 2b dataflow: per directed edge (v0,v1), BoundedIntersect(N0,N1,v0)
    yields the v2 < v0 candidates; the tail level folds into the closed-form
    deg(v1) - 2 multiplier at compile time."""
    _deprecated("tailed_triangle_count")
    return shared_session(g, chunk).count(TAILED_TRIANGLE)


def three_motif(g: CSRGraph, fused: bool = True) -> dict[str, int]:
    """3-motif mining: counts of both connected 3-vertex induced motifs.

    ``fused`` routes both patterns through one session batch (a fused
    ``PlanForest``); ``fused=False`` keeps the independent per-plan path
    (the baseline the forest is benchmarked and property-tested against)."""
    _deprecated("three_motif")
    if fused:
        t, chains = shared_session(g).count_many(
            [TRIANGLE, THREE_CHAIN_INDUCED])
    else:
        t = triangle_count(g)
        chains = three_chain_count(g, induced=True)
    return {"triangle": t, "chain": chains}


def clique_count(g: CSRGraph, k: int, chunk: int | None = None,
                 device_compact: bool = True) -> int:
    """k-clique counting, k >= 3: the compiled chain-restricted plan. Every
    level reuses the parent's survivor stream (the compiler's carry
    analysis), so the interpreter issues the exact executable sequence the
    old hand-coded engine did. ``device_compact=False`` routes the same plan
    through the host np.nonzero oracle."""
    _deprecated("clique_count")
    if k < 3:
        raise ValueError("clique_count needs k >= 3")
    return shared_session(g, chunk, device_compact).count(clique_pattern(k))


def four_motif(g: CSRGraph, chunk: int | None = None,
               fused: bool = True) -> dict[str, int]:
    """4-motif mining: induced counts of all six connected 4-vertex motifs.

    The motifs are adjacency-only shapes (``plan.FOUR_MOTIF_SHAPES``); the
    session's schedule stage picks each one's matching order automatically
    so the batch collapses to three shared level-2 expands over two
    edge-feed passes. ``fused=False`` runs the same auto-scheduled patterns
    independently — same counts, kept as the comparison baseline."""
    _deprecated("four_motif")
    miner = shared_session(g, chunk)
    if fused:
        counts = miner.count_many(list(FOUR_MOTIF_SHAPES))
        return dict(zip(FOUR_MOTIF_SHAPES, counts))
    from . import plan as P
    return {name: miner.count(P.FOUR_MOTIFS[name])
            for name in FOUR_MOTIF_SHAPES}


# the FSM pattern batch: every engine-fed plan FSM's support evaluation
# consumes, merged into one forest (a single feed pass). Today that is the
# triangle emit plan — wedge/star/path domains are closed forms over the
# neighbor-label count table — but additional engine-fed patterns join the
# same batch (and share its prefixes) by appending here.
FSM_FEED_PLANS: tuple = (compile_pattern(TRIANGLE, emit=True),)


def fsm_pattern_feed(g: CSRGraph, chunk: int | None = None,
                     miner: Miner | None = None) -> list:
    """Run the FSM engine-feed batch on a session; returns per-plan results
    in ``FSM_FEED_PLANS`` order (triangle embeddings first). ``miner``
    reuses a caller-held session (FSM passes its own)."""
    miner = miner or shared_session(g, chunk)
    return miner.run_plans(list(FSM_FEED_PLANS))


def triangle_list(g: CSRGraph, chunk: int | None = None) -> np.ndarray:
    """Enumerate all triangles as (T, 3) vertex triples (v0 > v1 > v2).

    Used by FSM (labelled support needs embeddings, not counts). Runs the
    triangle *emit* plan through the session: compaction happens on device
    via ``ops.xinter_compact``'s src output, and only the compacted
    embedding matrix crosses to the host."""
    _deprecated("triangle_list")
    return fsm_pattern_feed(g, chunk)[0]


def triangle_list_host(g: CSRGraph, chunk: int | None = None) -> np.ndarray:
    """Host-compaction oracle for ``triangle_list`` (np.nonzero +
    ``compact(return_src=True)``) — kept as the property-test reference for
    the device emit path."""
    chunk = chunk or choose_chunk(g.padded_max_degree)
    out = []
    for rows0, rows1, v0, v1, n in pair_wave(g, half_edges(g), chunk):
        wave = Wave(rows=np.asarray(rows0), verts=v1)
        rows2, counts2 = expand(g, wave)
        w2, ii = compact(rows2, counts2, limit=n, return_src=True)
        if w2 is None:
            continue
        out.append(np.stack([v0[ii], v1[ii], w2.verts], axis=1))
    if not out:
        return np.zeros((0, 3), dtype=np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)
