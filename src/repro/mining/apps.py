"""The paper's mining applications (§VI-B) + 4-motif mining, as patterns.

Every app is now a *declarative pattern definition* compiled by
``mining.plan`` and interpreted by ``mining.engine.WaveRunner.run`` — no app
has engine code of its own. Multi-pattern apps (3-motif, 4-motif, the FSM
feed) additionally fuse their batches through the ``mining.forest``
scheduler (``pattern_set_count``/``pattern_set_run``): one edge-feed pass
per orientation, shared canonical-prefix expands, bit-identical counts.
The only hand-written paths left are genuine closed forms (non-induced
three-chain = Σ C(deg, 2)) and the host ``triangle_list_host`` oracle the
device enumeration is property-tested against.

All counts are exact and each embedding is counted once (symmetry breaking
via the compiled upper/lower-bound restrictions, Fig. 2b's R3 operand),
except the explicitly paper-faithful *nested* variants which reproduce the
Fig. 4a unbounded S_NESTINTER dataflow and divide by the automorphism count
(``Pattern.div``).

Definitions (verified against brute-force oracles in tests):
  triangle           unordered vertex triples, mutually adjacent
  three-chain        non-induced: paths a—m—b (a<b);  induced: additionally
                     (a,b) ∉ E   (3-motif uses the induced count)
  tailed triangle    triangle {v0,v1,v2} + edge (v1,v3), v3 ∉ {v0,v2}; the
                     pattern automorphism (v0<->v2) is broken with v2 < v0
  k-clique           complete subgraphs of size k, counted once
  4-motif            induced counts of the six connected 4-vertex motifs
                     (4-path, 4-star, 4-cycle, paw, diamond, 4-clique)
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph
from .engine import Wave, WaveRunner, choose_chunk, compact, expand, \
    half_edges, pair_wave
from .forest import PlanForest, build_forest
from .plan import (FOUR_MOTIFS, Pattern, TAILED_TRIANGLE,
                   THREE_CHAIN_INDUCED, TRIANGLE, TRIANGLE_NESTED, WavePlan,
                   clique_pattern, compile_pattern)


def pattern_count(g: CSRGraph, pat: Pattern, chunk: int | None = None,
                  device_compact: bool = True) -> int:
    """Count embeddings of any declarative ``Pattern`` on the wave engine."""
    runner = WaveRunner(g, chunk, device_compact=device_compact)
    return runner.run(compile_pattern(pat))


def pattern_embeddings(g: CSRGraph, pat: Pattern, chunk: int | None = None,
                       device_compact: bool = True) -> np.ndarray:
    """Enumerate embeddings of ``pat`` as an (N, k) matrix (emit plan)."""
    runner = WaveRunner(g, chunk, device_compact=device_compact)
    return runner.run(compile_pattern(pat, emit=True))


# built tries memoised on the batch's canonical plan keys: repeated calls
# (four_motif per dataset sweep, FSM's per-level feeds) skip the merge
_FOREST_CACHE: dict[tuple, PlanForest] = {}


def _forest_for(plans: list[WavePlan]) -> PlanForest:
    key = tuple(p.canonical_key() for p in plans)
    forest = _FOREST_CACHE.get(key)
    if forest is None:
        forest = _FOREST_CACHE[key] = build_forest(plans)
    return forest


def pattern_set_run(g: CSRGraph, plans: list[WavePlan] | PlanForest,
                    chunk: int | None = None,
                    device_compact: bool = True) -> list:
    """Run a *batch* of compiled plans as one fused ``PlanForest``.

    The batch shares one edge-feed pass per orientation and every
    canonical-prefix expand (``mining.forest``); results come back per plan,
    in order — ints for counting plans, (N, k) matrices for emit plans —
    bit-identical to running each plan through ``WaveRunner.run`` alone."""
    forest = plans if isinstance(plans, PlanForest) else _forest_for(plans)
    runner = WaveRunner(g, chunk, device_compact=device_compact)
    return runner.run_set(forest)


def pattern_set_count(g: CSRGraph, pats: list[Pattern],
                      chunk: int | None = None,
                      device_compact: bool = True) -> list[int]:
    """Count several declarative ``Pattern``s in one fused forest pass."""
    return pattern_set_run(g, [compile_pattern(p) for p in pats], chunk,
                           device_compact)


def triangle_count(g: CSRGraph, chunk: int | None = None,
                   device_compact: bool = True) -> int:
    """Symmetry-broken triangle counting: one bounded intersection per half
    edge (v0 > v1), bound v1 => each triangle v0 > v1 > v2 counted once."""
    return pattern_count(g, TRIANGLE, chunk, device_compact)


def triangle_count_nested(g: CSRGraph, chunk: int | None = None) -> int:
    """Paper-faithful Fig. 4a: Σ_v S_NESTINTER(N(v)) counts each triangle 6x.

    The per-vertex nested instruction flattens to one unbounded intersection
    per *directed* edge — exactly the µop stream §IV-F's translator emits —
    and ``TRIANGLE_NESTED.div`` divides the automorphisms out at retire."""
    return pattern_count(g, TRIANGLE_NESTED, chunk)


def three_chain_count(g: CSRGraph, induced: bool = False,
                      chunk: int | None = None) -> int:
    """Three-chain (path) counting.

    non-induced: Σ_m C(deg m, 2) — closed form (no intersection needed; the
    stream engine is exercised by the induced variant).
    induced: the compiled SUB + lower-bound plan (b ∈ N(m), b ∉ N(a), b > a).
    """
    deg = np.asarray(g.degrees, dtype=np.int64)
    non_induced = int((deg * (deg - 1) // 2).sum())
    if not induced:
        return non_induced
    return pattern_count(g, THREE_CHAIN_INDUCED, chunk)


def tailed_triangle_count(g: CSRGraph, chunk: int | None = None) -> int:
    """Fig. 2b dataflow: per directed edge (v0,v1), BoundedIntersect(N0,N1,v0)
    yields the v2 < v0 candidates; the tail level folds into the closed-form
    deg(v1) - 2 multiplier at compile time."""
    return pattern_count(g, TAILED_TRIANGLE, chunk)


def three_motif(g: CSRGraph, fused: bool = True) -> dict[str, int]:
    """3-motif mining: counts of both connected 3-vertex induced motifs.

    ``fused`` routes both patterns through one ``PlanForest``
    (``engine.run_set``) so the batch is a single scheduler invocation;
    ``fused=False`` keeps the independent per-plan path (the baseline the
    forest is benchmarked and property-tested against)."""
    if fused:
        t, chains = pattern_set_count(g, [TRIANGLE, THREE_CHAIN_INDUCED])
    else:
        t = triangle_count(g)
        chains = three_chain_count(g, induced=True)
    return {"triangle": t, "chain": chains}


def clique_count(g: CSRGraph, k: int, chunk: int | None = None,
                 device_compact: bool = True) -> int:
    """k-clique counting, k >= 3: the compiled chain-restricted plan. Every
    level reuses the parent's survivor stream (the compiler's carry
    analysis), so the interpreter issues the exact executable sequence the
    old hand-coded engine did. ``device_compact=False`` routes the same plan
    through the host np.nonzero oracle."""
    if k < 3:
        raise ValueError("clique_count needs k >= 3")
    return pattern_count(g, clique_pattern(k), chunk, device_compact)


def four_motif(g: CSRGraph, chunk: int | None = None,
               fused: bool = True) -> dict[str, int]:
    """4-motif mining: induced counts of all six connected 4-vertex motifs,
    each from its compiled plan — zero per-pattern engine code.

    Default is the fused ``PlanForest`` path: the six plans collapse to
    three shared level-2 expands over two edge-feed passes (diamond/paw/
    4-clique share the N(v0) ∩ N(v1) wing stream, 4-cycle/4-path share
    N(v0) \\ N(v1); see ``mining.forest``). ``fused=False`` runs the six
    plans independently — same counts, kept as the comparison baseline."""
    if fused:
        counts = pattern_set_count(g, list(FOUR_MOTIFS.values()), chunk)
        return dict(zip(FOUR_MOTIFS, counts))
    runner = WaveRunner(g, chunk)
    return {name: runner.run(compile_pattern(p))
            for name, p in FOUR_MOTIFS.items()}


# the FSM pattern batch: every engine-fed plan FSM's support evaluation
# consumes, merged into one forest (a single feed pass). Today that is the
# triangle emit plan — wedge/star/path domains are closed forms over the
# neighbor-label count table — but additional engine-fed patterns join the
# same batch (and share its prefixes) by appending here.
FSM_FEED_PLANS: tuple = (compile_pattern(TRIANGLE, emit=True),)


def fsm_pattern_feed(g: CSRGraph, chunk: int | None = None) -> list:
    """Run the FSM engine-feed batch as one ``PlanForest`` pass; returns
    per-plan results in ``FSM_FEED_PLANS`` order (triangle embeddings
    first)."""
    return pattern_set_run(g, list(FSM_FEED_PLANS), chunk)


def triangle_list(g: CSRGraph, chunk: int | None = None) -> np.ndarray:
    """Enumerate all triangles as (T, 3) vertex triples (v0 > v1 > v2).

    Used by FSM (labelled support needs embeddings, not counts). Runs the
    triangle *emit* plan through the forest scheduler: compaction happens on
    device via ``ops.xinter_compact``'s src output, and only the compacted
    embedding matrix crosses to the host."""
    return fsm_pattern_feed(g, chunk)[0]


def triangle_list_host(g: CSRGraph, chunk: int | None = None) -> np.ndarray:
    """Host-compaction oracle for ``triangle_list`` (np.nonzero +
    ``compact(return_src=True)``) — kept as the property-test reference for
    the device emit path."""
    chunk = chunk or choose_chunk(g.padded_max_degree)
    out = []
    for rows0, rows1, v0, v1, n in pair_wave(g, half_edges(g), chunk):
        wave = Wave(rows=np.asarray(rows0), verts=v1)
        rows2, counts2 = expand(g, wave)
        w2, ii = compact(rows2, counts2, limit=n, return_src=True)
        if w2 is None:
            continue
        out.append(np.stack([v0[ii], v1[ii], w2.verts], axis=1))
    if not out:
        return np.zeros((0, 3), dtype=np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)
