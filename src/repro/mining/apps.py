"""The paper's seven mining applications on the wavefront engine (§VI-B).

All counts are exact and each embedding is counted once (symmetry breaking
via the bounded-intersection R3 operand, Fig. 2b), except the explicitly
paper-faithful *nested* variants which reproduce the Fig. 4a unbounded
S_NESTINTER dataflow and divide by the automorphism count.

Definitions (verified against brute-force oracles in tests):
  triangle           unordered vertex triples, mutually adjacent
  three-chain        non-induced: paths a—m—b (a<b);  induced: additionally
                     (a,b) ∉ E   (3-motif uses the induced count)
  tailed triangle    triangle {v0,v1,v2} + edge (v1,v3), v3 ∉ {v0,v2}; the
                     pattern automorphism (v0<->v2) is broken with v2 < v0
  k-clique           complete subgraphs of size k, counted once
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph
from .engine import (
    Wave, WaveRunner, choose_chunk, compact, expand, half_edges, pair_wave,
)


def triangle_count(g: CSRGraph, chunk: int | None = None,
                   device_compact: bool = True) -> int:
    """Symmetry-broken triangle counting: one bounded intersection per half
    edge (v0 > v1), bound v1 => each triangle v0 > v1 > v2 counted once."""
    runner = WaveRunner(g, chunk, device_compact=device_compact)
    return runner.count_edges(symmetric=True, bounded=True)


def triangle_count_nested(g: CSRGraph, chunk: int | None = None) -> int:
    """Paper-faithful Fig. 4a: Σ_v S_NESTINTER(N(v)) counts each triangle 6x.

    The per-vertex nested instruction flattens to one unbounded intersection
    per *directed* edge — exactly the µop stream §IV-F's translator emits,
    laid out as data parallelism."""
    runner = WaveRunner(g, chunk)
    total = runner.count_edges(symmetric=False, bounded=False)
    assert total % 6 == 0
    return total // 6


def three_chain_count(g: CSRGraph, induced: bool = False,
                      chunk: int | None = None) -> int:
    """Three-chain (path) counting.

    non-induced: Σ_m C(deg m, 2) — closed form (no intersection needed; the
    stream engine is exercised by the induced variant).
    induced: per directed edge (m, a), |{b ∈ N(m): b > a, b ∉ N(a)}| via two
    S_SUB.C calls (unbounded minus bounded-at-a minus the element a itself).
    """
    deg = np.asarray(g.degrees, dtype=np.int64)
    non_induced = int((deg * (deg - 1) // 2).sum())
    if not induced:
        return non_induced
    return WaveRunner(g, chunk).three_chain_induced()


def tailed_triangle_count(g: CSRGraph, chunk: int | None = None) -> int:
    """Fig. 2b dataflow: per directed edge (v0,v1), BoundedIntersect(N0,N1,v0)
    yields the v2 < v0 candidates; each then has deg(v1) - 2 tails v3."""
    return WaveRunner(g, chunk).tailed_triangle()


def three_motif(g: CSRGraph) -> dict[str, int]:
    """3-motif mining: counts of both connected 3-vertex induced motifs."""
    t = triangle_count(g)
    chains = three_chain_count(g, induced=True)
    return {"triangle": t, "chain": chains}


def clique_count(g: CSRGraph, k: int, chunk: int | None = None,
                 device_compact: bool = True) -> int:
    """k-clique counting, k ∈ {3,4,5}: wavefront of bounded intersections.

    Level l work item: (prefix stream S_l, candidate v); next stream
    S_{l+1} = S_l ∩ N(v) ∩ [0, v). Counting at the last level. The wave
    worklists stay device-resident between levels (``WaveRunner``);
    ``device_compact=False`` routes through the host np.nonzero oracle."""
    if k == 3:
        return triangle_count(g, chunk, device_compact=device_compact)
    if k not in (4, 5):
        raise ValueError("clique_count supports k in {3,4,5}")
    runner = WaveRunner(g, chunk, device_compact=device_compact)
    return runner.clique(k)


def triangle_list(g: CSRGraph, chunk: int | None = None) -> np.ndarray:
    """Enumerate all triangles as (T, 3) vertex triples (v0 > v1 > v2).

    Used by FSM (labelled support needs embeddings, not counts)."""
    chunk = chunk or choose_chunk(g.padded_max_degree)
    out = []
    for rows0, rows1, v0, v1, n in pair_wave(g, half_edges(g), chunk):
        wave = Wave(rows=np.asarray(rows0), verts=v1)
        rows2, counts2 = expand(g, wave)
        w2, ii = compact(rows2, counts2, limit=n, return_src=True)
        if w2 is None:
            continue
        out.append(np.stack([v0[ii], v1[ii], w2.verts], axis=1))
    if not out:
        return np.zeros((0, 3), dtype=np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)
