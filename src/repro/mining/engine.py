"""Wavefront pattern-enumeration engine: host-orchestrated, device-batched.

The paper's execution model is a core issuing stream instructions whose
operands live in the S-Cache. The TPU translation keeps the *dataflow* —
(prefix stream) x (neighbor list) bounded intersections — but replaces the
instruction stream with level-synchronous waves:

  level 1: the half edge list (v1 < v0, straight from the CSR offset register)
  level l: for each surviving work item, S_l = S_{l-1} ∩ N(v) ∩ [0, v)

Between levels the surviving (prefix, vertex) work items are *compacted on
the host* (the translation buffer of §IV-F become a dense worklist), and the
prefix capacity is re-derived from the actual max survivor length — the
paper's Fig. 14 observation (clique streams are short) becomes an adaptive
buffer size instead of a cache-residency win.

Work is chunked so device buffers stay bounded; padded tail items carry
bound=0 so they contribute nothing (branch-free masking, no special cases).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax.numpy as jnp

from repro.core.batch import batch_inter, batch_inter_count
from repro.core.stream import LANE, SENTINEL, round_capacity
from repro.graph.csr import CSRGraph, padded_rows


def half_edges(g: CSRGraph) -> np.ndarray:
    """(E/2, 2) array of (v0, v1) with v1 < v0 — the symmetric-breaking edge
    frontier, read directly via the CSR offset register (offsets[v0] = number
    of neighbors < v0)."""
    indptr = np.asarray(g.indptr)
    offsets = np.asarray(g.offsets)
    indices = np.asarray(g.indices)
    counts = offsets.astype(np.int64)
    v0 = np.repeat(np.arange(g.num_vertices, dtype=np.int32), counts)
    # position of each kept slot within its row
    pos = np.arange(counts.sum(), dtype=np.int64) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    v1 = indices[indptr[v0].astype(np.int64) + pos]
    return np.stack([v0, v1], axis=1)


def directed_edges(g: CSRGraph) -> np.ndarray:
    """(E, 2) all directed edges (v0, v1) in CSR order."""
    indptr = np.asarray(g.indptr).astype(np.int64)
    v0 = np.repeat(np.arange(g.num_vertices, dtype=np.int32), np.diff(indptr))
    v1 = np.asarray(g.indices)[: g.num_edges]
    return np.stack([v0, v1], axis=1)


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] == n:
        return x
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


@dataclasses.dataclass
class Wave:
    """A compacted frontier: prefix rows + the vertex that extends each."""

    rows: np.ndarray    # (N, cap) int32 sorted sentinel-padded prefix streams
    verts: np.ndarray   # (N,) int32 extension vertex (also the bound)

    def __len__(self) -> int:
        return int(self.verts.shape[0])


def _pow2cap(n: int) -> int:
    """Smallest power-of-two LANE multiple >= n (degree bucket capacity)."""
    c = LANE
    while c < n:
        c *= 2
    return c


def edge_wave(g: CSRGraph, chunk: int, symmetric: bool = True):
    """Yield level-1 waves: (v0 rows are N(v0), vert = v1), bucketed by the
    prefix vertex's degree so per-edge work is O(bucket) not O(max degree)
    (<= 2x padding waste — the paper's Fig. 14 stream-length skew exploited
    as static capacity classes; EXPERIMENTS.md §Perf mining iteration)."""
    edges = half_edges(g) if symmetric else directed_edges(g)
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    caps = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    for cap in np.unique(caps):
        sel = edges[caps == cap]
        # fixed chunk width: one compiled shape per degree bucket
        nb = min(chunk, _pow2cap(sel.shape[0]))
        for lo in range(0, sel.shape[0], nb):
            sl = sel[lo: lo + nb]
            n = sl.shape[0]
            v0 = _pad_to(sl[:, 0], nb, 0)
            v1 = _pad_to(sl[:, 1], nb, 0)
            rows, _ = padded_rows(g, jnp.asarray(v0), int(cap))
            yield Wave(rows=rows, verts=v1), n


def _neighbor_cap(g: CSRGraph, verts: np.ndarray) -> int:
    deg = np.asarray(g.degrees)
    mx = int(deg[np.asarray(verts)].max()) if len(verts) else 1
    return _pow2cap(max(mx, 1))


def expand_count(g: CSRGraph, wave: Wave, bounded: bool = True) -> jnp.ndarray:
    """counts[i] = |rows_i ∩ N(verts_i) ∩ [0, verts_i)| (bound dropped when
    ``bounded`` is False). Neighbor capacity = the chunk's degree bucket."""
    capn = _neighbor_cap(g, wave.verts)
    nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
    bounds = jnp.asarray(wave.verts) if bounded else None
    return batch_inter_count(jnp.asarray(wave.rows), nbr, bounds)


def expand(g: CSRGraph, wave: Wave, out_cap: int | None = None):
    """Materialise S_l rows: (rows (N, out_cap), counts (N,))."""
    capn = _neighbor_cap(g, wave.verts)
    rows_a = jnp.asarray(wave.rows)
    cap = out_cap or min(rows_a.shape[1], capn)
    nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
    rows, counts = batch_inter(rows_a, nbr,
                               jnp.asarray(wave.verts), out_cap=cap)
    return np.asarray(rows), np.asarray(counts)


def compact(rows: np.ndarray, counts: np.ndarray, limit: int | None = None,
            return_src: bool = False):
    """Host compaction: expand (rows, counts) into the next Wave.

    Every valid key rows[i, j] (j < counts[i]) becomes a work item whose
    prefix is rows[i] and whose extension vertex/bound is that key. The
    prefix capacity shrinks to the padded max survivor length (adaptive
    stream capacity — clique streams are short, paper Fig. 14).
    ``return_src`` additionally yields the source row index of each item
    (needed when the caller must recover the enclosing prefix vertices).
    """
    counts = counts[: limit] if limit is not None else counts
    rows = rows[: counts.shape[0]]
    maxc = int(counts.max()) if counts.size else 0
    if maxc == 0:
        return (None, None) if return_src else None
    cap = round_capacity(maxc)
    col = np.arange(rows.shape[1])
    ii, jj = np.nonzero(col[None, :] < counts[:, None])
    verts = rows[ii, jj].astype(np.int32)
    wave = Wave(rows=rows[ii, :cap], verts=verts)
    return (wave, ii) if return_src else wave


def pair_wave(g: CSRGraph, edges: np.ndarray, chunk: int):
    """Yield degree-bucketed padded row pairs for an (N, 2) vertex-pair list:
    (rows_a, rows_b, v0, v1, n_valid). Used by apps that intersect/subtract
    two neighbor lists per edge (TT, induced TC)."""
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    cap_a = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    cap_b = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 1]]])
    keys = cap_a.astype(np.int64) << 32 | cap_b
    for key in np.unique(keys):
        ca, cb = int(key >> 32), int(key & 0xFFFFFFFF)
        sel = edges[keys == key]
        nb = min(chunk, _pow2cap(sel.shape[0]))
        for lo in range(0, sel.shape[0], nb):
            sl = sel[lo: lo + nb]
            n = sl.shape[0]
            v0 = _pad_to(sl[:, 0], nb, 0)
            v1 = _pad_to(sl[:, 1], nb, 0)
            rows_a, _ = padded_rows(g, jnp.asarray(v0), ca)
            rows_b, _ = padded_rows(g, jnp.asarray(v1), cb)
            yield rows_a, rows_b, v0, v1, n


def wave_chunks(wave: Wave, chunk: int):
    """Split a host wave into padded device chunks; yields (Wave, n_valid).

    Padding uses vertex 0 with bound 0 => zero contribution."""
    n = len(wave)
    for lo in range(0, max(n, 1), chunk):
        r = wave.rows[lo: lo + chunk]
        v = wave.verts[lo: lo + chunk]
        if r.shape[0] == 0:
            continue
        k = r.shape[0]
        yield Wave(rows=_pad_to(r, chunk, SENTINEL), verts=_pad_to(v, chunk, 0)), k


DEFAULT_CHUNK = 4096


def choose_chunk(cap: int, budget_bytes: int = 64 << 20) -> int:
    """Chunk size so one wave's buffers stay within ``budget_bytes``."""
    per_row = cap * 4 * 4  # rows + neighbor rows + output + slack
    c = max(LANE, budget_bytes // max(per_row, 1))
    return int(min(DEFAULT_CHUNK * 4, (c // LANE) * LANE))
