"""Wavefront pattern-enumeration engine: device-resident, host-orchestrated.

The paper's execution model is a core issuing stream instructions whose
operands live in the S-Cache. The TPU translation keeps the *dataflow* —
(prefix stream) x (neighbor list) bounded intersections — but replaces the
instruction stream with level-synchronous waves driven by a compiled
``mining.plan.WavePlan`` (the §IV-F translator, run ahead of time):

  level 1: the edge list (half edges v1 < v0 when the plan's restrictions
           break that symmetry, straight from the CSR offset register)
  level l: for each surviving work item, the plan's LevelOp masks one base
           stream by the INTER/SUB/bound/injectivity refs it declares
           (the clique special case is S_l = S_{l-1} ∩ N(v) ∩ [0, v))

Between levels the surviving (prefix, vertex) work items are compacted into
a dense worklist (the translation buffer of §IV-F), and the prefix capacity
is re-derived from the actual max survivor length — the paper's Fig. 14
observation (clique streams are short) becomes an adaptive buffer size.

Two compaction paths exist:

  * **device (fast path, ``WaveRunner``)**: the expand's match mask is
    compacted on-device (segmented prefix-sum scatter,
    ``ops.xinter_compact`` / ``ops.xlevel_compact``) into the next wave's
    (rows, verts) buffers;
    only three level-boundary scalars (total, max count, max degree) ever
    cross to the host. Executables are cached per (cap_a, cap_b, chunk) so
    degree-bucketed shapes never retrace, and the level-1 edge feed is
    double-buffered (chunk N+1 uploads while chunk N computes) — the
    S-Cache residency win, restated as "operands never leave HBM".
  * **host (oracle, ``compact``)**: ``np.nonzero`` + re-upload. Kept as the
    semantic reference the device path is property-tested against.

Work is chunked so device buffers stay bounded; padded tail items carry
bound=0 so they contribute nothing (branch-free masking, no special cases).
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.batch import (batch_compact_scan, batch_inter,
                              batch_inter_count, compact_indices_scan)
from repro.obs import LegacyStatsView, Telemetry
from repro.core.stream import LANE, SENTINEL, round_capacity
from repro.graph.csr import CSRGraph, padded_rows, padded_value_rows
from repro.kernels.ops import (xinter_compact, xinter_count, xlevel_agg,
                               xlevel_compact, xlevel_count, xmark,
                               xsub_compact, xsub_count)
from repro.values import edge_value_lookup, prefix_scale
from .plan import LevelOp, WavePlan, clique_pattern, compile_pattern, pattern


def half_edges(g: CSRGraph) -> np.ndarray:
    """(E/2, 2) array of (v0, v1) with v1 < v0 — the symmetric-breaking edge
    frontier, read directly via the CSR offset register (offsets[v0] = number
    of neighbors < v0)."""
    indptr = np.asarray(g.indptr)
    offsets = np.asarray(g.offsets)
    indices = np.asarray(g.indices)
    counts = offsets.astype(np.int64)
    v0 = np.repeat(np.arange(g.num_vertices, dtype=np.int32), counts)
    # position of each kept slot within its row
    pos = np.arange(counts.sum(), dtype=np.int64) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    v1 = indices[indptr[v0].astype(np.int64) + pos]
    return np.stack([v0, v1], axis=1)


def directed_edges(g: CSRGraph) -> np.ndarray:
    """(E, 2) all directed edges (v0, v1) in CSR order."""
    indptr = np.asarray(g.indptr).astype(np.int64)
    v0 = np.repeat(np.arange(g.num_vertices, dtype=np.int32), np.diff(indptr))
    v1 = np.asarray(g.indices)[: g.num_edges]
    return np.stack([v0, v1], axis=1)


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] == n:
        return x
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


@dataclasses.dataclass
class Wave:
    """A compacted frontier: prefix rows + the vertex that extends each."""

    rows: np.ndarray    # (N, cap) int32 sorted sentinel-padded prefix streams
    verts: np.ndarray   # (N,) int32 extension vertex (also the bound)

    def __len__(self) -> int:
        return int(self.verts.shape[0])


def _pow2cap(n: int) -> int:
    """Smallest power-of-two LANE multiple >= n (degree bucket capacity)."""
    c = LANE
    while c < n:
        c *= 2
    return c


def edge_chunks(g: CSRGraph, chunk: int, symmetric: bool = True):
    """Host half of the level-1 feed: yields (cap, v0, v1, n) degree-bucketed
    chunk-padded int32 vertex arrays *without* materialising neighbor rows —
    row gathers happen on-device so the feed can be double-buffered."""
    edges = half_edges(g) if symmetric else directed_edges(g)
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    caps = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    for cap in np.unique(caps):
        sel = edges[caps == cap]
        # fixed chunk width: one compiled shape per degree bucket
        nb = min(chunk, _pow2cap(sel.shape[0]))
        for lo in range(0, sel.shape[0], nb):
            sl = sel[lo: lo + nb]
            n = sl.shape[0]
            v0 = _pad_to(sl[:, 0].astype(np.int32), nb, 0)
            v1 = _pad_to(sl[:, 1].astype(np.int32), nb, 0)
            yield int(cap), v0, v1, n


def edge_wave(g: CSRGraph, chunk: int, symmetric: bool = True):
    """Yield level-1 waves: (v0 rows are N(v0), vert = v1), bucketed by the
    prefix vertex's degree so per-edge work is O(bucket) not O(max degree)
    (<= 2x padding waste — the paper's Fig. 14 stream-length skew exploited
    as static capacity classes; EXPERIMENTS.md §Perf mining iteration)."""
    for cap, v0, v1, n in edge_chunks(g, chunk, symmetric):
        rows, _ = padded_rows(g, jnp.asarray(v0), cap)
        yield Wave(rows=rows, verts=v1), n


def _neighbor_cap(g: CSRGraph, verts: np.ndarray) -> int:
    deg = np.asarray(g.degrees)
    mx = int(deg[np.asarray(verts)].max()) if len(verts) else 1
    return _pow2cap(max(mx, 1))


def expand_count(g: CSRGraph, wave: Wave, bounded: bool = True) -> jnp.ndarray:
    """counts[i] = |rows_i ∩ N(verts_i) ∩ [0, verts_i)| (bound dropped when
    ``bounded`` is False). Neighbor capacity = the chunk's degree bucket."""
    capn = _neighbor_cap(g, wave.verts)
    nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
    bounds = jnp.asarray(wave.verts) if bounded else None
    return batch_inter_count(jnp.asarray(wave.rows), nbr, bounds)


def expand(g: CSRGraph, wave: Wave, out_cap: int | None = None):
    """Materialise S_l rows: (rows (N, out_cap), counts (N,))."""
    capn = _neighbor_cap(g, wave.verts)
    rows_a = jnp.asarray(wave.rows)
    cap = out_cap or min(rows_a.shape[1], capn)
    nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
    rows, counts = batch_inter(rows_a, nbr,
                               jnp.asarray(wave.verts), out_cap=cap)
    return np.asarray(rows), np.asarray(counts)


def compact(rows: np.ndarray, counts: np.ndarray, limit: int | None = None,
            return_src: bool = False):
    """Host compaction oracle: expand (rows, counts) into the next Wave.

    The device fast path (``WaveRunner`` via ``ops.xinter_compact``) is
    property-tested to produce item-for-item identical waves; this np.nonzero
    form stays as the semantic reference and the ``return_src`` provider for
    embedding enumeration (``apps.triangle_list``).

    Every valid key rows[i, j] (j < counts[i]) becomes a work item whose
    prefix is rows[i] and whose extension vertex/bound is that key. The
    prefix capacity shrinks to the padded max survivor length (adaptive
    stream capacity — clique streams are short, paper Fig. 14).
    ``return_src`` additionally yields the source row index of each item
    (needed when the caller must recover the enclosing prefix vertices).
    """
    counts = counts[: limit] if limit is not None else counts
    rows = rows[: counts.shape[0]]
    maxc = int(counts.max()) if counts.size else 0
    if maxc == 0:
        return (None, None) if return_src else None
    cap = round_capacity(maxc)
    col = np.arange(rows.shape[1])
    ii, jj = np.nonzero(col[None, :] < counts[:, None])
    verts = rows[ii, jj].astype(np.int32)
    wave = Wave(rows=rows[ii, :cap], verts=verts)
    return (wave, ii) if return_src else wave


def pair_chunks(g: CSRGraph, edges: np.ndarray, chunk: int):
    """Host half of the pair feed: yields (cap_a, cap_b, v0, v1, n) without
    materialising rows (device gathers, double-bufferable)."""
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    cap_a = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    cap_b = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 1]]])
    keys = cap_a.astype(np.int64) << 32 | cap_b
    for key in np.unique(keys):
        ca, cb = int(key >> 32), int(key & 0xFFFFFFFF)
        sel = edges[keys == key]
        nb = min(chunk, _pow2cap(sel.shape[0]))
        for lo in range(0, sel.shape[0], nb):
            sl = sel[lo: lo + nb]
            n = sl.shape[0]
            v0 = _pad_to(sl[:, 0].astype(np.int32), nb, 0)
            v1 = _pad_to(sl[:, 1].astype(np.int32), nb, 0)
            yield ca, cb, v0, v1, n


def pair_wave(g: CSRGraph, edges: np.ndarray, chunk: int):
    """Yield degree-bucketed padded row pairs for an (N, 2) vertex-pair list:
    (rows_a, rows_b, v0, v1, n_valid). Used by apps that intersect/subtract
    two neighbor lists per edge (TT, induced TC)."""
    for ca, cb, v0, v1, n in pair_chunks(g, edges, chunk):
        rows_a, _ = padded_rows(g, jnp.asarray(v0), ca)
        rows_b, _ = padded_rows(g, jnp.asarray(v1), cb)
        yield rows_a, rows_b, v0, v1, n


def wave_chunks(wave: Wave, chunk: int):
    """Split a host wave into padded device chunks; yields (Wave, n_valid).

    Padding uses vertex 0 with bound 0 => zero contribution."""
    n = len(wave)
    for lo in range(0, max(n, 1), chunk):
        r = wave.rows[lo: lo + chunk]
        v = wave.verts[lo: lo + chunk]
        if r.shape[0] == 0:
            continue
        k = r.shape[0]
        yield Wave(rows=_pad_to(r, chunk, SENTINEL), verts=_pad_to(v, chunk, 0)), k


DEFAULT_CHUNK = 4096


def choose_chunk(cap: int, budget_bytes: int = 64 << 20) -> int:
    """Chunk size so one wave's buffers stay within ``budget_bytes``."""
    per_row = cap * 4 * 4  # rows + neighbor rows + output + slack
    c = max(LANE, budget_bytes // max(per_row, 1))
    return int(min(DEFAULT_CHUNK * 4, (c // LANE) * LANE))


# ---------------------------------------------------------------------------
# WaveRunner — the stream-program interpreter over the device wave pipeline
# ---------------------------------------------------------------------------

# count_edges back-compat surface: the four (symmetric, bounded) triangle
# stream shapes as one-level plans
_EDGE_COUNT_PATTERNS = {
    (True, True): pattern("edges-sym-bounded", 3, [(0, 1), (0, 2), (1, 2)],
                          restrictions=[(1, 0), (2, 1)]),
    (True, False): pattern("edges-sym", 3, [(0, 1), (0, 2), (1, 2)],
                           restrictions=[(1, 0)]),
    (False, True): pattern("edges-bounded", 3, [(0, 1), (0, 2), (1, 2)],
                           restrictions=[(2, 1)]),
    (False, False): pattern("edges", 3, [(0, 1), (0, 2), (1, 2)]),
}


class WaveRunner:
    """Stream-program interpreter: executes any compiled ``WavePlan`` on the
    device-resident wavefront pipeline.

    ``run(plan)`` is the single generic entry point — the §IV-F translator's
    software half. Each ``LevelOp`` lowers to one cached jitted executable
    that gathers the neighbor streams it references, AND-combines their
    membership marks (INTER) / complements (SUB) over the base stream, applies
    bound and injectivity masks, and either counts, materialises + compacts
    (``ops.xinter_compact`` / ``ops.xsub_compact`` fused fast paths when the
    level is a single bounded stream op), or emits embeddings. Per-pattern
    engine methods are gone: ``clique``/``count_edges``/... below are thin
    plan wrappers kept for the benchmark/test surface.

    Mechanisms shared by every plan:

    * **executable cache** keyed by (kind, LevelOp, capacities, chunk):
      LevelOps hash by value, so recompiling a pattern — or two patterns
      sharing a level shape — reuses traces (``stats['exec_hits']``);
    * **fused expand_compact**: survivors are compacted on device; the only
      per-level host traffic is the meta sync (total, max survivor count,
      max degree per forwarded column) that sizes the next level's static
      capacities;
    * **prefix-column forwarding**: the compiler's liveness fields
      (``out_cols``/``gather_refs``) tell the interpreter which matched
      vertices deeper levels reference; columns are gathered through the
      compacted ``src`` indices on device, never round-tripping to host;
    * **double-buffered feeds**: level-1 edge chunks upload one ahead of
      compute;
    * **per-chunk device partial sums**: count levels reduce to one scalar
      per chunk on device (synced in a deferred batch at the end of
      ``run``) — no count vectors ever cross to the host.

    ``run_set(forest)`` generalises ``run`` to a ``mining.forest.PlanForest``
    of several plans at once: one edge-feed pass per orientation, each
    shared trie node's expand + compaction dispatched once per wave chunk
    and fanned out to every child branch (children whose branch deferred
    constraints into residuals first get a per-branch packed worklist, so
    relaxation never inflates their downstream item count), with per-leaf
    accumulators — results bit-identical to per-plan ``run`` calls.

    General (multi-operand) levels — several INTER/SUB refs, or injectivity
    excludes — dispatch ONE fused k-operand kernel per executable call
    (``ops.xlevel_count`` / ``ops.xlevel_compact``: the refs are stacked
    into a (k, B, cap) operand, polarity INTER-first, window/excludes folded
    in-kernel) instead of one ``xmark`` per reference; compaction everywhere
    is the O(B·cap) segmented prefix-sum scatter (``batch_compact_scan``),
    never a masked sort. ``fused_level=False`` keeps the per-ref mark
    composition as the comparison fallback — counts are property-tested
    bit-identical with the flag on and off, and the executable cache is
    keyed on it (plus the per-ref capacity signature, so a k-operand level's
    trace is reused across degree buckets exactly like the single-op ones).

    ``device_compact=False`` routes every expand through the host
    ``compact`` oracle (np.nonzero + re-upload) — the twin the fast path is
    property-tested against. ``record=True`` captures each wave's live
    (carry-or-prefix-columns, verts) into ``trace`` for those comparisons.

    Every executable is built in two halves: an unjitted *body*
    (``_count_body`` / ``_expand_body`` / ``_emit_body`` / ``_chunk_body`` /
    ``_rpack_body``) holding the traced computation, and a ``_jit_*`` hook
    that wraps it for dispatch (plain ``jax.jit`` here). The mesh-sharded
    runner (``mining.shard.ShardedWaveRunner``) overrides only the hooks —
    wrapping each body in ``shard_map`` with a ``psum`` leaf reduction —
    plus the feed/meta plumbing, so both runners interpret plans through
    the exact same per-level semantics. The bodies are written to accept
    the live count ``n`` as either a scalar (this runner) or a shape-(1,)
    per-shard slice (broadcast against ``jnp.arange`` either way).
    """

    # data-parallel width of the wave arrays: every (items,) buffer holds
    # ``_shards`` per-shard blocks back to back; 1 here (single device),
    # the mesh size on ShardedWaveRunner (which also divides the host-side
    # batch arithmetic below by it).
    _shards: int = 1
    # prepended to every executable-cache key so sharded (shard_map-wrapped)
    # traces can never collide with unsharded traces of the same LevelOp
    _exec_prefix: tuple = ()

    # legacy ``stats`` keys, in their historical insertion order — each is
    # a registry counter the view derives from (see __init__)
    _STAT_KEYS = ("exec_hits", "exec_misses", "host_syncs",
                  "device_compactions", "host_compactions", "items",
                  "level_kernel_dispatches", "count_rides")

    def __init__(self, g: CSRGraph, chunk: int | None = None,
                 backend: str = "auto", device_compact: bool = True,
                 record: bool = False, fused_level: bool = True,
                 exec_cache=None, telemetry: Telemetry | None = None):
        self.g = g
        # chunk <= 2^15 is the exactness envelope of the (hi, lo) int32
        # per-chunk count partials (see _plan_count_fn): a 2^15-item chunk of
        # 16-bit low words sums below 2^31. choose_chunk already stays under
        # it; explicit larger requests are clamped, never silently wrapped.
        self.chunk = min(chunk or choose_chunk(g.padded_max_degree), 1 << 15)
        self.backend = backend
        self.device_compact = device_compact
        self.record = record
        self.fused_level = fused_level
        # session-lifetime executable cache (mining.session.ExecutableCache):
        # when provided, compiled executables outlive this runner — repeated
        # queries on one Miner retrace nothing. Keys are widened with the
        # runner config (chunk / backend / flags) so runners with different
        # shapes never collide; None keeps the private per-runner dict.
        self._exec_cache = exec_cache
        self.trace: list[tuple[int, np.ndarray, np.ndarray]] = []
        self._exec: dict[tuple, Callable] = {}
        # telemetry substrate (repro.obs): the metrics registry is the
        # single source of truth for every counter, and ``self.stats`` is
        # the legacy dict DERIVED from it (bit-identical view, golden-
        # tested). The tracer is off unless the session enables it —
        # dispatch sites then open timed spans and block to completion.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.metrics = self.telemetry.metrics
        self.stats = LegacyStatsView()
        self._ct = {k: self.stats.expose_counter(k, self.metrics)
                    for k in self._STAT_KEYS}
        # registry-only extras (not part of the legacy view)
        self._h_wave_items = self.metrics.histogram("wave_items")
        self._ct_feed_chunks = self.metrics.counter("feed_chunks")
        # SVPU value plane: aggregate-leaf executions (each rides an
        # existing membership dispatch — value_lane_dispatches counts leaves
        # whose dispatch carried a value lane, NOT extra kernel launches)
        self._ct_value_lanes = self.metrics.counter("value_lane_dispatches")
        self._exec_fresh = False
        # per-(kind, level) executable dispatch counts — the fusion metric:
        # a PlanForest run dispatches each shared level once where the
        # independent-plan path dispatches it once per pattern.
        self.level_execs: dict[tuple[str, int], int] = {}

    def _level_dispatches(self, op: LevelOp, host: bool = False) -> int:
        """Membership-kernel dispatches one executable call issues for
        ``op`` — the per-operand DMA metric the fused level path collapses:
        a general level costs one dispatch per INTER/SUB ref on the per-ref
        fallback (and always on the host-oracle mark composition), exactly
        one with ``fused_level``; window-only levels need none."""
        k = len(op.inter) + len(op.sub)
        if host:
            return k
        if self._fused_shape(op) is not None:
            return 1
        if k == 0:
            return 0
        return 1 if self.fused_level else k

    def _bump(self, op: LevelOp, host: bool = False) -> None:
        key = (op.kind, op.level)
        self.level_execs[key] = self.level_execs.get(key, 0) + 1
        self._ct["level_kernel_dispatches"].inc(
            self._level_dispatches(op, host))

    # ------------------------------------------------------------------ cache
    def _executable(self, key: tuple, build: Callable) -> Callable:
        key = self._exec_prefix + key
        if self._exec_cache is not None:
            key = (self.chunk, self.backend, self.device_compact,
                   self.fused_level) + key
            fn, fresh = self._exec_cache.get_or_build(key, build)
            self._exec_fresh = fresh
            self._ct["exec_misses" if fresh else "exec_hits"].inc()
            return fn
        fn = self._exec.get(key)
        if fn is None:
            fn = self._exec[key] = build()
            self._exec_fresh = True
            self._ct["exec_misses"].inc()
        else:
            self._exec_fresh = False
            self._ct["exec_hits"].inc()
        return fn

    # -------------------------------------------------------- traced dispatch
    def _dispatch(self, op: LevelOp, fn: Callable, args: tuple,
                  items=None, caps_sig: tuple = (), host: bool = False):
        """Run one level executable. With tracing enabled, the call is
        wrapped in a ``dispatch`` span (op kind/level, wavefront items,
        capacity signature, exec-cache hit/miss) and followed by
        ``block_until_ready`` so the span measures device wall time, not
        async dispatch time. Disabled: the bare call — no span, no sync."""
        tr = self.telemetry.tracer
        if not tr.enabled:
            return fn(*args)
        attrs = {"kind": op.kind, "level": op.level,
                 "dispatches": self._level_dispatches(op, host),
                 "exec_cached": not self._exec_fresh}
        if op.agg is not None:
            attrs["agg"] = op.agg
        if items is not None:
            attrs["items"] = int(np.asarray(items).sum())
        if caps_sig:
            attrs["caps"] = str(tuple(caps_sig))
        if host:
            attrs["host"] = True
        with tr.span("dispatch", cat="dispatch", **attrs):
            out = fn(*args)
            jax.block_until_ready(out)
        return out

    def _level_span(self, op: LevelOp, n):
        """Level-span context for one op's processing on one wave chunk
        (children levels nest inside); no-op when tracing is off."""
        tr = self.telemetry.tracer
        if not tr.enabled:
            return nullcontext()
        return tr.span(f"L{op.level}:{op.kind}", cat="level",
                       level=op.level, kind=op.kind,
                       items=int(np.asarray(n).sum()))

    def _rows_fn(self, cap: int):
        def build():
            @jax.jit
            def fn(g, vs):
                return padded_rows(g, vs, cap)[0]
            return fn
        return self._executable(("rows", cap), build)

    # ------------------------------------------------------------------ feeds
    @staticmethod
    def _double_buffered(chunks, put_idx: frozenset):
        """Run one item ahead of the consumer, ``jax.device_put``-ing the
        arrays at ``put_idx``: chunk N+1's upload dispatches (async) while
        the consumer computes on chunk N."""
        pending = None
        for tup in chunks:
            nxt = tuple(jax.device_put(x) if i in put_idx else x
                        for i, x in enumerate(tup))
            if pending is not None:
                yield pending
            pending = nxt
        if pending is not None:
            yield pending

    def _edge_feed(self, symmetric: bool = True):
        """Double-buffered level-1 feed: (cap, dv0, dv1, v1_host, n)."""
        chunks = ((cap, v0, v1, v1, n) for cap, v0, v1, n
                  in edge_chunks(self.g, self.chunk, symmetric))
        return self._double_buffered(chunks, frozenset({1, 2}))

    # ------------------------------------------------------------- plan parts
    @staticmethod
    def _in_cols(op: LevelOp) -> tuple[int, ...]:
        """Prefix columns whose *values* the level executable consumes."""
        cols = set(op.val_refs()) | {c for c in op.gather_refs
                                     if c < op.level}
        if op.kind == "emit":
            cols |= {c for c in op.out_cols if c < op.level}
        return tuple(sorted(cols))

    @staticmethod
    def _fused_shape(op: LevelOp) -> str | None:
        """'inter'/'sub' when one fused bounded kernel covers the level.

        Lower bounds ride the kernels' lbounds operand (whole-tile skipping,
        like the R3 upper bound); residuals and the live mask fold into the
        per-row bound (bound 0 = dead row). Only per-element injectivity
        (``exclude``) still needs the general mark composition."""
        if op.exclude:
            return None
        if len(op.inter) == 1 and not op.sub:
            return "inter"
        if len(op.sub) == 1 and not op.inter:
            return "sub"
        return None

    def _mask_ops(self, op: LevelOp, caps: dict):
        """Traced general path: AND-combine one membership mark per INTER/SUB
        reference plus bound / injectivity masks — the multi-µop level."""
        backend = self.backend

        def keep_of(g, base, get, n):
            keep = base != SENTINEL
            for j in op.inter:
                nbr, _ = padded_rows(g, get[j], caps[j])
                keep = keep & xmark(base, nbr, backend=backend)
            for j in op.sub:
                nbr, _ = padded_rows(g, get[j], caps[j])
                keep = keep & ~xmark(base, nbr, backend=backend)
            if op.ub:
                ub = get[op.ub[0]]
                for u in op.ub[1:]:
                    ub = jnp.minimum(ub, get[u])
                keep = keep & (base < ub[:, None])
            if op.lb:
                lb = get[op.lb[0]]
                for w in op.lb[1:]:
                    lb = jnp.maximum(lb, get[w])
                keep = keep & (base > lb[:, None])
            for e in op.exclude:
                keep = keep & (base != get[e][:, None])
            for kind, i, j in op.residual:
                ok = (get[i] < get[j]) if kind == "lt" else (get[i] != get[j])
                keep = keep & ok[:, None]
            live = jnp.arange(base.shape[0], dtype=jnp.int32) < n
            return keep & live[:, None]
        return keep_of

    @staticmethod
    def _stack_refs(g, get, caps: dict, refs: tuple[int, ...]):
        """Gather the k reference neighbor streams and stack them into the
        fused kernel's (k, B, cap) operand; refs gathered at smaller degree
        buckets are SENTINEL-padded to the widest (padding keeps each row
        sorted, so every ref's tile schedule stays valid)."""
        capmax = max(caps[j] for j in refs)
        rows = []
        for j in refs:
            r, _ = padded_rows(g, get[j], caps[j])
            if caps[j] < capmax:
                r = jnp.pad(r, ((0, 0), (0, capmax - caps[j])),
                            constant_values=SENTINEL)
            rows.append(r)
        return jnp.stack(rows)

    @staticmethod
    def _stack_val_refs(g, get, caps: dict, refs: tuple[int, ...]):
        """Value twin of ``_stack_refs``: (k, B, cap) f32 stack aligned with
        the key stack, 0.0 where keys are SENTINEL padding (the pad columns
        never match, so their value is irrelevant but must exist)."""
        capmax = max(caps[j] for j in refs)
        rows = []
        for j in refs:
            v = padded_value_rows(g, get[j], caps[j])
            if caps[j] < capmax:
                v = jnp.pad(v, ((0, 0), (0, capmax - caps[j])))
            rows.append(v)
        return jnp.stack(rows)

    @staticmethod
    def _excl_vals(op: LevelOp, get):
        """Per-row injectivity values for the fused kernels' excludes
        operand (None when the level declares none)."""
        if not op.exclude:
            return None
        return jnp.stack([get[e] for e in op.exclude], axis=1)

    @staticmethod
    def _min_ub(op: LevelOp, get):
        ub = get[op.ub[0]]
        for u in op.ub[1:]:
            ub = jnp.minimum(ub, get[u])
        return ub

    @staticmethod
    def _max_lb(op: LevelOp, get):
        lb = get[op.lb[0]]
        for w in op.lb[1:]:
            lb = jnp.maximum(lb, get[w])
        return lb

    def _ub_vec(self, op: LevelOp, get, n, nrows: int):
        """Per-row effective upper bound for the fused kernels: min over the
        ``ub`` columns (SENTINEL when unbounded), then zeroed for padding
        rows and residual-failing items — bound 0 kills the whole row inside
        the tile schedule, so deferred constraints cost no B-tile DMA."""
        if op.ub:
            ub = self._min_ub(op, get)
        else:
            ub = jnp.full((nrows,), SENTINEL, jnp.int32)
        ok = jnp.arange(nrows, dtype=jnp.int32) < n
        for kind, i, j in op.residual:
            ok = ok & ((get[i] < get[j]) if kind == "lt"
                       else (get[i] != get[j]))
        return jnp.where(ok, ub, 0)

    def _plan_count_fn(self, op: LevelOp, caps_sig: tuple, cap_base: int):
        """Terminal count level -> one tiny device sync per chunk.

        The per-chunk sum is returned as an exact (hi, lo) int32 pair —
        Σ(count >> 16) and Σ(count & 0xffff) — reassembled in Python ints at
        ``run``'s deferred sync. With chunk <= 2^15 neither partial can wrap,
        so the only remaining envelope is per *item*: a tail-folded count
        (survivors x degree factor) must stay below 2^31, which holds
        whenever maxc * max_degree < 2^31 (the old host path multiplied in
        int64 but pulled the whole count vector to do it).
        """
        def build():
            return self._jit_count(
                op, self._count_body(op, caps_sig, cap_base))
        return self._executable(
            ("pcount", op, caps_sig, cap_base, self.fused_level), build)

    def _count_body(self, op: LevelOp, caps_sig: tuple, cap_base: int):
        """Unjitted count-level body (see the two-halves note in the class
        docstring); ``_jit_count`` wraps it for dispatch."""
        backend = self.backend
        in_cols = self._in_cols(op)
        caps = dict(caps_sig)
        fused = self._fused_shape(op)
        keep_of = self._mask_ops(op, caps)
        refs = op.inter + op.sub
        pol = (1,) * len(op.inter) + (0,) * len(op.sub)
        use_xlevel = fused is None and self.fused_level

        def fn(g, vals, carry, n):
            get = dict(zip(in_cols, vals))
            base = carry if op.use_carry else \
                padded_rows(g, get[op.base], caps[op.base])[0]
            if fused:
                ub = self._ub_vec(op, get, n, base.shape[0])
                lb = self._max_lb(op, get) if op.lb else None
                ref = op.inter[0] if fused == "inter" else op.sub[0]
                nbr, _ = padded_rows(g, get[ref], caps[ref])
                cfun = xinter_count if fused == "inter" else xsub_count
                counts = cfun(base, nbr, ub, backend=backend, lbounds=lb)
            elif use_xlevel:
                ub = self._ub_vec(op, get, n, base.shape[0])
                lb = self._max_lb(op, get) if op.lb else None
                bs = self._stack_refs(g, get, caps, refs) if refs \
                    else None
                counts = xlevel_count(base, bs, pol, ub, backend=backend,
                                      lbounds=lb,
                                      excludes=self._excl_vals(op, get))
            else:
                counts = jnp.sum(keep_of(g, base, get, n), axis=1,
                                 dtype=jnp.int32)
            if op.tail is not None:
                col, c = op.tail
                counts = counts * (g.degrees[get[col]].astype(jnp.int32)
                                   - c)
            return jnp.stack([jnp.sum(counts >> 16, dtype=jnp.int32),
                              jnp.sum(counts & 0xFFFF, dtype=jnp.int32)])
        return fn

    def _plan_agg_fn(self, op: LevelOp, caps_sig: tuple, cap_base: int):
        """Terminal SVPU aggregate level (``op.agg``): one (value, live)
        f32 pair per chunk, riding the same dispatch budget as the count
        leaf (``xlevel_agg`` shares ``xlevel_count``'s tile schedule)."""
        def build():
            return self._jit_agg(op, self._agg_body(op, caps_sig, cap_base))
        return self._executable(
            ("pagg", op, caps_sig, cap_base, self.fused_level), build)

    def _agg_body(self, op: LevelOp, caps_sig: tuple, cap_base: int):
        """Unjitted aggregate-leaf body; ``_jit_agg`` wraps it for dispatch.

        Per kept slot the embedding's value is the product over ALL pattern
        edges of the edge weight, assembled from three sources: prefix-
        prefix edges fold into the per-row ``scale`` (``prefix_scale``),
        candidate-edge weights the kernel's own INTER refs observe ride the
        mask-MAC value lane (``b_vals``), and candidate edges covered at an
        ancestor level (carry reuse / the fresh base's own gather) land in
        ``a_vals`` (value-row gather + ``edge_value_lookup``). The per-chunk
        partial is [op-reduced value, live embedding count] — live gates
        the op identity out at finalize (zero embeddings -> 0.0)."""
        backend = self.backend
        in_cols = self._in_cols(op)
        caps = dict(caps_sig)
        refs = op.inter + op.sub
        pol = (1,) * len(op.inter) + (0,) * len(op.sub)

        def fn(g, vals, carry, n):
            get = dict(zip(in_cols, vals))
            if op.use_carry:
                base = carry
                a_vals = jnp.ones(base.shape, jnp.float32)
            else:
                base = padded_rows(g, get[op.base], caps[op.base])[0]
                a_vals = padded_value_rows(g, get[op.base], caps[op.base])
            for c in op.agg_cand_cols:
                a_vals = a_vals * edge_value_lookup(g, get[c], base)
            scale = prefix_scale(g, get, op.agg_scale_edges) \
                if op.agg_scale_edges \
                else jnp.ones((base.shape[0],), jnp.float32)
            ub = self._ub_vec(op, get, n, base.shape[0])
            lb = self._max_lb(op, get) if op.lb else None
            if refs:
                bs = self._stack_refs(g, get, caps, refs)
                bv = self._stack_val_refs(g, get, caps, refs)
            else:
                bs = bv = None
            counts, rvals = xlevel_agg(
                base, bs, pol, a_vals, bv, scale, op=op.agg, bounds=ub,
                backend=backend, lbounds=lb,
                excludes=self._excl_vals(op, get))
            # dead rows carry the op identity, so the plain row reduce is
            # correct; ``live`` is only read as a zero test at finalize
            if op.agg == "sum":
                value = jnp.sum(rvals, dtype=jnp.float32)
            elif op.agg == "max":
                value = jnp.max(rvals)
            else:
                value = jnp.min(rvals)
            live = jnp.sum(counts, dtype=jnp.int32).astype(jnp.float32)
            return jnp.stack([value, live])
        return fn

    # -------------------------------------------------------- jit hooks
    # Single-device dispatch is a plain jit of each body; the sharded
    # runner overrides these to wrap the same bodies in shard_map (psum
    # reductions for count partials, per-shard meta/total rows otherwise).
    def _jit_count(self, op: LevelOp, body: Callable) -> Callable:
        return jax.jit(body)

    def _jit_agg(self, op: LevelOp, body: Callable) -> Callable:
        return jax.jit(body)

    def _jit_expand(self, op: LevelOp, body: Callable,
                    want_count: bool) -> Callable:
        return jax.jit(body)

    def _jit_emit(self, op: LevelOp, body: Callable) -> Callable:
        return jax.jit(body)

    def _jit_chunk(self, op: LevelOp, body: Callable) -> Callable:
        return jax.jit(body)

    def _jit_rpack(self, body: Callable, nrefs: int) -> Callable:
        return jax.jit(body)

    def _pack_total(self, tot):
        """Host view of a residual-pack live total: (orchestration value,
        any-survivors?). The sharded runner returns the per-shard total
        vector so downstream chunking stays lockstep SPMD."""
        tot = int(tot)
        return tot, bool(tot)

    def _survivor_core(self, op: LevelOp, caps: dict, out_cap: int,
                       out_items: int):
        """Traced core shared by expand/emit: survivors -> compacted items.

        Fast paths: a single INTER/SUB level is one fused
        ``xinter_compact``/``xsub_compact`` dispatch; a general level (k
        INTER/SUB refs, injectivity excludes) is one fused k-operand
        ``xlevel_compact`` dispatch. In both, the per-row bound vector
        (``_ub_vec``) folds the declared upper bounds, the live mask and any
        forest residuals into the bound operand (bound 0 kills dead rows
        inside the kernel) and lower bounds ride ``lbounds``. The
        ``fused_level=False`` fallback composes one mark per ref; every
        path's epilogue is the O(B·cap) ``batch_compact_scan`` prefix-sum
        scatter (no masked sort anywhere).
        """
        backend = self.backend
        fused = self._fused_shape(op)
        keep_of = self._mask_ops(op, caps)
        refs = op.inter + op.sub
        pol = (1,) * len(op.inter) + (0,) * len(op.sub)
        use_xlevel = fused is None and self.fused_level

        def core(g, get, base, n):
            if fused:
                ub = self._ub_vec(op, get, n, base.shape[0])
                lb = self._max_lb(op, get) if op.lb else None
                ref = op.inter[0] if fused == "inter" else op.sub[0]
                nbr, _ = padded_rows(g, get[ref], caps[ref])
                cfun = xinter_compact if fused == "inter" else xsub_compact
                rows2, counts, src, verts, total, maxc = cfun(
                    base, nbr, ub, out_cap=out_cap, out_items=out_items,
                    backend=backend, lbounds=lb)
            elif use_xlevel:
                ub = self._ub_vec(op, get, n, base.shape[0])
                lb = self._max_lb(op, get) if op.lb else None
                bs = self._stack_refs(g, get, caps, refs) if refs else None
                rows2, counts, src, verts, total, maxc = xlevel_compact(
                    base, bs, pol, ub, out_cap=out_cap, out_items=out_items,
                    backend=backend, lbounds=lb,
                    excludes=self._excl_vals(op, get))
            else:
                keep = keep_of(g, base, get, n)
                rows2, counts, src, verts, total, maxc = batch_compact_scan(
                    base, keep, out_cap, out_items)
            return rows2, counts, src, verts, total, maxc
        return core

    def _plan_expand_fn(self, op: LevelOp, caps_sig: tuple, cap_base: int,
                        out_cap: int, out_items: int,
                        want_count: bool = False):
        """Fused gather + level masks + on-device compaction + meta.

        meta = [total, max survivor count] + [max degree of column c over
        live items, for c in op.gather_refs] — the only host sync per level.
        ``want_count`` (count-rides-expand) appends the survivor-count sum
        as an exact (hi, lo) int32 pair: the partial a riding count leaf is
        credited with, at zero extra dispatches (same envelope as
        ``_plan_count_fn``: counts are already per-row exact).
        """
        def build():
            return self._jit_expand(
                op, self._expand_body(op, caps_sig, cap_base, out_cap,
                                      out_items, want_count), want_count)
        return self._executable(
            ("pexpand", op, caps_sig, cap_base, out_cap, out_items,
             self.fused_level, want_count), build)

    def _expand_body(self, op: LevelOp, caps_sig: tuple, cap_base: int,
                     out_cap: int, out_items: int, want_count: bool):
        """Unjitted expand-level body; meta layout as in
        ``_plan_expand_fn``, the (hi, lo) ride pair (when ``want_count``)
        in the last two slots."""
        in_cols = self._in_cols(op)
        caps = dict(caps_sig)
        core = self._survivor_core(op, caps, out_cap, out_items)

        def fn(g, vals, carry, n):
            get = dict(zip(in_cols, vals))
            base = carry if op.use_carry else \
                padded_rows(g, get[op.base], caps[op.base])[0]
            rows2, counts, src, verts, total, maxc = \
                core(g, get, base, n)
            live = jnp.arange(out_items, dtype=jnp.int32) < total
            metas = [total, maxc]
            for c in op.gather_refs:
                cv = verts if c == op.level else get[c][src]
                metas.append(jnp.max(jnp.where(live, g.degrees[cv], 0)))
            if want_count:
                metas += [jnp.sum(counts >> 16, dtype=jnp.int32),
                          jnp.sum(counts & 0xFFFF, dtype=jnp.int32)]
            return rows2, src, verts, jnp.stack(metas)
        return fn

    def _plan_expand_host_fn(self, op: LevelOp, caps_sig: tuple,
                             cap_base: int, out_cap: int):
        """Oracle-path twin: masks + materialise only; compaction on host."""
        in_cols = self._in_cols(op)
        caps = dict(caps_sig)
        keep_of = self._mask_ops(op, caps)

        def build():
            @jax.jit
            def fn(g, vals, carry, n):
                get = dict(zip(in_cols, vals))
                base = carry if op.use_carry else \
                    padded_rows(g, get[op.base], caps[op.base])[0]
                keep = keep_of(g, base, get, n)
                masked = jnp.where(keep, base, SENTINEL)
                rows2 = jnp.sort(masked, axis=1)[:, :out_cap]
                return rows2, jnp.sum(keep, axis=1, dtype=jnp.int32)
            return fn
        return self._executable(
            ("pexpandh", op, caps_sig, cap_base, out_cap), build)

    def _plan_emit_fn(self, op: LevelOp, caps_sig: tuple, cap_base: int,
                      out_cap: int, out_items: int):
        """Terminal emit level: compacted embeddings stay device-side until
        one bulk pull per chunk (FSM's triangle feed; ROADMAP item)."""
        def build():
            return self._jit_emit(
                op, self._emit_body(op, caps_sig, cap_base, out_cap,
                                    out_items))
        return self._executable(
            ("pemit", op, caps_sig, cap_base, out_cap, out_items,
             self.fused_level), build)

    def _emit_body(self, op: LevelOp, caps_sig: tuple, cap_base: int,
                   out_cap: int, out_items: int):
        """Unjitted emit-level body: (embedding matrix, live total)."""
        in_cols = self._in_cols(op)
        caps = dict(caps_sig)
        core = self._survivor_core(op, caps, out_cap, out_items)

        def fn(g, vals, carry, n):
            get = dict(zip(in_cols, vals))
            base = carry if op.use_carry else \
                padded_rows(g, get[op.base], caps[op.base])[0]
            _, _, src, verts, total, _ = core(g, get, base, n)
            live = jnp.arange(out_items, dtype=jnp.int32) < total
            cols_out = [verts if c == op.level
                        else jnp.where(live, get[c][src], 0)
                        for c in op.out_cols]
            return jnp.stack(cols_out, axis=1), total
        return fn

    def _plan_chunk_fn(self, op: LevelOp, b: int, out_cap: int, cap2: int,
                       chunk: int):
        """Slice the compacted worklist into the next level's device wave:
        forwarded prefix columns gather through ``src`` (zeroed past the live
        count so padding items carry bound-0 everywhere), the new vertex
        column comes from ``verts``, and the survivor streams become the next
        carry when the compiler proved reuse."""
        def build():
            return self._jit_chunk(op, self._chunk_body(op, cap2, chunk))
        return self._executable(("pchunk", op, b, out_cap, cap2, chunk),
                                build)

    def _chunk_body(self, op: LevelOp, cap2: int, chunk: int):
        """Unjitted worklist-slice body for ``_plan_chunk_fn``."""
        carry_out = op.carry_out

        def fn(rows2, src, verts2, colvals, lo, m):
            s = jax.lax.dynamic_slice_in_dim(src, lo, chunk)
            v = jax.lax.dynamic_slice_in_dim(verts2, lo, chunk)
            valid = jnp.arange(chunk, dtype=jnp.int32) < m
            v = jnp.where(valid, v, 0)
            outs = tuple(jnp.where(valid, cv[s], 0) for cv in colvals)
            if carry_out:
                return outs, v, rows2[s, :cap2]
            return outs, v
        return fn

    # ------------------------------------------------------- the interpreter
    def _record(self, level: int, rows, verts, n: int) -> None:
        if self.record:
            self.trace.append((level, np.asarray(rows)[:n].copy(),
                               np.asarray(verts)[:n].copy()))

    @staticmethod
    def _wave_repr(cols2: dict, out_cols, carry2, vch):
        """Trace representative for a wave chunk (device/host comparable)."""
        if carry2 is not None:
            return carry2
        if out_cols:
            return np.stack([np.asarray(cols2[c]) for c in out_cols], axis=1)
        return vch

    def _finalize(self, plan: WavePlan, parts: list):
        """Reduce one plan's accumulated chunk outputs to its result."""
        if plan.ops[-1].kind == "emit":
            if not parts:
                return np.zeros((0, plan.k), dtype=np.int32)
            return np.concatenate(parts, axis=0).astype(np.int32)
        agg = plan.ops[-1].agg
        if agg is not None:
            # f32 (value, live) pairs; live > 0 gates the op identity out
            # (a weighted query over zero embeddings aggregates to 0.0)
            value, live = None, 0.0
            for p in parts:
                v = np.asarray(p, dtype=np.float64)
                live += float(v[1])
                x = float(v[0])
                if value is None:
                    value = x
                elif agg == "sum":
                    value += x
                elif agg == "max":
                    value = max(value, x)
                else:
                    value = min(value, x)
            return float(value) if (value is not None and live > 0) else 0.0
        total = 0
        for p in parts:
            v = np.asarray(p)
            if v.shape[0] == 4:     # psum'd 16-bit limb quad (sharded runner)
                hi = (int(v[0]) << 16) + int(v[1])
                lo = (int(v[2]) << 16) + int(v[3])
            else:                   # (hi, lo) int32 pair, exact
                hi, lo = (int(x) for x in v)
            total += (hi << 16) + lo
        if plan.div > 1:
            assert total % plan.div == 0, (plan.pattern.name, total, plan.div)
            total //= plan.div
        return total

    def run(self, plan: WavePlan):
        """Execute a compiled ``WavePlan``.

        Counting plans return a Python int (divided by ``plan.div``); emit
        plans return the (N, k) int32 embedding matrix in matching order.
        """
        op0 = plan.ops[0]
        outs: list = []
        tr = self.telemetry.tracer
        with (tr.span("execute", plan=plan.pattern.name)
              if tr.enabled else nullcontext()):
            for cap0, dv0, dv1, v1h, n in self._edge_feed(plan.symmetric):
                self._ct_feed_chunks.inc()
                with (tr.span("feed", cat="level", cap=cap0,
                              items=int(np.asarray(n).sum()))
                      if tr.enabled else nullcontext()):
                    caps = {0: cap0}
                    if 1 in op0.row_refs():
                        caps[1] = _neighbor_cap(self.g, v1h)
                    if self.record:
                        self._record(1, self._rows_fn(cap0)(self.g, dv0),
                                     dv1, n)
                    outs += self._plan_descend(plan, 0, {0: dv0, 1: dv1},
                                               caps, None, n)
            self._ct["host_syncs"].inc(len(outs))
            with tr.span("finalize") if tr.enabled else nullcontext():
                return self._finalize(plan, outs)

    def run_set(self, forest):
        """Execute a ``mining.forest.PlanForest``: each feed orientation is
        materialised and iterated ONCE, every trie root consumes the same
        device-resident edge chunks, and shared interior nodes run their
        expand + compaction a single time before fanning out to all child
        branches. Per-leaf accumulators collect (hi, lo) count partials /
        embedding blocks per source plan.

        Returns a list of per-plan results in ``forest.plans`` order (ints
        for counting plans, (N, k) int32 matrices for emit plans) —
        bit-identical to running each plan through ``run`` independently.
        """
        acc: list[list] = [[] for _ in forest.plans]
        tr = self.telemetry.tracer
        with (tr.span("execute", plans=len(forest.plans), forest=True)
              if tr.enabled else nullcontext()):
            for symmetric, roots in ((True, forest.symmetric_roots),
                                     (False, forest.directed_roots)):
                if not roots:
                    continue
                need1 = any(1 in r.op.row_refs() for r in roots)
                for cap0, dv0, dv1, v1h, n in self._edge_feed(symmetric):
                    self._ct_feed_chunks.inc()
                    with (tr.span("feed", cat="level", cap=cap0,
                                  items=int(np.asarray(n).sum()))
                          if tr.enabled else nullcontext()):
                        caps = {0: cap0}
                        if need1:
                            caps[1] = _neighbor_cap(self.g, v1h)
                        if self.record:
                            self._record(1, self._rows_fn(cap0)(self.g, dv0),
                                         dv1, n)
                        for root in roots:
                            self._forest_descend(root, {0: dv0, 1: dv1},
                                                 caps, None, n, acc)
            self._ct["host_syncs"].inc(sum(len(a) for a in acc))
            with tr.span("finalize") if tr.enabled else nullcontext():
                return [self._finalize(plan, parts)
                        for plan, parts in zip(forest.plans, acc)]

    def _forest_descend(self, node, cols: dict, caps: dict, carry, n: int,
                        acc: list) -> None:
        """Execute one forest node on a wave chunk; fan out over children.

        Identical per-op machinery to ``_plan_descend`` — same cached
        executables, same compaction — except an expand's chunk loop feeds
        *every* child branch instead of a single successor op, and terminal
        nodes append their partials to each owning plan's accumulator."""
        op = node.op
        caps_sig = tuple(sorted((c, caps[c]) for c in op.row_refs()))
        cap_base = int(carry.shape[1]) if op.use_carry else caps[op.base]
        vals = tuple(cols[c] for c in self._in_cols(op))
        carry_in = carry if op.use_carry else np.int32(0)
        with self._level_span(op, n):
            if op.kind == "count":
                self._bump(op)
                if op.agg is not None:
                    self._ct_value_lanes.inc()
                    fn = self._plan_agg_fn(op, caps_sig, cap_base)
                else:
                    fn = self._plan_count_fn(op, caps_sig, cap_base)
                part = self._dispatch(op, fn, (self.g, vals, carry_in, n),
                                      items=n, caps_sig=caps_sig)
                for i in node.plans:
                    acc[i].append(part)
                return
            b = (int(carry.shape[0]) if op.use_carry
                 else int(cols[op.base].shape[0])) // self._shards
            out_cap = min([cap_base] + [caps[j] for j in op.inter])
            out_items = -(-b * out_cap // self.chunk) * self.chunk
            if op.kind == "emit":
                parts = self._plan_emit(op, caps_sig, cap_base, out_cap,
                                        out_items, cols, vals, carry_in, n)
                for i in node.plans:
                    acc[i].extend(parts)
                return
            if node.ride_plans:
                self._ct["count_rides"].inc(len(node.ride_plans))
            if not self.device_compact:
                ride_out: dict = {}
                chunks = self._expand_chunks_host(op, caps_sig, cap_base,
                                                  out_cap, cols, vals,
                                                  carry_in, n,
                                                  ride_out=ride_out)
                for cols2, caps2, carry2, vch, m in chunks:
                    self._record(op.level + 1,
                                 self._wave_repr(cols2, op.out_cols, carry2,
                                                 vch),
                                 vch, m)
                    for child in node.children:
                        self._forest_descend(child, cols2, caps2, carry2, m,
                                             acc)
                part = ride_out.get("count_part")
                if part is not None:
                    for i in node.ride_plans:
                        acc[i].append(part)
                    # host-resident partials: no sync at finalize (see above).
                    # Counter.dec raises on underflow — the ride credit can
                    # never exceed syncs actually paid (invariant, tested).
                    self._ct["host_syncs"].dec(len(node.ride_plans))
                return
            exp = self._expand_device(op, caps_sig, cap_base, out_cap,
                                      out_items, vals, carry_in, n,
                                      want_count=bool(node.ride_plans))
            if exp is None:
                return
            rows2, src, verts2, total, caps2, cap2, ride = exp
            if ride is not None:
                for i in node.ride_plans:
                    acc[i].append(ride)
                # ride partials arrived inside the expand's existing meta
                # sync; offset run_set's per-part tally so they aren't
                # double-counted (guarded dec: underflow raises)
                self._ct["host_syncs"].dec(len(node.ride_plans))
            # children that kept every constraint of the shared node consume
            # the compacted worklist as-is (one chunk stream for all of
            # them); children whose branch deferred constraints into
            # residuals get a per-branch packed worklist first, so relaxation
            # never inflates a branch's downstream item count past its
            # independent plan's.
            feeds: list[tuple[list, object, object, int]] = []
            shared = [ch for ch in node.children if not ch.op.residual]
            if shared:
                feeds.append((shared, src, verts2, total))
            for ch in node.children:
                if not ch.op.residual:
                    continue
                pfn, refs = self._residual_pack_fn(
                    op.level, ch.op.residual,
                    int(src.shape[0]) // self._shards)
                rvals = tuple(cols[c] for c in refs)
                src_b, verts_b, tot_b = pfn(rvals, src, verts2, total)
                tot_b, has_b = self._pack_total(tot_b)
                self._ct["host_syncs"].inc()
                if has_b:
                    feeds.append(([ch], src_b, verts_b, tot_b))
            for children, s, v, t in feeds:
                for cols2, carry2, vch, m in self._expand_chunks(
                        op, b, out_cap, cap2, rows2, s, v, cols, t):
                    self._record(op.level + 1,
                                 self._wave_repr(cols2, op.out_cols, carry2,
                                                 vch),
                                 vch, m)
                    for child in children:
                        self._forest_descend(child, cols2, caps2, carry2, m,
                                             acc)

    def _plan_descend(self, plan: WavePlan, oi: int, cols: dict, caps: dict,
                      carry, n: int) -> list:
        """Execute plan.ops[oi] on one wave chunk; recurse over survivors."""
        op = plan.ops[oi]
        caps_sig = tuple(sorted((c, caps[c]) for c in op.row_refs()))
        cap_base = int(carry.shape[1]) if op.use_carry else caps[op.base]
        vals = tuple(cols[c] for c in self._in_cols(op))
        carry_in = carry if op.use_carry else np.int32(0)
        with self._level_span(op, n):
            if op.kind == "count":
                self._bump(op)
                if op.agg is not None:
                    self._ct_value_lanes.inc()
                    fn = self._plan_agg_fn(op, caps_sig, cap_base)
                else:
                    fn = self._plan_count_fn(op, caps_sig, cap_base)
                return [self._dispatch(op, fn, (self.g, vals, carry_in, n),
                                       items=n, caps_sig=caps_sig)]
            b = (int(carry.shape[0]) if op.use_carry
                 else int(cols[op.base].shape[0])) // self._shards
            out_cap = min([cap_base] + [caps[j] for j in op.inter])
            out_items = -(-b * out_cap // self.chunk) * self.chunk
            if op.kind == "emit":
                return self._plan_emit(op, caps_sig, cap_base, out_cap,
                                       out_items, cols, vals, carry_in, n)
            nxt = plan.ops[oi + 1]
            if self.device_compact:
                chunks = self._expand_chunks_device(op, caps_sig, cap_base,
                                                    out_cap, out_items, b,
                                                    cols, vals, carry_in, n)
            else:
                chunks = self._expand_chunks_host(op, caps_sig, cap_base,
                                                  out_cap, cols, vals,
                                                  carry_in, n)
            parts: list = []
            for cols2, caps2, carry2, vch, m in chunks:
                self._record(nxt.level,
                             self._wave_repr(cols2, op.out_cols, carry2, vch),
                             vch, m)
                parts += self._plan_descend(plan, oi + 1, cols2, caps2,
                                            carry2, m)
            return parts

    def _plan_emit(self, op, caps_sig, cap_base, out_cap, out_items, cols,
                   vals, carry_in, n) -> list:
        self._bump(op, host=not self.device_compact)
        if self.device_compact:
            fn = self._plan_emit_fn(op, caps_sig, cap_base, out_cap,
                                    out_items)
            emb, total = self._dispatch(op, fn, (self.g, vals, carry_in, n),
                                        items=n, caps_sig=caps_sig)
            total = int(total)
            self._ct["device_compactions"].inc()
            self._ct["items"].inc(total)
            self._h_wave_items.observe(total)
            if total == 0:
                return []
            return [np.asarray(emb)[:total]]
        hfn = self._plan_expand_host_fn(op, caps_sig, cap_base, out_cap)
        rows2, counts2 = self._dispatch(op, hfn, (self.g, vals, carry_in, n),
                                        items=n, caps_sig=caps_sig, host=True)
        wave, ii = compact(np.asarray(rows2), np.asarray(counts2),
                           return_src=True)
        self._ct["host_compactions"].inc()
        if wave is None:
            return []
        self._ct["items"].inc(len(wave))
        cols_out = [wave.verts if c == op.level else np.asarray(cols[c])[ii]
                    for c in op.out_cols]
        return [np.stack(cols_out, axis=1)]

    def _expand_device(self, op, caps_sig, cap_base, out_cap, out_items,
                       vals, carry_in, n, want_count: bool = False):
        """Run one expand executable + meta sync. Returns ``None`` when no
        survivors, else (rows2, src, verts2, total, caps2, cap2, ride) —
        ``ride`` is the (hi, lo) survivor-count partial when ``want_count``
        (count-rides-expand), else None."""
        self._bump(op)
        fn = self._plan_expand_fn(op, caps_sig, cap_base, out_cap, out_items,
                                  want_count)
        rows2, src, verts2, meta = self._dispatch(
            op, fn, (self.g, vals, carry_in, n), items=n, caps_sig=caps_sig)
        meta = [int(x) for x in np.asarray(meta)]
        if want_count:
            meta, ride = meta[:-2], np.asarray(meta[-2:], dtype=np.int32)
        else:
            ride = None
        total, maxc, dmaxs = meta[0], meta[1], meta[2:]
        self._ct["host_syncs"].inc()
        self._ct["device_compactions"].inc()
        self._ct["items"].inc(total)
        self._h_wave_items.observe(total)
        if total == 0:
            return None
        caps2 = {c: _pow2cap(max(d, 1))
                 for c, d in zip(op.gather_refs, dmaxs)}
        cap2 = round_capacity(maxc) if op.carry_out else 0
        return rows2, src, verts2, total, caps2, cap2, ride

    def _expand_chunks(self, op, b, out_cap, cap2, rows2, src, verts2, cols,
                       total):
        """Slice a compacted (src, verts) worklist into next-level device
        chunks; yields (cols2, carry2, vch, m)."""
        cfn = self._plan_chunk_fn(op, b, out_cap, cap2, self.chunk)
        fwdvals = tuple(cols[c] for c in op.out_cols if c < op.level)
        for lo in range(0, total, self.chunk):
            m = min(self.chunk, total - lo)
            if op.carry_out:
                outs, vch, carry2 = cfn(rows2, src, verts2, fwdvals, lo, m)
            else:
                outs, vch = cfn(rows2, src, verts2, fwdvals, lo, m)
                carry2 = None
            cols2 = dict(zip([c for c in op.out_cols if c < op.level], outs))
            if op.level in op.out_cols:
                cols2[op.level] = vch
            yield cols2, carry2, vch, m

    def _expand_chunks_device(self, op, caps_sig, cap_base, out_cap,
                              out_items, b, cols, vals, carry_in, n):
        """Run one expand level on device; yield the next wave's chunks as
        (cols2, caps2, carry2, vch, m). Shared by the single-plan descent and
        the forest fan-out (one expand feeding k child levels)."""
        exp = self._expand_device(op, caps_sig, cap_base, out_cap, out_items,
                                  vals, carry_in, n)
        if exp is None:
            return
        rows2, src, verts2, total, caps2, cap2, _ = exp
        for cols2, carry2, vch, m in self._expand_chunks(
                op, b, out_cap, cap2, rows2, src, verts2, cols, total):
            yield cols2, caps2, carry2, vch, m

    def _residual_pack_fn(self, level: int, residual: tuple, out_items: int):
        """Per-branch worklist pack: drop items failing a child branch's
        residuals *before* chunking, so a branch that shared a relaxed
        ancestor processes exactly the items its independent plan would
        (order-preserving prefix-sum scatter over the item indices —
        ``compact_indices_scan``, O(items) instead of the index sort's
        O(items·log)). Returns (packing fn, value columns it consumes)."""
        refs = tuple(sorted({c for _, i, j in residual for c in (i, j)
                             if c < level}))

        def build():
            return self._jit_rpack(
                self._rpack_body(level, residual, refs, out_items),
                len(refs))
        return self._executable(("rpack", level, residual, out_items),
                                build), refs

    def _rpack_body(self, level: int, residual: tuple, refs: tuple,
                    out_items: int):
        """Unjitted residual-pack body for ``_residual_pack_fn``."""
        def fn(rvals, src, verts, total):
            get = dict(zip(refs, rvals))

            def val(c):
                return verts if c == level else get[c][src]
            idx = jnp.arange(out_items, dtype=jnp.int32)
            ok = idx < total
            for kind, i, j in residual:
                ok = ok & ((val(i) < val(j)) if kind == "lt"
                           else (val(i) != val(j)))
            order, tot = compact_indices_scan(ok)
            live = idx < tot
            return src[order], \
                jnp.where(live, verts[order], 0).astype(jnp.int32), tot
        return fn

    def _expand_chunks_host(self, op, caps_sig, cap_base, out_cap, cols,
                            vals, carry_in, n, ride_out: dict | None = None):
        """Oracle twin of ``_expand_chunks_device``: same masks, np.nonzero
        compaction + re-upload; same (cols2, caps2, carry2, vch, m) yield.
        ``ride_out`` (forest count-rides) receives the survivor-count sum as
        an (hi, lo) int32 partial under ``"count_part"``."""
        self._bump(op, host=True)
        hfn = self._plan_expand_host_fn(op, caps_sig, cap_base, out_cap)
        rows2, counts2 = self._dispatch(op, hfn, (self.g, vals, carry_in, n),
                                        items=n, caps_sig=caps_sig, host=True)
        if ride_out is not None:
            t = int(np.asarray(counts2, dtype=np.int64).sum())
            ride_out["count_part"] = np.asarray([t >> 16, t & 0xFFFF],
                                                dtype=np.int32)
        wave, ii = compact(np.asarray(rows2), np.asarray(counts2),
                           return_src=True)
        self._ct["host_syncs"].inc()
        self._ct["host_compactions"].inc()
        if wave is None:
            return
        total = len(wave)
        self._ct["items"].inc(total)
        self._h_wave_items.observe(total)
        fwd = [c for c in op.out_cols if c < op.level]
        hostcols = {c: np.asarray(cols[c])[ii] for c in fwd}
        caps2 = {c: _neighbor_cap(self.g, wave.verts if c == op.level
                                  else hostcols[c])
                 for c in op.gather_refs}
        for lo in range(0, total, self.chunk):
            m = min(self.chunk, total - lo)
            sl = slice(lo, lo + self.chunk)
            cols2 = {c: jnp.asarray(_pad_to(hostcols[c][sl], self.chunk, 0))
                     for c in fwd}
            vch = jnp.asarray(_pad_to(wave.verts[sl], self.chunk, 0))
            if op.level in op.out_cols:
                cols2[op.level] = vch
            carry2 = None
            if op.carry_out:
                carry2 = jnp.asarray(
                    _pad_to(wave.rows[sl], self.chunk, SENTINEL))
            yield cols2, caps2, carry2, vch, m

    # ----------------------------------------------- plan wrappers (compat)
    def count_edges(self, symmetric: bool = True, bounded: bool = True) -> int:
        """Σ over edges of |N(v0) ∩ N(v1) (∩ [0, v1))| — triangle / nested
        triangle counting as a one-level plan."""
        return self.run(compile_pattern(
            _EDGE_COUNT_PATTERNS[(symmetric, bounded)]))

    def clique(self, k: int) -> int:
        """k-clique counting, k >= 3 (compiled chain-restricted plan)."""
        if k < 3:
            raise ValueError("clique needs k >= 3")
        return self.run(compile_pattern(clique_pattern(k)))

    def three_chain_induced(self) -> int:
        """Per directed edge (m, a): |{b ∈ N(m): b > a, b ∉ N(a)}|."""
        from .plan import THREE_CHAIN_INDUCED
        return self.run(compile_pattern(THREE_CHAIN_INDUCED))

    def tailed_triangle(self) -> int:
        """Fig. 2b: BoundedIntersect(N0, N1, v0) per directed edge; the tail
        level compiles away into the closed-form deg(v1) - 2 multiplier."""
        from .plan import TAILED_TRIANGLE
        return self.run(compile_pattern(TAILED_TRIANGLE))
