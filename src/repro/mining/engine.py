"""Wavefront pattern-enumeration engine: device-resident, host-orchestrated.

The paper's execution model is a core issuing stream instructions whose
operands live in the S-Cache. The TPU translation keeps the *dataflow* —
(prefix stream) x (neighbor list) bounded intersections — but replaces the
instruction stream with level-synchronous waves:

  level 1: the half edge list (v1 < v0, straight from the CSR offset register)
  level l: for each surviving work item, S_l = S_{l-1} ∩ N(v) ∩ [0, v)

Between levels the surviving (prefix, vertex) work items are compacted into
a dense worklist (the translation buffer of §IV-F), and the prefix capacity
is re-derived from the actual max survivor length — the paper's Fig. 14
observation (clique streams are short) becomes an adaptive buffer size.

Two compaction paths exist:

  * **device (fast path, ``WaveRunner``)**: the expand's match mask is
    compacted on-device (masked sort + prefix-sum scatter,
    ``ops.xinter_compact``) into the next wave's (rows, verts) buffers;
    only three level-boundary scalars (total, max count, max degree) ever
    cross to the host. Executables are cached per (cap_a, cap_b, chunk) so
    degree-bucketed shapes never retrace, and the level-1 edge feed is
    double-buffered (chunk N+1 uploads while chunk N computes) — the
    S-Cache residency win, restated as "operands never leave HBM".
  * **host (oracle, ``compact``)**: ``np.nonzero`` + re-upload. Kept as the
    semantic reference the device path is property-tested against.

Work is chunked so device buffers stay bounded; padded tail items carry
bound=0 so they contribute nothing (branch-free masking, no special cases).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.batch import batch_inter, batch_inter_count, batch_sub_count
from repro.core.stream import LANE, SENTINEL, round_capacity
from repro.graph.csr import CSRGraph, padded_rows
from repro.kernels.ops import xinter_compact, xinter_count


def half_edges(g: CSRGraph) -> np.ndarray:
    """(E/2, 2) array of (v0, v1) with v1 < v0 — the symmetric-breaking edge
    frontier, read directly via the CSR offset register (offsets[v0] = number
    of neighbors < v0)."""
    indptr = np.asarray(g.indptr)
    offsets = np.asarray(g.offsets)
    indices = np.asarray(g.indices)
    counts = offsets.astype(np.int64)
    v0 = np.repeat(np.arange(g.num_vertices, dtype=np.int32), counts)
    # position of each kept slot within its row
    pos = np.arange(counts.sum(), dtype=np.int64) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    v1 = indices[indptr[v0].astype(np.int64) + pos]
    return np.stack([v0, v1], axis=1)


def directed_edges(g: CSRGraph) -> np.ndarray:
    """(E, 2) all directed edges (v0, v1) in CSR order."""
    indptr = np.asarray(g.indptr).astype(np.int64)
    v0 = np.repeat(np.arange(g.num_vertices, dtype=np.int32), np.diff(indptr))
    v1 = np.asarray(g.indices)[: g.num_edges]
    return np.stack([v0, v1], axis=1)


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] == n:
        return x
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


@dataclasses.dataclass
class Wave:
    """A compacted frontier: prefix rows + the vertex that extends each."""

    rows: np.ndarray    # (N, cap) int32 sorted sentinel-padded prefix streams
    verts: np.ndarray   # (N,) int32 extension vertex (also the bound)

    def __len__(self) -> int:
        return int(self.verts.shape[0])


def _pow2cap(n: int) -> int:
    """Smallest power-of-two LANE multiple >= n (degree bucket capacity)."""
    c = LANE
    while c < n:
        c *= 2
    return c


def edge_chunks(g: CSRGraph, chunk: int, symmetric: bool = True):
    """Host half of the level-1 feed: yields (cap, v0, v1, n) degree-bucketed
    chunk-padded int32 vertex arrays *without* materialising neighbor rows —
    row gathers happen on-device so the feed can be double-buffered."""
    edges = half_edges(g) if symmetric else directed_edges(g)
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    caps = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    for cap in np.unique(caps):
        sel = edges[caps == cap]
        # fixed chunk width: one compiled shape per degree bucket
        nb = min(chunk, _pow2cap(sel.shape[0]))
        for lo in range(0, sel.shape[0], nb):
            sl = sel[lo: lo + nb]
            n = sl.shape[0]
            v0 = _pad_to(sl[:, 0].astype(np.int32), nb, 0)
            v1 = _pad_to(sl[:, 1].astype(np.int32), nb, 0)
            yield int(cap), v0, v1, n


def edge_wave(g: CSRGraph, chunk: int, symmetric: bool = True):
    """Yield level-1 waves: (v0 rows are N(v0), vert = v1), bucketed by the
    prefix vertex's degree so per-edge work is O(bucket) not O(max degree)
    (<= 2x padding waste — the paper's Fig. 14 stream-length skew exploited
    as static capacity classes; EXPERIMENTS.md §Perf mining iteration)."""
    for cap, v0, v1, n in edge_chunks(g, chunk, symmetric):
        rows, _ = padded_rows(g, jnp.asarray(v0), cap)
        yield Wave(rows=rows, verts=v1), n


def _neighbor_cap(g: CSRGraph, verts: np.ndarray) -> int:
    deg = np.asarray(g.degrees)
    mx = int(deg[np.asarray(verts)].max()) if len(verts) else 1
    return _pow2cap(max(mx, 1))


def expand_count(g: CSRGraph, wave: Wave, bounded: bool = True) -> jnp.ndarray:
    """counts[i] = |rows_i ∩ N(verts_i) ∩ [0, verts_i)| (bound dropped when
    ``bounded`` is False). Neighbor capacity = the chunk's degree bucket."""
    capn = _neighbor_cap(g, wave.verts)
    nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
    bounds = jnp.asarray(wave.verts) if bounded else None
    return batch_inter_count(jnp.asarray(wave.rows), nbr, bounds)


def expand(g: CSRGraph, wave: Wave, out_cap: int | None = None):
    """Materialise S_l rows: (rows (N, out_cap), counts (N,))."""
    capn = _neighbor_cap(g, wave.verts)
    rows_a = jnp.asarray(wave.rows)
    cap = out_cap or min(rows_a.shape[1], capn)
    nbr, _ = padded_rows(g, jnp.asarray(wave.verts), capn)
    rows, counts = batch_inter(rows_a, nbr,
                               jnp.asarray(wave.verts), out_cap=cap)
    return np.asarray(rows), np.asarray(counts)


def compact(rows: np.ndarray, counts: np.ndarray, limit: int | None = None,
            return_src: bool = False):
    """Host compaction oracle: expand (rows, counts) into the next Wave.

    The device fast path (``WaveRunner`` via ``ops.xinter_compact``) is
    property-tested to produce item-for-item identical waves; this np.nonzero
    form stays as the semantic reference and the ``return_src`` provider for
    embedding enumeration (``apps.triangle_list``).

    Every valid key rows[i, j] (j < counts[i]) becomes a work item whose
    prefix is rows[i] and whose extension vertex/bound is that key. The
    prefix capacity shrinks to the padded max survivor length (adaptive
    stream capacity — clique streams are short, paper Fig. 14).
    ``return_src`` additionally yields the source row index of each item
    (needed when the caller must recover the enclosing prefix vertices).
    """
    counts = counts[: limit] if limit is not None else counts
    rows = rows[: counts.shape[0]]
    maxc = int(counts.max()) if counts.size else 0
    if maxc == 0:
        return (None, None) if return_src else None
    cap = round_capacity(maxc)
    col = np.arange(rows.shape[1])
    ii, jj = np.nonzero(col[None, :] < counts[:, None])
    verts = rows[ii, jj].astype(np.int32)
    wave = Wave(rows=rows[ii, :cap], verts=verts)
    return (wave, ii) if return_src else wave


def pair_chunks(g: CSRGraph, edges: np.ndarray, chunk: int):
    """Host half of the pair feed: yields (cap_a, cap_b, v0, v1, n) without
    materialising rows (device gathers, double-bufferable)."""
    if edges.shape[0] == 0:
        return
    deg = np.asarray(g.degrees)
    cap_a = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 0]]])
    cap_b = np.array([_pow2cap(max(int(d), 1)) for d in deg[edges[:, 1]]])
    keys = cap_a.astype(np.int64) << 32 | cap_b
    for key in np.unique(keys):
        ca, cb = int(key >> 32), int(key & 0xFFFFFFFF)
        sel = edges[keys == key]
        nb = min(chunk, _pow2cap(sel.shape[0]))
        for lo in range(0, sel.shape[0], nb):
            sl = sel[lo: lo + nb]
            n = sl.shape[0]
            v0 = _pad_to(sl[:, 0].astype(np.int32), nb, 0)
            v1 = _pad_to(sl[:, 1].astype(np.int32), nb, 0)
            yield ca, cb, v0, v1, n


def pair_wave(g: CSRGraph, edges: np.ndarray, chunk: int):
    """Yield degree-bucketed padded row pairs for an (N, 2) vertex-pair list:
    (rows_a, rows_b, v0, v1, n_valid). Used by apps that intersect/subtract
    two neighbor lists per edge (TT, induced TC)."""
    for ca, cb, v0, v1, n in pair_chunks(g, edges, chunk):
        rows_a, _ = padded_rows(g, jnp.asarray(v0), ca)
        rows_b, _ = padded_rows(g, jnp.asarray(v1), cb)
        yield rows_a, rows_b, v0, v1, n


def wave_chunks(wave: Wave, chunk: int):
    """Split a host wave into padded device chunks; yields (Wave, n_valid).

    Padding uses vertex 0 with bound 0 => zero contribution."""
    n = len(wave)
    for lo in range(0, max(n, 1), chunk):
        r = wave.rows[lo: lo + chunk]
        v = wave.verts[lo: lo + chunk]
        if r.shape[0] == 0:
            continue
        k = r.shape[0]
        yield Wave(rows=_pad_to(r, chunk, SENTINEL), verts=_pad_to(v, chunk, 0)), k


DEFAULT_CHUNK = 4096


def choose_chunk(cap: int, budget_bytes: int = 64 << 20) -> int:
    """Chunk size so one wave's buffers stay within ``budget_bytes``."""
    per_row = cap * 4 * 4  # rows + neighbor rows + output + slack
    c = max(LANE, budget_bytes // max(per_row, 1))
    return int(min(DEFAULT_CHUNK * 4, (c // LANE) * LANE))


# ---------------------------------------------------------------------------
# WaveRunner — the device-resident wavefront pipeline
# ---------------------------------------------------------------------------


class WaveRunner:
    """Device-resident wavefront orchestrator for the mining apps.

    Three mechanisms turn the level-synchronous loop into a device pipeline:

    * **executable cache** keyed by (kind, cap_a, cap_b, chunk): every
      degree bucket / level capacity gets one jitted closure fusing the
      neighbor gather with its intersection (the host loop never re-traces
      a shape it has seen — ``stats['exec_hits']`` proves it);
    * **fused expand_compact**: ``ops.xinter_compact`` leaves the next
      wave's (rows, verts) work items on device; the only host traffic per
      level is a 3-scalar sync (total, max survivor count, max extension
      degree) that sizes the next level's static capacities;
    * **double-buffered feeds**: the level-1 edge/pair chunks are
      ``jax.device_put`` one chunk ahead of compute.

    ``device_compact=False`` runs the same loop through the host
    ``compact`` oracle (np.nonzero + re-upload) — the twin the fast path is
    property-tested against, and the "before" leg of the wave-throughput
    benchmark. ``record=True`` captures every wave's live (rows, verts)
    into ``trace`` for those comparisons.
    """

    def __init__(self, g: CSRGraph, chunk: int | None = None,
                 backend: str = "auto", device_compact: bool = True,
                 record: bool = False):
        self.g = g
        self.chunk = chunk or choose_chunk(g.padded_max_degree)
        self.backend = backend
        self.device_compact = device_compact
        self.record = record
        self.trace: list[tuple[int, np.ndarray, np.ndarray]] = []
        self._exec: dict[tuple, Callable] = {}
        self.stats = {"exec_hits": 0, "exec_misses": 0, "host_syncs": 0,
                      "device_compactions": 0, "host_compactions": 0,
                      "items": 0}

    # ------------------------------------------------------------------ cache
    def _executable(self, key: tuple, build: Callable) -> Callable:
        fn = self._exec.get(key)
        if fn is None:
            fn = self._exec[key] = build()
            self.stats["exec_misses"] += 1
        else:
            self.stats["exec_hits"] += 1
        return fn

    def _rows_fn(self, cap: int):
        def build():
            @jax.jit
            def fn(g, vs):
                return padded_rows(g, vs, cap)[0]
            return fn
        return self._executable(("rows", cap), build)

    def _count_fn(self, cap_a: int, capn: int, bounded: bool):
        backend = self.backend

        def build():
            @jax.jit
            def fn(g, rows, verts, n):
                nbr, _ = padded_rows(g, verts, capn)
                bounds = verts if bounded else None
                counts = xinter_count(rows, nbr, bounds, backend=backend)
                # explicit validity mask: unbounded counts (nested variant)
                # are NOT self-masking on bound-0 padding items
                live = jnp.arange(rows.shape[0], dtype=jnp.int32) < n
                return jnp.sum(jnp.where(live, counts, 0), dtype=jnp.int32)
            return fn
        return self._executable(("count", cap_a, capn, bounded), build)

    def _expand_fn(self, cap_a: int, capn: int, out_cap: int, out_items: int):
        """Fused gather + bounded intersect + on-device compaction."""
        backend = self.backend

        def build():
            @jax.jit
            def fn(g, rows, verts):
                nbr, _ = padded_rows(g, verts, capn)
                rows2, counts2, src, verts2, total, maxc = xinter_compact(
                    rows, nbr, bounds=verts, out_cap=out_cap,
                    out_items=out_items, backend=backend)
                live = jnp.arange(out_items, dtype=jnp.int32) < total
                dmax = jnp.max(jnp.where(live, g.degrees[verts2], 0))
                meta = jnp.stack([total, maxc, dmax])
                return rows2, src, verts2, meta
            return fn
        return self._executable(
            ("expand", cap_a, capn, out_cap, out_items), build)

    def _expand_host_fn(self, cap_a: int, capn: int, out_cap: int):
        """Oracle-path twin of ``_expand_fn``: expand only, compact on host."""
        def build():
            @jax.jit
            def fn(g, rows, verts):
                nbr, _ = padded_rows(g, verts, capn)
                return batch_inter(rows, nbr, verts, out_cap=out_cap)
            return fn
        return self._executable(("expandh", cap_a, capn, out_cap), build)

    def _chunk_fn(self, b: int, out_cap: int, cap2: int, chunk: int):
        """Slice the compacted worklist into the next level's device wave."""
        def build():
            @jax.jit
            def fn(rows2, src, verts2, lo):
                s = jax.lax.dynamic_slice_in_dim(src, lo, chunk)
                v = jax.lax.dynamic_slice_in_dim(verts2, lo, chunk)
                return rows2[s, :cap2], v
            return fn
        return self._executable(("chunk", b, out_cap, cap2, chunk), build)

    # ------------------------------------------------------------------ feeds
    @staticmethod
    def _double_buffered(chunks, put_idx: frozenset):
        """Run one item ahead of the consumer, ``jax.device_put``-ing the
        arrays at ``put_idx``: chunk N+1's upload dispatches (async) while
        the consumer computes on chunk N."""
        pending = None
        for tup in chunks:
            nxt = tuple(jax.device_put(x) if i in put_idx else x
                        for i, x in enumerate(tup))
            if pending is not None:
                yield pending
            pending = nxt
        if pending is not None:
            yield pending

    def _edge_feed(self, symmetric: bool = True):
        """Double-buffered level-1 feed: (cap, dv0, dv1, v1_host, n)."""
        chunks = ((cap, v0, v1, v1, n) for cap, v0, v1, n
                  in edge_chunks(self.g, self.chunk, symmetric))
        return self._double_buffered(chunks, frozenset({1, 2}))

    def _pair_feed(self, edges: np.ndarray):
        """Double-buffered pair feed: (cap_a, cap_b, dv0, dv1, v1_host, n)."""
        chunks = ((ca, cb, v0, v1, v1, n) for ca, cb, v0, v1, n
                  in pair_chunks(self.g, edges, self.chunk))
        return self._double_buffered(chunks, frozenset({2, 3}))

    # ------------------------------------------------------------- wave loops
    def _record(self, level: int, rows, verts, n: int) -> None:
        if self.record:
            self.trace.append((level, np.asarray(rows)[:n].copy(),
                               np.asarray(verts)[:n].copy()))

    def count_edges(self, symmetric: bool = True, bounded: bool = True) -> int:
        """Σ over edges of |N(v0) ∩ N(v1) (∩ [0, v1))| — triangle / nested
        triangle counting as one wave level."""
        parts = []
        for cap, dv0, dv1, v1h, n in self._edge_feed(symmetric):
            rows = self._rows_fn(cap)(self.g, dv0)
            self._record(1, rows, dv1, n)
            capn = _neighbor_cap(self.g, v1h)
            parts.append(self._count_fn(cap, capn, bounded)(self.g, rows,
                                                            dv1, n))
        self.stats["host_syncs"] += len(parts)
        return sum(int(p) for p in parts)

    def clique(self, k: int) -> int:
        """k-clique counting on the wavefront, k >= 3."""
        if k < 3:
            raise ValueError("clique needs k >= 3")
        parts = []
        for cap, dv0, dv1, v1h, n in self._edge_feed(True):
            rows = self._rows_fn(cap)(self.g, dv0)
            self._record(1, rows, dv1, n)
            capn = _neighbor_cap(self.g, v1h)
            parts += self._descend(rows, dv1, capn, k - 2, n)
        self.stats["host_syncs"] += len(parts)
        return sum(int(p) for p in parts)

    def _descend(self, rows, verts, capn: int, depth: int, n: int) -> list:
        """One wavefront level: count at the last level, else expand +
        compact + recurse over the next wave's chunks."""
        cap_a = int(rows.shape[1])
        if depth == 1:
            return [self._count_fn(cap_a, capn, True)(self.g, rows, verts, n)]
        out_cap = min(cap_a, capn)
        b = int(rows.shape[0])
        out_items = -(-b * out_cap // self.chunk) * self.chunk
        if self.device_compact:
            rows2, src, verts2, meta = self._expand_fn(
                cap_a, capn, out_cap, out_items)(self.g, rows, verts)
            total, maxc, dmax = (int(x) for x in np.asarray(meta))
            self.stats["host_syncs"] += 1
            self.stats["device_compactions"] += 1
            self.stats["items"] += total
            if total == 0:
                return []
            cap2 = round_capacity(maxc)
            capn2 = _pow2cap(max(dmax, 1))
            cfn = self._chunk_fn(b, out_cap, cap2, self.chunk)
            parts = []
            for lo in range(0, total, self.chunk):
                crows, cverts = cfn(rows2, src, verts2, lo)
                m = min(self.chunk, total - lo)
                self._record(depth, crows, cverts, m)
                parts += self._descend(crows, cverts, capn2, depth - 1, m)
            return parts
        # oracle path: same loop through host np.nonzero compaction
        rows2, counts2 = self._expand_host_fn(
            cap_a, capn, out_cap)(self.g, rows, verts)
        wave = compact(np.asarray(rows2), np.asarray(counts2))
        self.stats["host_syncs"] += 1
        self.stats["host_compactions"] += 1
        if wave is None:
            return []
        self.stats["items"] += len(wave)
        capn2 = _neighbor_cap(self.g, wave.verts)
        parts = []
        for w, m in wave_chunks(wave, self.chunk):
            crows = jnp.asarray(w.rows)
            cverts = jnp.asarray(w.verts)
            self._record(depth, crows, cverts, m)
            parts += self._descend(crows, cverts, capn2, depth - 1, m)
        return parts

    # ------------------------------------------------------- pair-based apps
    def _pair_counts_fn(self, ca: int, cb: int, kind: str):
        def build():
            @jax.jit
            def fn(g, v0, v1):
                rows_a, _ = padded_rows(g, v0, ca)
                rows_b, _ = padded_rows(g, v1, cb)
                if kind == "chain":
                    full = batch_sub_count(rows_a, rows_b)
                    below = batch_sub_count(rows_a, rows_b, v1)
                    return full - below - 1
                return batch_inter_count(rows_a, rows_b, v0)
            return fn
        return self._executable(("pair", ca, cb, kind), build)

    def three_chain_induced(self) -> int:
        """Per directed edge (m, a): |{b ∈ N(m): b > a, b ∉ N(a)}|."""
        total = 0
        for ca, cb, dm, da, ah, n in self._pair_feed(directed_edges(self.g)):
            per_edge = self._pair_counts_fn(ca, cb, "chain")(self.g, dm, da)
            total += int(np.asarray(per_edge)[:n].sum())
            self.stats["host_syncs"] += 1
        return total

    def tailed_triangle(self) -> int:
        """Fig. 2b: BoundedIntersect(N0, N1, v0) per directed edge, each
        candidate v2 contributing deg(v1) - 2 tails."""
        deg = np.asarray(self.g.degrees, dtype=np.int64)
        total = 0
        for ca, cb, dv0, dv1, v1h, n in self._pair_feed(directed_edges(self.g)):
            c = self._pair_counts_fn(ca, cb, "tailed")(self.g, dv0, dv1)
            c = np.asarray(c)[:n].astype(np.int64)
            total += int((c * (deg[v1h[:n]] - 2)).sum())
            self.stats["host_syncs"] += 1
        return total
