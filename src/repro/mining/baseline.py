"""InHouseAutoMine — the paper's CPU baseline (§VI, footnote 1).

Scalar pattern enumeration with the same schedules and symmetry breaking as
``apps.py`` but executed as ordinary CPU code: python loops over vertices and
``np.intersect1d``/``searchsorted`` per intersection. This is the Fig. 3
code pattern (tight loops, data-dependent work) that IntersectX accelerates;
benchmarks report IntersectX-engine/ InHouseAutoMine speedups as the Fig. 9
analogue.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def _adj(g: CSRGraph):
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    return indptr, indices


def _nbrs(indptr, indices, v) -> np.ndarray:
    return indices[indptr[v]: indptr[v + 1]]


def triangle_count(g: CSRGraph) -> int:
    indptr, indices = _adj(g)
    offsets = np.asarray(g.offsets)
    total = 0
    for v0 in range(g.num_vertices):
        n0 = _nbrs(indptr, indices, v0)
        for v1 in n0[: offsets[v0]]:                    # v1 < v0
            n1 = _nbrs(indptr, indices, v1)
            common = np.intersect1d(n0, n1, assume_unique=True)
            total += int(np.searchsorted(common, v1))   # bounded: v2 < v1
    return total


def three_chain_count(g: CSRGraph, induced: bool = False) -> int:
    indptr, indices = _adj(g)
    deg = np.asarray(g.degrees, dtype=np.int64)
    if not induced:
        return int((deg * (deg - 1) // 2).sum())
    total = 0
    for m in range(g.num_vertices):
        nm = _nbrs(indptr, indices, m)
        for a in nm:
            na = _nbrs(indptr, indices, a)
            rest = np.setdiff1d(nm, na, assume_unique=True)
            total += int(rest.shape[0] - np.searchsorted(rest, a, side="right"))
    return total


def tailed_triangle_count(g: CSRGraph) -> int:
    indptr, indices = _adj(g)
    deg = np.asarray(g.degrees, dtype=np.int64)
    total = 0
    for v0 in range(g.num_vertices):
        n0 = _nbrs(indptr, indices, v0)
        for v1 in n0:
            n1 = _nbrs(indptr, indices, v1)
            common = np.intersect1d(n0, n1, assume_unique=True)
            c = int(np.searchsorted(common, v0))        # v2 < v0
            total += c * int(deg[v1] - 2)
    return total


def three_motif(g: CSRGraph) -> dict[str, int]:
    return {"triangle": triangle_count(g),
            "chain": three_chain_count(g, induced=True)}


def clique_count(g: CSRGraph, k: int) -> int:
    if k == 3:
        return triangle_count(g)
    indptr, indices = _adj(g)
    offsets = np.asarray(g.offsets)
    total = 0

    def rec(prefix_set: np.ndarray, level: int) -> int:
        if level == k:
            return prefix_set.shape[0]
        c = 0
        for v in prefix_set:
            nv = _nbrs(indptr, indices, v)
            nxt = np.intersect1d(prefix_set, nv, assume_unique=True)
            nxt = nxt[: np.searchsorted(nxt, v)]        # bound: < v
            if level + 1 == k:
                c += nxt.shape[0]
            elif nxt.shape[0]:
                c += rec(nxt, level + 1)
        return c

    for v0 in range(g.num_vertices):
        n0 = _nbrs(indptr, indices, v0)
        for v1 in n0[: offsets[v0]]:
            n1 = _nbrs(indptr, indices, v1)
            s2 = np.intersect1d(n0, n1, assume_unique=True)
            s2 = s2[: np.searchsorted(s2, v1)]
            if s2.shape[0]:
                total += rec(s2, 3) if k > 3 else s2.shape[0]
    return total
