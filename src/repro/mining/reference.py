"""Brute-force oracles for the mining applications (test-only, networkx/numpy).

Nothing here is used by the library at runtime; tests assert that the
wavefront engine, the InHouseAutoMine baseline and the exhaustive-check
baseline all agree with these definitions on small graphs.
"""
from __future__ import annotations

import itertools

import networkx as nx
import numpy as np

from repro.graph.csr import CSRGraph, edge_list


def to_networkx(g: CSRGraph) -> nx.Graph:
    G = nx.Graph()
    G.add_nodes_from(range(g.num_vertices))
    G.add_edges_from(map(tuple, edge_list(g)))
    return G


def triangle_count(g: CSRGraph) -> int:
    G = to_networkx(g)
    return sum(nx.triangles(G).values()) // 3


def clique_count(g: CSRGraph, k: int) -> int:
    G = to_networkx(g)
    return sum(1 for c in nx.enumerate_all_cliques(G) if len(c) == k)


def three_chain_count(g: CSRGraph, induced: bool = False) -> int:
    deg = np.asarray(g.degrees, dtype=np.int64)
    non_induced = int((deg * (deg - 1) // 2).sum())
    if not induced:
        return non_induced
    return non_induced - 3 * triangle_count(g)


def tailed_triangle_count(g: CSRGraph) -> int:
    """Σ over triangles of (deg(a)+deg(b)+deg(c) - 6)."""
    G = to_networkx(g)
    deg = np.asarray(g.degrees, dtype=np.int64)
    total = 0
    for c in nx.enumerate_all_cliques(G):
        if len(c) == 3:
            total += int(deg[list(c)].sum() - 6)
    return total


def motif3(g: CSRGraph) -> dict[str, int]:
    return {"triangle": triangle_count(g),
            "chain": three_chain_count(g, induced=True)}


# degree-multiset signature of each connected 4-vertex induced subgraph
_MOTIF4_SIG = {
    (1, 1, 2, 2): "4-path", (1, 1, 1, 3): "4-star", (2, 2, 2, 2): "4-cycle",
    (1, 2, 2, 3): "paw", (2, 2, 3, 3): "diamond", (3, 3, 3, 3): "4-clique",
}


def four_motif_counts(g: CSRGraph) -> dict[str, int]:
    """Brute-force induced 4-motif census: classify every vertex quadruple
    by the degree multiset of its induced subgraph (unique per motif; the
    disconnected shapes — incl. triangle+isolated (0,2,2,2) — drop out).
    Vectorised over all C(n,4) combinations: small graphs only."""
    n = g.num_vertices
    A = np.zeros((n, n), dtype=bool)
    e = edge_list(g)
    A[e[:, 0], e[:, 1]] = True
    quads = np.array(list(itertools.combinations(range(n), 4)), dtype=np.int64)
    if quads.size == 0:
        return {m: 0 for m in _MOTIF4_SIG.values()}
    deg = np.zeros((quads.shape[0], 4), dtype=np.int8)
    for i, j in itertools.combinations(range(4), 2):
        hit = A[quads[:, i], quads[:, j]]
        deg[:, i] += hit
        deg[:, j] += hit
    deg.sort(axis=1)
    out = {m: 0 for m in _MOTIF4_SIG.values()}
    sigs, counts = np.unique(deg, axis=0, return_counts=True)
    for sig, c in zip(sigs, counts):
        m = _MOTIF4_SIG.get(tuple(int(x) for x in sig))
        if m is not None:
            out[m] = int(c)
    return out


def pattern_count_oracle(g: CSRGraph, pat) -> int:
    """Count embeddings of a ``mining.plan.Pattern`` by brute force.

    Enumerates every injective vertex mapping (itertools.permutations),
    checks pattern edges (plus non-edges when ``pat.induced``) and the
    declared symmetry-breaking restrictions, then divides by ``pat.div`` —
    the semantic definition every compiled ``WavePlan`` must reproduce.
    Exponential: tiny graphs only.
    """
    n = g.num_vertices
    A = np.zeros((n, n), dtype=bool)
    e = edge_list(g)
    A[e[:, 0], e[:, 1]] = True
    k = pat.k
    pairs = [(i, j, pat.adj[i][j]) for i in range(k) for j in range(i + 1, k)]
    total = 0
    for vs in itertools.permutations(range(n), k):
        ok = all(A[vs[i], vs[j]] == want if pat.induced
                 else (not want or A[vs[i], vs[j]])
                 for i, j, want in pairs)
        if ok and all(vs[i] < vs[j] for i, j in pat.restrictions):
            total += 1
    assert total % pat.div == 0
    return total // pat.div


def weighted_pattern_oracle(g: CSRGraph, pat, op: str = "sum") -> float:
    """SVPU value-plane oracle: aggregate embedding weights by brute force.

    An embedding's value is the product over ALL pattern edges of the
    matched graph edge's weight (``g.edge_values``); the query result is
    the ``op`` ('sum' | 'max' | 'min') reduction over every embedding
    ``pattern_count_oracle`` would count. Mirrors ``Miner.aggregate``:
    requires a fully symmetry-broken schedule (``pat.div == 1``) and
    returns 0.0 when no embedding exists. Host float64 enumeration —
    exponential, tiny graphs only.
    """
    if g.edge_values is None:
        raise ValueError("graph has no edge_values (see with_edge_values)")
    if pat.div != 1:
        raise ValueError("weighted oracle needs div == 1 schedules")
    n = g.num_vertices
    e = edge_list(g)
    vals = np.asarray(g.edge_values, dtype=np.float64)[: g.num_edges]
    A = np.zeros((n, n), dtype=bool)
    W = np.zeros((n, n), dtype=np.float64)
    A[e[:, 0], e[:, 1]] = True
    W[e[:, 0], e[:, 1]] = vals
    k = pat.k
    pairs = [(i, j, pat.adj[i][j]) for i in range(k) for j in range(i + 1, k)]
    acc: list[float] = []
    for vs in itertools.permutations(range(n), k):
        ok = all(A[vs[i], vs[j]] == want if pat.induced
                 else (not want or A[vs[i], vs[j]])
                 for i, j, want in pairs)
        if ok and all(vs[i] < vs[j] for i, j in pat.restrictions):
            value = 1.0
            for i, j, want in pairs:
                if want:
                    value *= W[vs[i], vs[j]]
            acc.append(value)
    if not acc:
        return 0.0
    if op == "sum":
        return float(sum(acc))
    if op == "max":
        return float(max(acc))
    if op == "min":
        return float(min(acc))
    raise ValueError(f"op must be 'sum' | 'max' | 'min', got {op!r}")


def fsm_oracle(g: CSRGraph, labels: np.ndarray, min_support: int,
               metric: str = "mni") -> dict:
    """Brute-force FSM oracle (tiny labelled graphs only).

    Enumerates every non-induced embedding of each <=3-edge pattern shape
    explicitly, fills MNI domains per pattern-vertex orbit, and returns
    {canonical pattern: support} for the frequent ones. ``metric`` = 'mni'
    or 'count' (the sFSM/GRAMER metric). Shares canonical keys with
    ``repro.mining.fsm`` so results are directly comparable.
    """
    from .fsm import edge_key, wedge_key, triangle_key, star3_key

    L = np.asarray(labels)
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    adj = [indices[indptr[v]: indptr[v + 1]] for v in range(g.num_vertices)]
    domains: dict[tuple, dict[tuple, set]] = {}
    counts: dict[tuple, int] = {}

    def add(key, orbit_assignments):
        dom = domains.setdefault(key, {})
        for orbit, v in orbit_assignments:
            dom.setdefault(orbit, set()).add(int(v))
        counts[key] = counts.get(key, 0) + 1

    # edges (unordered)
    for u in range(g.num_vertices):
        for v in adj[u]:
            if v <= u:
                continue
            k = edge_key(L[u], L[v])
            add(k, [(("end", int(L[u])), u), (("end", int(L[v])), v)])
    # wedges: center m, unordered leaf pairs
    for m in range(g.num_vertices):
        for a, b in itertools.combinations(adj[m].tolist(), 2):
            k = wedge_key(L[a], L[m], L[b])
            add(k, [(("center",), m), (("leaf", int(L[a])), a),
                    (("leaf", int(L[b])), b)])
    # triangles
    for u in range(g.num_vertices):
        for v in adj[u]:
            if v <= u:
                continue
            common = np.intersect1d(adj[u], adj[v], assume_unique=True)
            for w in common[common > v]:
                k = triangle_key(L[u], L[v], L[w])
                add(k, [(("v", int(L[x])), x) for x in (u, v, int(w))])
    # 3-stars: center + unordered leaf triples
    for m in range(g.num_vertices):
        for tri in itertools.combinations(adj[m].tolist(), 3):
            k = star3_key(int(L[m]), tuple(int(L[x]) for x in tri))
            add(k, [(("center",), m)] + [(("leaf", int(L[x])), x) for x in tri])
    # 4-paths: ordered tuples, registered in canonical orientation(s)
    for b in range(g.num_vertices):
        for c in adj[b]:
            for a in adj[b]:
                if a == c:
                    continue
                for d in adj[int(c)]:
                    if d == b or d == a:
                        continue
                    seq = (int(L[a]), int(L[b]), int(L[c]), int(L[d]))
                    canon = min(seq, seq[::-1])
                    k = ("path4", canon)
                    tup = (a, b, int(c), int(d))
                    if seq == canon:
                        add(k, [((i,), tup[i]) for i in range(4)])
                    if seq[::-1] == canon and seq != canon:
                        add(k, [((i,), tup[3 - i]) for i in range(4)])
    # Each path-4 subgraph has exactly two ordered tuples (forward/backward)
    # and exactly one of the two registration branches fires per tuple, so
    # every subgraph registers twice regardless of palindromy => halve.
    out = {}
    for key, dom in domains.items():
        if key[0] == "path4":
            assert counts[key] % 2 == 0
            counts[key] //= 2
        support = min(len(s) for s in dom.values())
        value = support if metric == "mni" else counts[key]
        if value >= min_support:
            out[key] = value
    return out
