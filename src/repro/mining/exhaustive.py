"""GRAMER-style exhaustive-check baseline (§II-A, [64]).

Enumerates *all* connected subgraphs up to the pattern size (oblivious to
the pattern), then performs the isomorphic check at full size — exactly the
method the paper argues is algorithmically inferior (its Fig. 8 shows
pattern enumeration on an unmodified CPU beating GRAMER). We reproduce that
gap in benchmarks/bench_mining.py.

Connected subgraphs are enumerated once each via the standard ESU-style
rule: extend S only with vertices w > min(S) that neighbor S and are not in
S, tracking the extension frontier to avoid duplicates.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph

PATTERN_CHECKS = {
    "triangle": (3, lambda adj, vs: _num_edges(adj, vs) == 3),
    "3-chain": (3, lambda adj, vs: _num_edges(adj, vs) == 2),
    "4-clique": (4, lambda adj, vs: _num_edges(adj, vs) == 6),
    "5-clique": (5, lambda adj, vs: _num_edges(adj, vs) == 10),
    # 4-vertex induced motifs (ESU enumerates connected sets, so 3 edges =>
    # a tree: star iff some vertex touches all others, else path)
    "tailed-triangle": (4, lambda adj, vs: _num_edges(adj, vs) == 4 and _has_triangle(adj, vs)),
    "diamond": (4, lambda adj, vs: _num_edges(adj, vs) == 5),
    "4-cycle": (4, lambda adj, vs: _num_edges(adj, vs) == 4 and not _has_triangle(adj, vs)),
    "4-star": (4, lambda adj, vs: _num_edges(adj, vs) == 3 and _max_deg_in(adj, vs) == 3),
    "4-path": (4, lambda adj, vs: _num_edges(adj, vs) == 3 and _max_deg_in(adj, vs) == 2),
}


def _max_deg_in(adj, vs) -> int:
    return max(sum(1 for v in vs if v != u and v in adj[u]) for u in vs)


def _num_edges(adj, vs) -> int:
    return sum(1 for i, u in enumerate(vs) for v in vs[i + 1:] if v in adj[u])


def _has_triangle(adj, vs) -> bool:
    for i, a in enumerate(vs):
        for j in range(i + 1, len(vs)):
            b = vs[j]
            if b not in adj[a]:
                continue
            for c in vs[j + 1:]:
                if c in adj[a] and c in adj[b]:
                    return True
    return False


def exhaustive_count(g: CSRGraph, pattern: str) -> int:
    """Count embeddings of ``pattern`` by exhaustive subgraph enumeration.

    Counts *connected vertex sets* whose induced subgraph passes the check —
    this matches the vertex-induced semantics GRAMER uses; for cliques and
    (non-induced-agnostic) triangles the result equals pattern enumeration's.
    Exponential: intended for small graphs only (it is the baseline to beat).
    """
    size, check = PATTERN_CHECKS[pattern]
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    adj = [set(indices[indptr[v]: indptr[v + 1]].tolist())
           for v in range(g.num_vertices)]
    count = 0
    for v in range(g.num_vertices):
        ext = [u for u in adj[v] if u > v]
        # ESU (Wernicke): each connected vertex set enumerated exactly once.
        # ``blocked`` = vs ∪ N(vs): new candidates must be *exclusive*
        # neighbors of the newly added vertex.
        stack = [([v], ext, adj[v] | {v})]
        while stack:
            vs, frontier, blocked = stack.pop()
            if len(vs) == size:
                if check(adj, vs):
                    count += 1
                continue
            for i, w in enumerate(frontier):
                new_ext = frontier[i + 1:] + [
                    u for u in adj[w] if u > v and u not in blocked]
                stack.append((vs + [w], new_ext, blocked | adj[w] | {w}))
    return count
