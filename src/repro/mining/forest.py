"""Plan-forest scheduler: fuse a *set* of compiled plans into one
shared-prefix stream program.

Motif workloads (3-motif, 4-motif, FSM) run several patterns over the same
graph. Executed independently, each ``WavePlan`` re-materialises the level-1
edge feed and re-runs every interior expand even when another pattern in the
batch performs identical work — 4-motif's diamond, paw and 4-clique all
start from the N(v0) ∩ N(v1) wing stream. This module merges the batch into
a ``PlanForest``: a prefix trie whose shared interior nodes run ONCE per
wave chunk and fan out to per-pattern suffix branches, with per-leaf
count/emit accumulators (AutoMine's multi-pattern schedule reuse and
TrieJax's shared-prefix join tries, restated on the §IV-F plan IR;
interpreted by ``engine.WaveRunner.run_set``).

Canonical-prefix rules
----------------------

Plans are grouped by feed orientation first (``WavePlan.symmetric``: the
half-edge v1 < v0 feed vs the directed feed) — a forest has at most one
root set per orientation and each feed is materialised and iterated once.
Column names need no renumbering: every compiled plan matches vertices in
schedule order, so prefix column ``j`` means "the vertex matched at level
``j``" in every plan and ``LevelOp`` references are directly comparable.

Two expand ops can share a node iff their **stream keys** agree —
``(level, use_carry, base, inter, sub)``, the fields that define which
survivor *elements* the level materialises. Bound and injectivity fields
(``ub``/``lb``/``exclude``) do NOT need to agree: the shared node is
**relaxed** to the intersection of the branches' constraint sets, and each
branch's surplus is pushed one level down:

* as a **residual** on the branch's next op — a per-item constraint
  (``('lt', i, j)`` ≡ v_i < v_j, ``('ne', i, j)`` ≡ v_i != v_j) that the
  engine folds into the per-row bound operand (bound 0 ⇒ the kernels' tile
  schedule skips the whole row), and
* when the branch's next op **carries** the shared survivor stream, the
  surplus ``ub``/``lb``/``exclude`` are additionally re-added to that op's
  own element constraints, restoring exactly the filter the relaxation
  dropped from the carried elements.

Terminal (count/emit) ops are never relaxed — they ARE the per-pattern
semantics — and merge only when identical, in which case the count runs
once and is credited to every owning plan. Residual sets shared by every
branch of a node are applied at the node; disagreeing residuals defer
further down. Relaxation therefore never changes any leaf's result, only
*where* constraints are enforced — ``run_set`` output is bit-identical to
running each plan independently (property-tested in tests/test_forest.py).

Trie interpretation contract (``WaveRunner.run_set``)
-----------------------------------------------------

* liveness is recomputed across branches: an interior node's ``out_cols`` /
  ``gather_refs`` are the union of its subtree's value/row references (so
  residual columns are forwarded), and ``carry_out`` is the OR over children
  — non-carrying children simply ignore the carry;
* every node is executed through the same cached executables as the
  single-plan path (``LevelOp`` hashes by value, residuals included), so a
  forest node and an identical single-plan level share compiled traces;
* each expand node runs its gather + masks + on-device compaction once per
  wave chunk and feeds the resulting (cols2, caps2, carry2) to every child;
* leaf partials — (hi, lo) int32 count pairs or embedding blocks — are
  appended to per-plan accumulators and finalised per plan (division by
  ``Pattern.div``, emit concatenation) exactly as ``run`` does.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence

from .plan import LevelOp, WavePlan

__all__ = ["ForestNode", "PlanForest", "build_forest"]


@dataclasses.dataclass(frozen=True)
class ForestNode:
    """One trie node: an expand interior (``children``) or a count/emit leaf
    (``plans`` = indices of the source plans credited with its output)."""

    op: LevelOp
    children: tuple["ForestNode", ...] = ()
    plans: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class PlanForest:
    """A merged pattern batch: per-feed root sets over ``plans``."""

    plans: tuple[WavePlan, ...]
    symmetric_roots: tuple[ForestNode, ...]
    directed_roots: tuple[ForestNode, ...]

    def all_roots(self) -> tuple[ForestNode, ...]:
        return self.symmetric_roots + self.directed_roots

    def sharing_stats(self) -> dict:
        """Static fusion report: per-(kind, level) op counts, plans vs trie.

        ``feed_passes`` counts level-1 edge-feed traversals: one per plan
        when run independently, one per used orientation when fused."""
        plan_ops: Counter = Counter()
        for p in self.plans:
            for op in p.ops:
                plan_ops[(op.kind, op.level)] += 1
        forest_ops: Counter = Counter()

        def walk(node: ForestNode) -> None:
            forest_ops[(node.op.kind, node.op.level)] += 1
            for ch in node.children:
                walk(ch)

        for root in self.all_roots():
            walk(root)
        feeds = int(bool(self.symmetric_roots)) + int(bool(self.directed_roots))
        return {
            "plans": len(self.plans),
            "plan_ops": dict(plan_ops),
            "forest_ops": dict(forest_ops),
            "ops_saved": sum(plan_ops.values()) - sum(forest_ops.values()),
            "feed_passes": {"independent": len(self.plans), "fused": feeds},
        }


# ---------------------------------------------------------------------------
# the merge
# ---------------------------------------------------------------------------


def _merge(branches: list[tuple[int, list[LevelOp]]]) -> tuple[ForestNode, ...]:
    """Merge one trie level. ``branches`` = (plan index, remaining ops) with
    any constraints deferred from relaxed ancestors already folded into
    ``ops[0]``. Deterministic: groups keep first-seen plan order."""
    nodes: list[ForestNode] = []
    leaves: dict[LevelOp, list[int]] = {}
    groups: dict[tuple, list[tuple[int, list[LevelOp]]]] = {}
    for idx, ops in branches:
        if ops[0].kind == "expand":
            groups.setdefault(ops[0].stream_key(), []).append((idx, ops))
        else:
            leaves.setdefault(ops[0], []).append(idx)
    for op, idxs in leaves.items():
        nodes.append(ForestNode(op=op, plans=tuple(idxs)))
    for group in groups.values():
        relaxed, sub = _relax(group)
        children = _merge(sub)
        nodes.append(_with_liveness(relaxed, children))
    return tuple(nodes)


def _relax(group: list[tuple[int, list[LevelOp]]]):
    """Relax a stream-key group to its shared constraint intersection; push
    each branch's surplus down as residuals (+ re-added element constraints
    when the branch's next op carries the shared stream)."""
    ops0 = [ops[0] for _, ops in group]
    sh_ub = set.intersection(*[set(o.ub) for o in ops0])
    sh_lb = set.intersection(*[set(o.lb) for o in ops0])
    sh_ex = set.intersection(*[set(o.exclude) for o in ops0])
    sh_res = set.intersection(*[set(o.residual) for o in ops0])
    relaxed = dataclasses.replace(
        ops0[0], ub=tuple(sorted(sh_ub)), lb=tuple(sorted(sh_lb)),
        exclude=tuple(sorted(sh_ex)), residual=tuple(sorted(sh_res)))
    sub: list[tuple[int, list[LevelOp]]] = []
    for idx, ops in group:
        op0, nxt = ops[0], ops[1]
        s_ub = set(op0.ub) - sh_ub
        s_lb = set(op0.lb) - sh_lb
        s_ex = set(op0.exclude) - sh_ex
        res = set(nxt.residual) | (set(op0.residual) - sh_res) \
            | {("lt", op0.level, u) for u in s_ub} \
            | {("lt", w, op0.level) for w in s_lb} \
            | {("ne", op0.level, e) for e in s_ex}
        if nxt.use_carry and (s_ub or s_lb or s_ex):
            # the carried elements lost the surplus filters with the
            # relaxation: restore them on the consuming op
            nxt = dataclasses.replace(
                nxt, ub=tuple(sorted(set(nxt.ub) | s_ub)),
                lb=tuple(sorted(set(nxt.lb) | s_lb)),
                exclude=tuple(sorted(set(nxt.exclude) | s_ex)))
        nxt = dataclasses.replace(nxt, residual=tuple(sorted(res)))
        sub.append((idx, [nxt] + ops[2:]))
    return relaxed, sub


def _subtree_refs(node: ForestNode) -> tuple[set[int], set[int]]:
    """(value refs, row refs) of a subtree — the liveness a parent must
    forward. Emit leaves additionally consume their output columns."""
    vals = set(node.op.val_refs())
    rows = set(node.op.row_refs())
    if node.op.kind == "emit":
        vals |= set(node.op.out_cols)
    for ch in node.children:
        v, r = _subtree_refs(ch)
        vals |= v
        rows |= r
    return vals, rows


def _with_liveness(op: LevelOp, children: tuple[ForestNode, ...]) -> ForestNode:
    """Interior-node liveness = union over the child subtrees (residual
    columns included via ``val_refs``); carry is produced iff any child
    consumes it."""
    vals: set[int] = set()
    rows: set[int] = set()
    for ch in children:
        v, r = _subtree_refs(ch)
        vals |= v
        rows |= r
    return ForestNode(
        op=dataclasses.replace(
            op,
            out_cols=tuple(sorted(c for c in vals if c <= op.level)),
            gather_refs=tuple(sorted(c for c in rows if c <= op.level)),
            carry_out=any(ch.op.use_carry for ch in children)),
        children=children)


def build_forest(plans: Sequence[WavePlan]) -> PlanForest:
    """Merge compiled plans into a ``PlanForest``.

    Plans appear in the result exactly in input order (``run_set`` returns
    per-plan results positionally). The merge is structural — stream-key
    grouping for expands, full-op equality for leaves — so duplicate plans
    (equal ``WavePlan.canonical_key()``) collapse onto fully shared paths,
    down to one shared leaf credited to both."""
    plans = tuple(plans)
    if not plans:
        raise ValueError("build_forest needs at least one plan")
    sym = [(i, list(p.ops)) for i, p in enumerate(plans) if p.symmetric]
    dirc = [(i, list(p.ops)) for i, p in enumerate(plans) if not p.symmetric]
    return PlanForest(plans=plans,
                      symmetric_roots=_merge(sym) if sym else (),
                      directed_roots=_merge(dirc) if dirc else ())
