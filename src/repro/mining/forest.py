"""Plan-forest scheduler: fuse a *set* of compiled plans into one
shared-prefix stream program.

Motif workloads (3-motif, 4-motif, FSM) run several patterns over the same
graph. Executed independently, each ``WavePlan`` re-materialises the level-1
edge feed and re-runs every interior expand even when another pattern in the
batch performs identical work — 4-motif's diamond, paw and 4-clique all
start from the N(v0) ∩ N(v1) wing stream. This module merges the batch into
a ``PlanForest``: a prefix trie whose shared interior nodes run ONCE per
wave chunk and fan out to per-pattern suffix branches, with per-leaf
count/emit accumulators (AutoMine's multi-pattern schedule reuse and
TrieJax's shared-prefix join tries, restated on the §IV-F plan IR;
interpreted by ``engine.WaveRunner.run_set``).

Canonical-prefix rules
----------------------

Plans are grouped by feed orientation first (``WavePlan.symmetric``: the
half-edge v1 < v0 feed vs the directed feed) — a forest has at most one
root set per orientation and each feed is materialised and iterated once.
Column names need no renumbering: every compiled plan matches vertices in
schedule order, so prefix column ``j`` means "the vertex matched at level
``j``" in every plan and ``LevelOp`` references are directly comparable.

Two expand ops can share a node iff their **stream keys** agree —
``(level, use_carry, base, inter, sub)``, the fields that define which
survivor *elements* the level materialises. Bound and injectivity fields
(``ub``/``lb``/``exclude``) do NOT need to agree: the shared node is
**relaxed** to the intersection of the branches' constraint sets, and each
branch's surplus is pushed one level down:

* as a **residual** on the branch's next op — a per-item constraint
  (``('lt', i, j)`` ≡ v_i < v_j, ``('ne', i, j)`` ≡ v_i != v_j) that the
  engine folds into the per-row bound operand (bound 0 ⇒ the kernels' tile
  schedule skips the whole row), and
* when the branch's next op **carries** the shared survivor stream, the
  surplus ``ub``/``lb``/``exclude`` are additionally re-added to that op's
  own element constraints, restoring exactly the filter the relaxation
  dropped from the carried elements.

Terminal (count/emit) ops are never relaxed — they ARE the per-pattern
semantics — and merge only when identical, in which case the count runs
once and is credited to every owning plan. Residual sets shared by every
branch of a node are applied at the node; disagreeing residuals defer
further down. Relaxation therefore never changes any leaf's result, only
*where* constraints are enforced — ``run_set`` output is bit-identical to
running each plan independently (property-tested in tests/test_forest.py).
The same forest interprets unchanged on the mesh-sharded runner
(``mining.shard.ShardedWaveRunner``): the fan-out and residual packs are
per-shard SPMD, count leaves psum across the mesh, and per-plan results
stay bit-identical to both the single-device forest and independent runs.

**Count-rides-expand fusion**: a terminal count leaf (no degree tail)
whose stream key AND full constraint set (ub/lb/exclude/residual) equal a
sibling expand node's relaxed op dispatches no kernel at all — the expand
already computes that exact per-item survivor-count vector, so the leaf's
plans are recorded in the node's ``ride_plans`` and ``run_set`` credits
them with the expand's count partial (a 4-clique leaf rides a 5-clique's
level-3 expand; the 4-clique leaf does NOT ride the 4-motif wing expand,
which is relaxed below its bounds).

Schedule search (``schedule_patterns``)
---------------------------------------

Which *matching order* each pattern uses decides what can share. For
``Motif`` inputs (unordered shapes, no hand-written order or restrictions)
``schedule_patterns`` runs AutoMine's compilation loop: every motif's
candidate orders (``plan.matching_orders``, restrictions derived from the
automorphism group) are searched by coordinate descent to minimise a
static cost — one trie-node dispatch weight per feed edge orientation
(directed feeds iterate twice the half-edge feed's chunks) plus the feed
passes themselves — which maximises shared canonical prefixes across the
batch. Explicit ``Pattern`` inputs are respected as-is (fixed points of
the search). The 4-motif batch lands on 3 shared level-2 nodes over 2
feed passes with no hand-ordered definitions anywhere.

Trie interpretation contract (``WaveRunner.run_set``)
-----------------------------------------------------

* liveness is recomputed across branches: an interior node's ``out_cols`` /
  ``gather_refs`` are the union of its subtree's value/row references (so
  residual columns are forwarded), and ``carry_out`` is the OR over children
  — non-carrying children simply ignore the carry;
* every node is executed through the same cached executables as the
  single-plan path (``LevelOp`` hashes by value, residuals included), so a
  forest node and an identical single-plan level share compiled traces;
* each expand node runs its gather + masks + on-device compaction once per
  wave chunk and feeds the resulting (cols2, caps2, carry2) to every child;
* leaf partials — (hi, lo) int32 count pairs or embedding blocks — are
  appended to per-plan accumulators and finalised per plan (division by
  ``Pattern.div``, emit concatenation) exactly as ``run`` does.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence

from .plan import LevelOp, Motif, Pattern, WavePlan, compile_pattern, \
    matching_orders

__all__ = ["ForestNode", "PlanForest", "build_forest", "schedule_patterns"]


@dataclasses.dataclass(frozen=True)
class ForestNode:
    """One trie node: an expand interior (``children``) or a count/emit leaf
    (``plans`` = indices of the source plans credited with its output).

    ``ride_plans`` (interior expands only) are plans whose terminal count
    leaf matched this node's stream AND constraints exactly: they dispatch
    no kernel — the engine credits them with this expand's survivor-count
    sum (count-rides-expand fusion)."""

    op: LevelOp
    children: tuple["ForestNode", ...] = ()
    plans: tuple[int, ...] = ()
    ride_plans: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class PlanForest:
    """A merged pattern batch: per-feed root sets over ``plans``."""

    plans: tuple[WavePlan, ...]
    symmetric_roots: tuple[ForestNode, ...]
    directed_roots: tuple[ForestNode, ...]

    def all_roots(self) -> tuple[ForestNode, ...]:
        return self.symmetric_roots + self.directed_roots

    def sharing_stats(self) -> dict:
        """Static fusion report: per-(kind, level) op counts, plans vs trie.

        ``feed_passes`` counts level-1 edge-feed traversals: one per plan
        when run independently, one per used orientation when fused.
        ``count_rides`` counts terminal count leaves folded into a sibling
        expand (they appear in ``plan_ops`` but dispatch nothing)."""
        plan_ops: Counter = Counter()
        for p in self.plans:
            for op in p.ops:
                plan_ops[(op.kind, op.level)] += 1
        forest_ops: Counter = Counter()
        rides = 0

        def walk(node: ForestNode) -> None:
            nonlocal rides
            forest_ops[(node.op.kind, node.op.level)] += 1
            rides += len(node.ride_plans)
            for ch in node.children:
                walk(ch)

        for root in self.all_roots():
            walk(root)
        feeds = int(bool(self.symmetric_roots)) + int(bool(self.directed_roots))
        return {
            "plans": len(self.plans),
            "plan_ops": dict(plan_ops),
            "forest_ops": dict(forest_ops),
            "count_rides": rides,
            "ops_saved": sum(plan_ops.values()) - sum(forest_ops.values()),
            "feed_passes": {"independent": len(self.plans), "fused": feeds},
        }


# ---------------------------------------------------------------------------
# the merge
# ---------------------------------------------------------------------------


def _merge(branches: list[tuple[int, list[LevelOp]]]) -> tuple[ForestNode, ...]:
    """Merge one trie level. ``branches`` = (plan index, remaining ops) with
    any constraints deferred from relaxed ancestors already folded into
    ``ops[0]``. Deterministic: groups keep first-seen plan order."""
    leaves: dict[LevelOp, list[int]] = {}
    groups: dict[tuple, list[tuple[int, list[LevelOp]]]] = {}
    for idx, ops in branches:
        if ops[0].kind == "expand":
            groups.setdefault(ops[0].stream_key(), []).append((idx, ops))
        else:
            leaves.setdefault(ops[0], []).append(idx)
    merged: dict[tuple, list] = {}       # stream key -> [relaxed, kids, rides]
    for key, group in groups.items():
        relaxed, sub = _relax(group)
        merged[key] = [relaxed, _merge(sub), []]
    nodes: list[ForestNode] = []
    for op, idxs in leaves.items():
        # count-rides-expand: a tail-free count leaf matching a sibling
        # expand's stream AND relaxed constraints reads that expand's
        # survivor-count vector instead of dispatching its own kernel.
        # Aggregate leaves never ride: an expand yields counts, not values.
        tgt = merged.get(op.stream_key()) \
            if op.kind == "count" and op.tail is None and op.agg is None \
            else None
        if tgt is not None and (op.ub, op.lb, op.exclude, op.residual) == \
                (tgt[0].ub, tgt[0].lb, tgt[0].exclude, tgt[0].residual):
            tgt[2].extend(idxs)
        else:
            nodes.append(ForestNode(op=op, plans=tuple(idxs)))
    for relaxed, children, rides in merged.values():
        nodes.append(_with_liveness(relaxed, children, tuple(rides)))
    return tuple(nodes)


def _relax(group: list[tuple[int, list[LevelOp]]]):
    """Relax a stream-key group to its shared constraint intersection; push
    each branch's surplus down as residuals (+ re-added element constraints
    when the branch's next op carries the shared stream)."""
    ops0 = [ops[0] for _, ops in group]
    sh_ub = set.intersection(*[set(o.ub) for o in ops0])
    sh_lb = set.intersection(*[set(o.lb) for o in ops0])
    sh_ex = set.intersection(*[set(o.exclude) for o in ops0])
    sh_res = set.intersection(*[set(o.residual) for o in ops0])
    relaxed = dataclasses.replace(
        ops0[0], ub=tuple(sorted(sh_ub)), lb=tuple(sorted(sh_lb)),
        exclude=tuple(sorted(sh_ex)), residual=tuple(sorted(sh_res)))
    sub: list[tuple[int, list[LevelOp]]] = []
    for idx, ops in group:
        op0, nxt = ops[0], ops[1]
        s_ub = set(op0.ub) - sh_ub
        s_lb = set(op0.lb) - sh_lb
        s_ex = set(op0.exclude) - sh_ex
        res = set(nxt.residual) | (set(op0.residual) - sh_res) \
            | {("lt", op0.level, u) for u in s_ub} \
            | {("lt", w, op0.level) for w in s_lb} \
            | {("ne", op0.level, e) for e in s_ex}
        if nxt.use_carry and (s_ub or s_lb or s_ex):
            # the carried elements lost the surplus filters with the
            # relaxation: restore them on the consuming op
            nxt = dataclasses.replace(
                nxt, ub=tuple(sorted(set(nxt.ub) | s_ub)),
                lb=tuple(sorted(set(nxt.lb) | s_lb)),
                exclude=tuple(sorted(set(nxt.exclude) | s_ex)))
        nxt = dataclasses.replace(nxt, residual=tuple(sorted(res)))
        sub.append((idx, [nxt] + ops[2:]))
    return relaxed, sub


def _subtree_refs(node: ForestNode) -> tuple[set[int], set[int]]:
    """(value refs, row refs) of a subtree — the liveness a parent must
    forward. Emit leaves additionally consume their output columns."""
    vals = set(node.op.val_refs())
    rows = set(node.op.row_refs())
    if node.op.kind == "emit":
        vals |= set(node.op.out_cols)
    for ch in node.children:
        v, r = _subtree_refs(ch)
        vals |= v
        rows |= r
    return vals, rows


def _with_liveness(op: LevelOp, children: tuple[ForestNode, ...],
                   ride_plans: tuple[int, ...] = ()) -> ForestNode:
    """Interior-node liveness = union over the child subtrees (residual
    columns included via ``val_refs``); carry is produced iff any child
    consumes it. Riding count leaves add no liveness: their constraint set
    equals the node's, so every column they read is already consumed."""
    vals: set[int] = set()
    rows: set[int] = set()
    for ch in children:
        v, r = _subtree_refs(ch)
        vals |= v
        rows |= r
    return ForestNode(
        op=dataclasses.replace(
            op,
            out_cols=tuple(sorted(c for c in vals if c <= op.level)),
            gather_refs=tuple(sorted(c for c in rows if c <= op.level)),
            carry_out=any(ch.op.use_carry for ch in children)),
        children=children, ride_plans=ride_plans)


# ---------------------------------------------------------------------------
# automatic matching-order search (the schedule stage)
# ---------------------------------------------------------------------------


def _schedule_score(forest: PlanForest) -> tuple:
    """Static cost of a candidate schedule, lower is better.

    Every trie node dispatches once per level-1 feed chunk of its
    orientation, and the directed feed iterates all E edges where the
    half-edge feed iterates E/2 — so nodes under directed roots weigh 2,
    nodes under symmetric roots weigh 1, and each used orientation adds its
    own feed-materialisation weight. Total forest ops and feed-pass count
    break ties; all components are schedule facts (machine-independent)."""
    weighted = 0

    def walk(node: ForestNode, w: int) -> None:
        nonlocal weighted
        weighted += w
        for ch in node.children:
            walk(ch, w)

    for root in forest.symmetric_roots:
        walk(root, 1)
    for root in forest.directed_roots:
        walk(root, 2)
    feeds = int(bool(forest.symmetric_roots)) \
        + 2 * int(bool(forest.directed_roots))
    stats = forest.sharing_stats()
    return (weighted + feeds, sum(stats["forest_ops"].values()),
            stats["feed_passes"]["fused"])


_SCHEDULE_CACHE: dict[tuple, tuple[Pattern, ...]] = {}


def schedule_patterns(items: Sequence, context: Sequence[WavePlan] = ()) \
        -> list[Pattern]:
    """Pick a matching order per pattern to maximise batch sharing.

    ``items`` mixes ``Motif``s (unordered shapes — every candidate order
    from ``plan.matching_orders`` is in play) and ``Pattern``s (explicit
    schedules, respected as-is). ``context`` plans join the scoring forest
    without being rescheduled (a session batch alongside fixed queries).
    Coordinate descent over the candidate lists minimises
    ``_schedule_score`` until a fixpoint — AutoMine's compilation loop on
    the plan IR. Deterministic (pure host combinatorics, first-improvement
    in stable order) and memoised; returns one ``Pattern`` per item, in
    input order."""
    items = tuple(items)
    key = (items, tuple(p.canonical_key() for p in context))
    hit = _SCHEDULE_CACHE.get(key)
    if hit is not None:
        return list(hit)
    cands: list[tuple[Pattern, ...]] = []
    for it in items:
        if isinstance(it, Pattern):
            cands.append((it,))
        elif isinstance(it, Motif):
            cands.append(matching_orders(it))
        else:
            raise TypeError(f"schedule_patterns wants Pattern|Motif, got "
                            f"{type(it).__name__}")
    fixed = list(context)
    choice = [0] * len(cands)

    def score(ch: list[int]) -> tuple:
        plans = [compile_pattern(c[i]) for c, i in zip(cands, ch)] + fixed
        return _schedule_score(build_forest(plans))

    best = score(choice)
    improved = True
    while improved:
        improved = False
        for pi, cand in enumerate(cands):
            if len(cand) < 2:
                continue
            for ci in range(len(cand)):
                if ci == choice[pi]:
                    continue
                trial = list(choice)
                trial[pi] = ci
                sc = score(trial)
                if sc < best:
                    best, choice = sc, trial
                    improved = True
    picked = tuple(c[i] for c, i in zip(cands, choice))
    _SCHEDULE_CACHE[key] = picked
    return list(picked)


def build_forest(plans: Sequence[WavePlan]) -> PlanForest:
    """Merge compiled plans into a ``PlanForest``.

    Plans appear in the result exactly in input order (``run_set`` returns
    per-plan results positionally). The merge is structural — stream-key
    grouping for expands, full-op equality for leaves — so duplicate plans
    (equal ``WavePlan.canonical_key()``) collapse onto fully shared paths,
    down to one shared leaf credited to both."""
    plans = tuple(plans)
    if not plans:
        raise ValueError("build_forest needs at least one plan")
    sym = [(i, list(p.ops)) for i, p in enumerate(plans) if p.symmetric]
    dirc = [(i, list(p.ops)) for i, p in enumerate(plans) if not p.symmetric]
    return PlanForest(plans=plans,
                      symmetric_roots=_merge(sym) if sym else (),
                      directed_roots=_merge(dirc) if dirc else ())
