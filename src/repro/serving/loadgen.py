"""Load generator: client threads + the tick loop, with latency stats.

Drives a ``MiningService`` the way real traffic would: N client threads
submit requests (round-robin over a fixed list of query mixes, optionally
paced to a target qps) and park on ``result()``, while the generator's
main thread runs the service's tick loop until every request completed.
Because clients submit concurrently and ticks drain whole queues, the
service merges heterogeneous in-flight requests into shared forest
schedules — the cross-request-sharing behaviour the benchmark gates.

Latency is the request's own ``latency_s`` (submit -> completion, queue
wait included); the report carries p50/p99, achieved qps, and the
service's sharing/admission counters. Wall-clock numbers are
machine-dependent — ``benchmarks/ci_gate.py --serving`` gates them only
as RATIOS against a sequential single-session baseline.
"""
from __future__ import annotations

import threading
import time

from .service import MiningService

__all__ = ["LoadGenerator", "percentile"]


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]) of a non-empty sequence."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    idx = min(len(xs) - 1, max(0, round(p / 100.0 * (len(xs) - 1))))
    return float(xs[idx])


class LoadGenerator:
    """Threaded client traffic against one service.

    ``mixes`` is a list of request shapes, each either a query batch or a
    ``(queries, traffic_class)`` pair; request ``i`` (global order) uses
    ``mixes[i % len(mixes)]``. ``qps=None`` submits as fast as the
    clients can (burst — queues deepen, ticks merge maximally);
    a float paces each client to ``qps / clients`` submissions/s."""

    def __init__(self, service: MiningService, mixes, requests: int = 64,
                 clients: int = 4, qps: float | None = None,
                 timeout_s: float | None = None):
        if requests < 1 or clients < 1:
            raise ValueError("need requests >= 1 and clients >= 1")
        self.service = service
        self.mixes = [m if isinstance(m, tuple) and len(m) == 2
                      and isinstance(m[1], str) else (m, "default")
                      for m in mixes]
        self.requests = int(requests)
        self.clients = min(int(clients), self.requests)
        self.qps = qps
        self.timeout_s = timeout_s

    def _client(self, cid: int, out: list) -> None:
        interval = (self.clients / self.qps) if self.qps else 0.0
        nxt = time.monotonic()
        for i in range(cid, self.requests, self.clients):
            if interval:
                nxt += interval
                delay = nxt - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
            queries, tc = self.mixes[i % len(self.mixes)]
            out.append(self.service.submit(queries, traffic_class=tc,
                                           timeout_s=self.timeout_s))

    def run(self) -> dict:
        """Generate the load; tick until every request completed."""
        per_client: list[list] = [[] for _ in range(self.clients)]
        threads = [threading.Thread(target=self._client, args=(c, per_client[c]),
                                    daemon=True)
                   for c in range(self.clients)]
        t0 = time.monotonic()
        for t in threads:
            t.start()
        while any(t.is_alive() for t in threads) or self.service.pending:
            if not self.service.tick()["requests"]:
                time.sleep(0.001)          # idle tick: let clients enqueue
        for t in threads:
            t.join()
        self.service.run_until_idle()
        wall = time.monotonic() - t0
        reqs = [r for sub in per_client for r in sub]
        lat = [r.latency_s for r in reqs if r.state == "done"]
        st = self.service.stats
        return {
            "requests": len(reqs),
            "completed": len(lat),
            "rejected": sum(r.state == "rejected" for r in reqs),
            "timeouts": sum(r.state == "timeout" for r in reqs),
            "failed": sum(r.state == "failed" for r in reqs),
            "wall_s": round(wall, 4),
            "qps": round(len(lat) / max(wall, 1e-9), 2),
            "p50_s": round(percentile(lat, 50), 5) if lat else None,
            "p99_s": round(percentile(lat, 99), 5) if lat else None,
            "feed_passes": {
                "independent": st["service_feed_passes_independent"],
                "fused": st["service_feed_passes_fused"]},
            "retraces": st["retraces"],
        }
