"""``MiningService``: the tick loop — admission, batching, execution.

One service owns a graph, a ``WorkerPool`` of resident ``Miner`` sessions
(one per traffic class), a graph-version-keyed ``ResultCache`` and an
in-flight request queue. ``submit()`` is thread-safe and non-blocking;
``tick()`` — the single-consumer scheduling round — drains the queue,
merges every drained request's queries into ONE ``PlanForest`` schedule
per traffic class (cross-request sharing), executes it, and routes the
per-query results back to each request. See the package docstring
(``repro.serving``) for the full contract.

Cross-request sharing accounting (the gate metric): per executed batch,

* ``service_feed_passes_independent`` — the sum over the batch's requests
  of the feed passes each request's *own* fused schedule would cost if
  executed alone (``worker.schedule(request.queries)`` — already each
  request's best case);
* ``service_feed_passes_fused`` — the merged batch forest's actual feed
  passes.

fused < independent whenever a tick merged two or more requests — the
"cross-REQUEST sharing, not just cross-pattern" fact ``ci_gate.py
--serving`` gates exactly.

Value traffic (SVPU, §IV-E): ``submit(..., aggregate="sum"|"max"|"min")``
routes the request onto the ``values`` traffic class (unless the caller
pins one explicitly) and executes via ``Miner.aggregate_many``. Aggregate
requests batch exactly like count requests — one merged forest per
(traffic class, op) group — and their results live in the same
graph-version-keyed cache under op-tagged keys, so a weighted SUM and an
unweighted count over the same pattern never collide.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from contextlib import nullcontext
from typing import Sequence

from repro.graph.csr import CSRGraph
from repro.mining.plan import AGG_OPS, Motif, Pattern, resolve_query
from repro.obs import Telemetry
from .cache import ResultCache
from .pool import DEFAULT_CLASS, WorkerPool, WorkerSpec
from .request import ServiceRequest

__all__ = ["MiningService", "ServiceConfig", "VALUES_CLASS"]

# Traffic class aggregate submissions default onto. The pool falls back
# to its first spec for classes without a dedicated worker, so services
# configured before the value plane existed serve it unchanged.
VALUES_CLASS = "values"


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Every service knob in one frozen config (``MiningService(g,
    **kwargs)`` is sugar that builds/extends one, mirroring ``Miner``).

    ``telemetry`` is the SERVICE's observability (tick spans, queue
    gauges, latency histograms); each worker session keeps its own
    (``WorkerSpec.config.telemetry``) so session registries never alias.
    """

    max_in_flight: int = 64           # admission bound on queued requests
    timeout_s: float | None = None    # default per-request deadline
    cache_results: bool = True        # graph-version-keyed result cache
    cache_entries: int = 1024         # result-cache LRU cap
    workers: tuple[WorkerSpec, ...] = (WorkerSpec(),)
    telemetry: Telemetry | None = dataclasses.field(
        default=None, compare=False, repr=False)


class MiningService:
    """Concurrent mining service over a pool of resident sessions.

    Thread contract: ``submit`` may be called from any thread; ``tick``
    (and ``set_graph``) must run on ONE service thread — the tick loop is
    the single consumer, exactly as each ``Miner`` is single-threaded
    with concurrency layered above it.
    """

    def __init__(self, graph: CSRGraph, config: ServiceConfig | None = None,
                 telemetry: Telemetry | None = None, **overrides):
        if telemetry is not None:
            overrides["telemetry"] = telemetry
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self.telemetry = (config.telemetry if config.telemetry is not None
                          else Telemetry())
        reg = self.telemetry.metrics
        self._submitted = reg.counter("service_requests")
        self._completed = reg.counter("service_completed")
        self._rejected = reg.counter("service_rejected")
        self._timeouts = reg.counter("service_timeouts")
        self._failed = reg.counter("service_failed")
        self._ticks = reg.counter("service_ticks")
        self._queries = reg.counter("service_queries")
        self._feed_indep = reg.counter("service_feed_passes_independent")
        self._feed_fused = reg.counter("service_feed_passes_fused")
        self._depth = reg.gauge("service_queue_depth")
        self._version_g = reg.gauge("service_graph_version")
        self._batch_h = reg.histogram("service_batch_requests")
        self.version = 0
        self._lock = threading.Lock()
        self._queue: deque[ServiceRequest] = deque()
        self._ids = itertools.count()
        self.pool = WorkerPool(graph, config.workers)
        self.cache = (ResultCache(config.cache_entries, reg)
                      if config.cache_results else None)

    # ------------------------------------------------------------- submit
    def submit(self, queries, traffic_class: str | None = None,
               timeout_s: float | None = None,
               aggregate: str | None = None) -> ServiceRequest:
        """Enqueue one request (any thread, non-blocking).

        ``queries`` is one query (name / ``Pattern`` / ``Motif``) or a
        sequence; resolution happens here so the queue, the cache and the
        batcher all speak hashable resolved queries. ``aggregate`` turns
        the request into a weighted-value query (``Miner.aggregate_many``
        semantics) and defaults its traffic class to ``values``.
        Admission control: with ``max_in_flight`` requests already queued
        the request is REJECTED immediately (completed handle,
        ``result()`` raises) — the clean back-pressure path, never an
        unbounded queue."""
        if aggregate is not None and aggregate not in AGG_OPS:
            raise ValueError(
                f"aggregate must be one of {AGG_OPS}, got {aggregate!r}")
        if traffic_class is None:
            traffic_class = (VALUES_CLASS if aggregate is not None
                             else DEFAULT_CLASS)
        if isinstance(queries, (str, Pattern, Motif)):
            queries = (queries,)
        resolved = tuple(resolve_query(q) for q in queries)
        if timeout_s is None:
            timeout_s = self.config.timeout_s
        req = ServiceRequest(next(self._ids), resolved, traffic_class,
                             timeout_s, aggregate=aggregate)
        self._submitted.inc()
        self._queries.inc(len(resolved))
        with self._lock:
            if len(self._queue) >= self.config.max_in_flight:
                self._rejected.inc()
                req._finish("rejected", error=RuntimeError(
                    f"{len(self._queue)} requests in flight "
                    f"(max_in_flight={self.config.max_in_flight})"))
                return req
            self._queue.append(req)
            self._depth.set(len(self._queue))
        return req

    # --------------------------------------------------------------- tick
    def tick(self) -> dict:
        """One scheduling round (service thread only).

        Drain the queue; expire requests past their deadline; serve
        fully-cached requests; merge the remainder per traffic class into
        one forest schedule each and execute; route results; complete
        every drained request. Returns the tick summary."""
        with self._lock:
            batch = list(self._queue)
            self._queue.clear()
            self._depth.set(0)
        self._ticks.inc()
        tr = self.telemetry.tracer
        summary = {"requests": len(batch), "executed": 0, "cached": 0,
                   "timeouts": 0, "failed": 0,
                   "feed_passes": {"independent": 0, "fused": 0}}
        if not batch:
            return summary
        self._batch_h.observe(len(batch))
        with (tr.span("tick", cat="serve", requests=len(batch))
              if tr.enabled else nullcontext()):
            now = time.monotonic()
            groups: dict[tuple, list] = {}
            for req in batch:
                if req.expired(now):
                    self._timeouts.inc()
                    summary["timeouts"] += 1
                    req._finish("timeout")
                    continue
                # per-query cache probe: partial hits shrink the batch,
                # full hits skip execution entirely
                found = {}
                if self.cache is not None:
                    for q in req.queries:
                        hit, v = self.cache.get(
                            self.version, self._cache_key(req.aggregate, q))
                        if hit:
                            found[q] = v
                missing = [q for q in req.queries if q not in found]
                if not missing:
                    self._complete(req, found, from_cache=True)
                    summary["cached"] += 1
                    continue
                # counts and aggregates never share a forest: the group
                # key carries the op so each merged schedule is homogeneous
                groups.setdefault((req.traffic_class, req.aggregate),
                                  []).append((req, found, missing))
            for (tc, agg), group in groups.items():
                self._execute_group(tc, agg, group, summary)
        return summary

    @staticmethod
    def _cache_key(aggregate: str | None, q):
        """Result-cache key: op-tagged for aggregates so a weighted SUM
        and a count of the same pattern occupy distinct entries."""
        return q if aggregate is None else (aggregate, q)

    def _execute_group(self, tc: str, agg: str | None, group: list,
                       summary: dict) -> None:
        """Merge one (traffic class, op) group into one forest and run it."""
        tr = self.telemetry.tracer
        worker = self.pool.worker(tc)
        union = list(dict.fromkeys(
            q for _req, _found, missing in group for q in missing))
        # sharing accounting: each request alone vs the merged batch —
        # schedule() is forest-cached, so repeated mixes re-derive nothing
        indep = sum(
            worker.schedule(missing, aggregate=agg)
            .sharing_stats()["feed_passes"]["fused"]
            for _req, _found, missing in group)
        fused = (worker.schedule(union, aggregate=agg)
                 .sharing_stats()["feed_passes"]["fused"])
        self._feed_indep.inc(indep)
        self._feed_fused.inc(fused)
        summary["feed_passes"]["independent"] += indep
        summary["feed_passes"]["fused"] += fused
        try:
            with (tr.span(f"execute:{tc}", cat="serve",
                          requests=len(group), queries=len(union))
                  if tr.enabled else nullcontext()):
                counts = (worker.count_many(union) if agg is None
                          else worker.aggregate_many(union, op=agg))
        except Exception as e:           # noqa: BLE001 — routed per request
            for req, _found, _missing in group:
                self._failed.inc()
                summary["failed"] += 1
                req._finish("failed", error=e)
            return
        by_query = dict(zip(union, counts))
        if self.cache is not None:
            for q, v in by_query.items():
                self.cache.put(self.version, self._cache_key(agg, q), v)
        for req, found, _missing in group:
            self._complete(req, {**found, **by_query})
            summary["executed"] += 1

    def _complete(self, req: ServiceRequest, by_query: dict,
                  from_cache: bool = False) -> None:
        self._completed.inc()
        self.telemetry.metrics.histogram(
            "service_latency_seconds", cls=req.traffic_class).observe(
            time.monotonic() - req.submitted_at)
        req._finish("done", [by_query[q] for q in req.queries],
                    from_cache=from_cache)

    # -------------------------------------------------------- conveniences
    def query(self, queries, traffic_class: str | None = None,
              timeout_s: float | None = None,
              aggregate: str | None = None):
        """Synchronous submit + tick + result (single-threaded callers —
        e.g. ``launch/serve.py --mine`` round mode). Returns the result
        list for a sequence, the bare value for a single query."""
        single = isinstance(queries, (str, Pattern, Motif))
        req = self.submit(queries, traffic_class, timeout_s,
                          aggregate=aggregate)
        if not req.done:
            self.tick()
        res = req.result(0)
        return res[0] if single else res

    def run_until_idle(self, max_ticks: int = 10_000) -> int:
        """Tick until the queue is empty; returns ticks spent."""
        n = 0
        while self.pending and n < max_ticks:
            self.tick()
            n += 1
        return n

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # ---------------------------------------------------------- lifecycle
    def set_graph(self, graph: CSRGraph) -> None:
        """Swap the served graph (service thread only): bumps the result
        cache's version (old-version entries invalidated) and rebuilds
        every worker session against the new graph."""
        self.version += 1
        self._version_g.set(self.version)
        self.pool.set_graph(graph)
        if self.cache is not None:
            self.cache.invalidate(self.version)

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        reg = self.telemetry.metrics
        out = {k: reg.value(k) for k in (
            "service_requests", "service_completed", "service_rejected",
            "service_timeouts", "service_failed", "service_ticks",
            "service_queries", "service_feed_passes_independent",
            "service_feed_passes_fused")}
        out["version"] = self.version
        out["pending"] = self.pending
        out["workers"] = self.pool.stats()
        out["retraces"] = self.pool.retraces()
        if self.cache is not None:
            out["cache"] = self.cache.snapshot()
        return out

    def prometheus_text(self, prefix: str = "mining_") -> str:
        return self.telemetry.prometheus_text(prefix=prefix)

    def write_trace(self, path):
        return self.telemetry.write_trace(path)
