"""Request objects + typed completion errors for the mining service.

A ``ServiceRequest`` is the handle ``MiningService.submit`` returns: the
submitting thread parks on ``result()`` (a ``threading.Event`` under the
hood) while the service's tick loop batches, executes and completes the
request. Completion is terminal and single-shot — exactly one of

* ``done``     — ``results`` holds one value per submitted query;
* ``rejected`` — admission control refused the request at submit time
  (queue full); ``result()`` raises ``RequestRejected``;
* ``timeout``  — the request's deadline passed before a tick executed it;
  ``result()`` raises ``RequestTimeout``;
* ``failed``   — execution raised; ``result()`` re-raises the cause
  wrapped in ``RequestFailed``.

Queries are resolved (``plan.resolve_query``) at submit time, so a
request always carries hashable ``Pattern``/``Motif`` objects — the same
keys the result cache and the session's plan cache use.
"""
from __future__ import annotations

import threading
import time

__all__ = ["RequestFailed", "RequestRejected", "RequestTimeout",
           "ServiceRequest"]


class RequestRejected(RuntimeError):
    """Admission control refused the request (max_in_flight reached)."""


class RequestTimeout(TimeoutError):
    """The request's deadline passed before the service executed it."""


class RequestFailed(RuntimeError):
    """Execution of the request's batch raised (cause chained)."""


class ServiceRequest:
    """One in-flight query batch. Built by ``MiningService.submit`` only.

    Thread contract: the service thread is the single writer (``_finish``);
    any number of client threads may block in ``result()``/``wait()``.
    """

    __slots__ = ("id", "queries", "traffic_class", "aggregate",
                 "submitted_at", "deadline", "state", "results", "error",
                 "latency_s", "from_cache", "_done")

    def __init__(self, rid: int, queries: tuple, traffic_class: str,
                 timeout_s: float | None = None,
                 aggregate: str | None = None):
        self.id = rid
        self.queries = queries                  # resolved, hashable
        self.traffic_class = traffic_class
        self.aggregate = aggregate              # None => count query
        self.submitted_at = time.monotonic()
        self.deadline = (None if timeout_s is None
                         else self.submitted_at + float(timeout_s))
        self.state = "pending"
        self.results: list | None = None        # one entry per query
        self.error: BaseException | None = None
        self.latency_s: float | None = None     # submit -> completion
        self.from_cache = False                 # every query cache-served
        self._done = threading.Event()

    # ------------------------------------------------------------ service
    def _finish(self, state: str, results: list | None = None,
                error: BaseException | None = None,
                from_cache: bool = False) -> None:
        """Terminal transition (service thread). Idempotence guard: a
        request completes exactly once."""
        if self.state != "pending":
            raise RuntimeError(f"request {self.id} already {self.state}")
        self.state = state
        self.results = results
        self.error = error
        self.from_cache = from_cache
        self.latency_s = time.monotonic() - self.submitted_at
        self._done.set()

    def expired(self, now: float | None = None) -> bool:
        return (self.deadline is not None
                and (now if now is not None else time.monotonic())
                >= self.deadline)

    # ------------------------------------------------------------- client
    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until completed (any terminal state). True if completed."""
        return self._done.wait(timeout)

    def result(self, timeout: float | None = None) -> list:
        """Block for the per-query result list; raise the typed error for
        a rejected / timed-out / failed request."""
        if not self._done.wait(timeout):
            raise RequestTimeout(
                f"request {self.id} still pending after {timeout}s wait "
                "(is the service's tick loop running?)")
        if self.state == "done":
            return self.results
        if self.state == "rejected":
            raise RequestRejected(
                f"request {self.id} rejected: {self.error}")
        if self.state == "timeout":
            raise RequestTimeout(
                f"request {self.id} timed out before execution "
                f"(deadline {self.deadline - self.submitted_at:.3f}s "
                "after submit)")
        raise RequestFailed(
            f"request {self.id} failed: {self.error!r}") from self.error

    def __repr__(self) -> str:
        return (f"ServiceRequest(id={self.id}, state={self.state!r}, "
                f"queries={len(self.queries)}, "
                f"class={self.traffic_class!r})")
