"""Graph-version-keyed result cache for the mining service.

Entries are keyed ``(graph_version, resolved_query)`` — per *query*, not
per request, so heterogeneous requests share hits (request {T, 4C} warms
request {4C} even though their batches differ). Resolved queries are
frozen ``Pattern``/``Motif`` dataclasses, hashable and stable across
submissions, which is exactly why ``MiningService.submit`` resolves them
up front.

A graph swap bumps the service's version; ``invalidate()`` then drops
every entry from older versions (counts are facts about one graph, never
transferable). Bounded LRU: the cap evicts oldest-touched entries so a
long-running service with a churning query population cannot grow without
bound.

Counters land in the service's ``MetricsRegistry`` (``repro.obs``):
``service_cache_hits`` / ``service_cache_misses`` /
``service_cache_invalidations`` — the gate facts ``ci_gate.py --serving``
checks exactly.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.obs import MetricsRegistry

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU of per-query results, keyed by graph version."""

    def __init__(self, entries: int = 1024,
                 metrics: MetricsRegistry | None = None):
        if entries < 1:
            raise ValueError("ResultCache needs entries >= 1")
        self.cap = int(entries)
        self._entries: OrderedDict[tuple, object] = OrderedDict()
        reg = metrics if metrics is not None else MetricsRegistry()
        self.hits = reg.counter("service_cache_hits")
        self.misses = reg.counter("service_cache_misses")
        self.invalidations = reg.counter("service_cache_invalidations")

    def get(self, version: int, query) -> tuple[bool, object]:
        """(hit?, value) — counts the lookup either way."""
        key = (version, query)
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits.inc()
            return True, self._entries[key]
        self.misses.inc()
        return False, None

    def put(self, version: int, query, value) -> None:
        key = (version, query)
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.cap:
            self._entries.popitem(last=False)

    def invalidate(self, current_version: int) -> int:
        """Drop every entry from a version older than ``current_version``;
        returns (and counts) how many were dropped."""
        stale = [k for k in self._entries if k[0] < current_version]
        for k in stale:
            del self._entries[k]
        if stale:
            self.invalidations.inc(len(stale))
        return len(stale)

    def __len__(self) -> int:
        return len(self._entries)

    def snapshot(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits.value,
                "misses": self.misses.value,
                "invalidations": self.invalidations.value}
