"""Worker pool: one ``Miner`` session per traffic class.

A ``WorkerSpec`` binds a traffic-class name to a full ``MinerConfig`` —
the service can therefore mix an unsharded ``Miner(g)`` for latency
traffic with a mesh-sharded ``Miner(g, mesh=S)`` for heavy batches
(``WorkerSpec("bulk", MinerConfig(mesh=8))``); their executable caches
are topology-keyed (see the ``mining.session`` cache-key doc) and never
collide.

Each worker keeps its OWN ``Telemetry`` (built by ``Miner`` from its
config): the per-session registries back each session's legacy ``stats``
view, and sharing one registry across sessions would alias their
counters. The service aggregates across workers through ``retraces()`` /
``stats()`` instead.

``set_graph`` rebuilds every session against the new graph — sessions
are graph-resident by design, so a swap pays the staging + warm-up cost
again (the service bumps its cache version at the same time).
"""
from __future__ import annotations

import dataclasses

from repro.graph.csr import CSRGraph
from repro.mining.session import Miner, MinerConfig

__all__ = ["WorkerPool", "WorkerSpec"]

DEFAULT_CLASS = "default"


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One traffic class -> one session configuration."""

    traffic_class: str = DEFAULT_CLASS
    config: MinerConfig = dataclasses.field(default_factory=MinerConfig)


class WorkerPool:
    """Traffic-class-keyed ``Miner`` sessions over one shared graph."""

    def __init__(self, graph: CSRGraph, specs=(WorkerSpec(),)):
        specs = tuple(specs)
        if not specs:
            raise ValueError("WorkerPool needs at least one WorkerSpec")
        seen = [s.traffic_class for s in specs]
        if len(set(seen)) != len(seen):
            raise ValueError(f"duplicate traffic classes: {seen}")
        self.specs = specs
        # unknown classes fall back to the first spec's session
        self._fallback = specs[0].traffic_class
        self._workers: dict[str, Miner] = {}
        self._build(graph)

    def _build(self, graph: CSRGraph) -> None:
        self.graph = graph
        self._workers = {s.traffic_class: Miner(graph, s.config)
                         for s in self.specs}

    # ------------------------------------------------------------- access
    @property
    def classes(self) -> tuple[str, ...]:
        return tuple(self._workers)

    def worker(self, traffic_class: str = DEFAULT_CLASS) -> Miner:
        w = self._workers.get(traffic_class)
        return w if w is not None else self._workers[self._fallback]

    def set_graph(self, graph: CSRGraph) -> None:
        """Swap every session onto a new graph (staging + warm-up redo)."""
        self._build(graph)

    # -------------------------------------------------------------- stats
    def retraces(self) -> int:
        """Executables built across the pool — the steady-state-0 gate."""
        return sum(w.exec_cache.misses for w in self._workers.values())

    def stats(self) -> dict:
        return {tc: {"queries": w.stats["queries"],
                     "retraces": w.exec_cache.misses,
                     "exec_entries": len(w.exec_cache),
                     "mesh": None if w.mesh is None
                     else dict(w.mesh.shape)}
                for tc, w in self._workers.items()}
