"""Concurrent mining service: cross-request forest batching over resident
``Miner`` sessions.

The session layer (``repro.mining.session``) made one graph + one query
stream cheap; this package serves MANY concurrent query streams over one
graph — the paper's accelerator as a *workload* engine, amortizing across
requests the way ``PlanForest`` amortizes across patterns.

Tick / batching / admission contract
------------------------------------

* **submit** (any thread, non-blocking) — ``service.submit(queries,
  traffic_class=..., timeout_s=...)`` resolves the queries, applies
  admission control, and returns a ``ServiceRequest`` handle the caller
  parks on (``result()``). With ``max_in_flight`` requests already
  queued, the request is rejected immediately — typed
  ``RequestRejected`` on ``result()`` — never queued unboundedly.
* **tick** (ONE service thread — the single consumer; each ``Miner`` is
  single-threaded and the service layers concurrency above the sessions,
  not inside them) — drains the whole queue, expires requests whose
  deadline passed (``RequestTimeout``), serves fully-cached requests,
  then merges the remaining requests' queries *across requests*, per
  traffic class, into ONE ``PlanForest`` schedule
  (``Miner.schedule``/``count_many`` — the same shared-prefix fusion
  that merges patterns inside a batch) and executes it on that class's
  resident session. Results route back per request, per query.
  Counts are bit-identical to executing every request independently
  (the forest contract), and the merged schedule's feed passes are
  *strictly below* the sum of the requests' independent schedules
  whenever a tick merged two or more requests — the gated
  cross-request-sharing fact.
* **result cache** — per-(graph-version, query) LRU in front of the
  pool: repeated queries at one graph version are served without
  touching a session; ``set_graph`` bumps the version and invalidates.
* **value traffic** — ``submit(queries, aggregate="sum"|"max"|"min")``
  serves weighted-value queries (``Miner.aggregate_many``) on the
  ``values`` traffic class by default. Aggregate groups batch and cache
  exactly like count groups, under op-tagged cache keys, and never mix
  into a count group's forest.
* **worker pool** — one resident ``Miner`` per traffic class
  (``WorkerSpec``), mixing unsharded and mesh-sharded sessions
  (``MinerConfig(mesh=S)``); executable caches are topology-keyed, so
  steady state stays 0-retrace per session under any request mix.
* **observability** — the service *consumes* ``repro.obs``: queue-depth
  gauges, per-class latency histograms, admission / cache / sharing
  counters in its ``MetricsRegistry`` (``service.prometheus_text()``),
  and per-tick span trees (``tick`` -> ``execute:<class>``) exported as
  Chrome-trace JSON. Each worker session keeps its own registry.

Entry points: ``launch/serve.py --mine`` drives rounds or a
``--qps/--clients`` load phase through one service;
``benchmarks/bench_serving.py`` is the gated load benchmark.
"""
from .cache import ResultCache
from .loadgen import LoadGenerator, percentile
from .pool import WorkerPool, WorkerSpec
from .request import (RequestFailed, RequestRejected, RequestTimeout,
                      ServiceRequest)
from .service import MiningService, ServiceConfig, VALUES_CLASS

__all__ = [
    "LoadGenerator", "MiningService", "RequestFailed", "RequestRejected",
    "RequestTimeout", "ResultCache", "ServiceConfig", "ServiceRequest",
    "VALUES_CLASS", "WorkerPool", "WorkerSpec", "percentile",
]
