"""Typed metrics registry: counters, gauges, histograms, labeled series.

The registry is the single source of truth for every runtime counter the
mining pipeline keeps (``WaveRunner``/``ShardedWaveRunner``/``Miner``).
It is deliberately tiny and allocation-light — instruments are plain
``__slots__`` objects and an increment is one attribute add — because the
hot path (one ``inc`` per kernel dispatch / host sync) must cost no more
than the raw ``stats[...] += 1`` dict mutations it replaced.

Instruments
-----------

* ``Counter`` — monotone up-counter with an explicitly guarded ``dec``:
  decrements below zero raise instead of silently underflowing (the
  count-rides host-sync bookkeeping in ``mining.engine`` relies on this
  invariant).
* ``Gauge`` — last-written value (e.g. per-shard feed block width).
* ``Histogram`` — count/sum/min/max plus fixed exponential buckets; used
  for span durations and wavefront item sizes.

Labels
------

``registry.counter("shard_feed_items", shard=3)`` creates one instrument
per label set under a shared family name — the labeled-series form the
per-shard metrics use. ``series(name)`` returns the family as a dict
keyed by the sorted ``(key, value)`` label tuple.

The legacy ``WaveRunner.stats`` dict is a *derived view* over this
registry (``LegacyStatsView``): reads pull live instrument values, writes
set them, and the view is bit-identical to the dict the engine used to
mutate in place (golden-tested in tests/test_obs.py).
"""
from __future__ import annotations

from collections.abc import MutableMapping
from typing import Callable

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LegacyStatsView"]


class Counter:
    """Up-counter. ``dec`` enforces a non-negative invariant: the engine's
    ride bookkeeping subtracts host syncs it knows it never paid, and a
    drift below zero is a bug to surface, not arithmetic to absorb."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, v: int = 1) -> None:
        self.value += v

    def dec(self, v: int = 1) -> None:
        nv = self.value - v
        if nv < 0:
            raise ValueError(
                f"counter underflow: dec({v}) from {self.value} — "
                "bookkeeping drift (see mining.engine count-rides path)")
        self.value = nv

    def set(self, v: int) -> None:
        """Explicit reset/write-through (legacy ``stats[...] = n`` sites)."""
        self.value = v

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, v=1) -> None:
        self.value += v

    def snapshot(self):
        return self.value


# default exponential bucket bounds — wide enough for item counts and for
# seconds-scale durations alike (values land in the first bucket whose
# bound is >= v; the last bucket is +inf)
_DEFAULT_BUCKETS = tuple(4.0 ** e for e in range(-8, 9))


class Histogram:
    """count/sum/min/max + fixed exponential buckets (no per-sample
    storage, so observing is O(#buckets) worst case and allocation-free)."""

    __slots__ = ("count", "total", "min", "max", "bounds", "buckets")

    def __init__(self, bounds=_DEFAULT_BUCKETS) -> None:
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.bounds = tuple(bounds)
        self.buckets = [0] * (len(self.bounds) + 1)

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min, "max": self.max, "mean": self.mean}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Name -> instrument (or labeled family of instruments).

    A name is bound to one instrument type on first use; re-requesting it
    as a different type raises (typed registry, not a loose dict). Lookups
    are cached per (name, labels) so hot-path calls after the first are a
    single dict get + attribute add.
    """

    def __init__(self) -> None:
        self._metrics: dict[tuple, object] = {}   # (name, labels) -> inst
        self._types: dict[str, str] = {}          # name -> kind

    # ------------------------------------------------------------- access
    def _get(self, kind: str, name: str, labels: dict, **ctor):
        key = (name, tuple(sorted(labels.items())))
        inst = self._metrics.get(key)
        if inst is not None:
            if self._types[name] != kind:
                raise TypeError(f"metric {name!r} is a "
                                f"{self._types[name]}, requested {kind}")
            return inst
        prev = self._types.setdefault(name, kind)
        if prev != kind:
            raise TypeError(f"metric {name!r} is a {prev}, requested {kind}")
        inst = self._metrics[key] = _KINDS[kind](**ctor)
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, bounds=None, **labels) -> Histogram:
        ctor = {"bounds": bounds} if bounds is not None else {}
        return self._get("histogram", name, labels, **ctor)

    # ------------------------------------------------------------ queries
    def series(self, name: str) -> dict:
        """All instruments of a family: {sorted (key, value) label tuple ->
        instrument} (empty labels -> the ``()`` entry)."""
        return {lk: inst for (n, lk), inst in self._metrics.items()
                if n == name}

    def value(self, name: str, **labels):
        key = (name, tuple(sorted(labels.items())))
        inst = self._metrics.get(key)
        return None if inst is None else inst.snapshot()

    def snapshot(self) -> dict:
        """{name: value} for unlabeled metrics; labeled families nest as
        {name: {"label=value,...": value}} (histograms as their summary
        dicts)."""
        out: dict = {}
        for (name, lk), inst in sorted(self._metrics.items()):
            v = inst.snapshot()
            if not lk:
                out[name] = v
            else:
                lab = ",".join(f"{k}={x}" for k, x in lk)
                out.setdefault(name, {})[lab] = v
        return out

    # ------------------------------------------------------------- export
    def prometheus_text(self, prefix: str = "mining_") -> str:
        """Prometheus text-exposition snapshot of every instrument.

        Counters/gauges emit one sample per label set; histograms emit the
        ``_count``/``_sum``/``_bucket{le=...}`` triplet. Metric names get
        ``prefix`` and non-identifier characters become underscores."""
        def sanitize(n: str) -> str:
            return "".join(c if c.isalnum() or c == "_" else "_"
                           for c in n)

        lines: list[str] = []
        by_name: dict[str, list] = {}
        for (name, lk), inst in sorted(self._metrics.items()):
            by_name.setdefault(name, []).append((lk, inst))
        for name, insts in by_name.items():
            kind = self._types[name]
            pname = prefix + sanitize(name)
            lines.append(f"# TYPE {pname} "
                         f"{'untyped' if kind == 'gauge' else kind}")
            for lk, inst in insts:
                lab = ",".join(f'{sanitize(k)}="{v}"' for k, v in lk)
                labp = "{" + lab + "}" if lab else ""
                if kind == "histogram":
                    cum = 0
                    for bound, c in zip(inst.bounds, inst.buckets):
                        cum += c
                        blab = (lab + "," if lab else "") + f'le="{bound}"'
                        lines.append(f"{pname}_bucket{{{blab}}} {cum}")
                    blab = (lab + "," if lab else "") + 'le="+Inf"'
                    lines.append(f"{pname}_bucket{{{blab}}} {inst.count}")
                    lines.append(f"{pname}_sum{labp} {inst.total}")
                    lines.append(f"{pname}_count{labp} {inst.count}")
                else:
                    lines.append(f"{pname}{labp} {inst.snapshot()}")
        return "\n".join(lines) + ("\n" if lines else "")


class LegacyStatsView(MutableMapping):
    """The engine's historical ``stats`` dict, derived live from a
    ``MetricsRegistry``.

    Every key maps to a getter (and optional setter) registered by the
    runner; iteration order is registration order, so ``dict(view)``
    reproduces the pre-registry dict bit-for-bit (golden-tested). Writes
    (``view["exec_misses"] = 0`` — a couple of legacy call sites) pass
    through to the backing instrument; deletes are not a thing stats ever
    supported and raise."""

    def __init__(self) -> None:
        self._getters: dict[str, Callable] = {}
        self._setters: dict[str, Callable] = {}

    def expose(self, key: str, getter: Callable,
               setter: Callable | None = None) -> None:
        self._getters[key] = getter
        if setter is not None:
            self._setters[key] = setter

    def expose_counter(self, key: str, registry: MetricsRegistry,
                       name: str | None = None) -> Counter:
        c = registry.counter(name or key)
        self.expose(key, lambda: c.value, c.set)
        return c

    def __getitem__(self, key):
        return self._getters[key]()

    def __setitem__(self, key, value) -> None:
        try:
            self._setters[key](value)
        except KeyError:
            raise KeyError(f"stats key {key!r} is not writable") from None

    def __delitem__(self, key) -> None:
        raise TypeError("stats keys cannot be deleted")

    def __iter__(self):
        return iter(self._getters)

    def __len__(self) -> int:
        return len(self._getters)

    def __repr__(self) -> str:
        return repr(dict(self))
