"""Structured tracing: per-query span trees over the mining pipeline.

A ``Tracer`` records a tree of ``Span``s per traced query:

    query(triangle)
    ├─ compile
    ├─ schedule            (batch queries)
    └─ execute
       ├─ feed  L1         (one per edge-feed chunk: cap, items)
       │  └─ level L2 expand
       │     ├─ dispatch   (kernel dispatch + block_until_ready wall time)
       │     └─ level L3 count
       │        └─ dispatch
       └─ ...

Spans nest by wall time (children run inside their parent's interval), so
the tree exports directly to Chrome-trace/Perfetto "X" events
(``repro.obs.export``). Each span records ``perf_counter`` start/end,
a category, and free-form attributes — dispatch spans carry the op kind,
level, wavefront items, capacities and the executable-cache hit/miss bit.

Timing discipline: the engine only opens dispatch spans when the tracer
is *enabled*, and then follows the dispatch with ``block_until_ready`` so
the span measures real device wall time instead of async dispatch time.
Disabled (the default) the engine takes the untraced branch — no spans,
no synchronization, no extra kernel dispatches (tested in
tests/test_obs.py).

``self_seconds`` is a span's exclusive time (duration minus direct
children), which makes per-level attribution sum-consistent: the exclusive
times of every span under ``execute`` add up to the query's execute wall
time minus untracked gaps.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

__all__ = ["Span", "Tracer"]


class Span:
    """One timed node of the trace tree."""

    __slots__ = ("name", "cat", "attrs", "t0", "t1", "children")

    def __init__(self, name: str, cat: str = "span",
                 attrs: dict | None = None):
        self.name = name
        self.cat = cat
        self.attrs = attrs or {}
        self.t0 = time.perf_counter()
        self.t1 = None
        self.children: list[Span] = []

    def close(self) -> None:
        if self.t1 is None:
            self.t1 = time.perf_counter()

    @property
    def seconds(self) -> float:
        return (self.t1 if self.t1 is not None
                else time.perf_counter()) - self.t0

    @property
    def self_seconds(self) -> float:
        """Exclusive time: duration minus direct children's durations."""
        return self.seconds - sum(c.seconds for c in self.children)

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str):
        """All descendant spans (incl. self) with ``name``."""
        return [s for s in self.walk() if s.name == name]

    def to_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat,
                "t0": self.t0, "seconds": self.seconds,
                "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, cat={self.cat!r}, "
                f"{self.seconds * 1e3:.3f}ms, "
                f"{len(self.children)} children)")


class Tracer:
    """Span-tree recorder. ``enabled=False`` (the default) records nothing
    and ``span()`` degenerates to a no-op context manager; finished root
    spans accumulate in ``self.finished``."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.finished: list[Span] = []
        self._stack: list[Span] = []

    # ----------------------------------------------------------- recording
    @property
    def current(self) -> Span | None:
        return self._stack[-1] if self._stack else None

    @contextmanager
    def span(self, name: str, cat: str = "span", **attrs):
        if not self.enabled:
            yield None
            return
        sp = Span(name, cat, attrs)
        parent = self.current
        if parent is not None:
            parent.children.append(sp)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            sp.close()
            self._stack.pop()
            if parent is None:
                self.finished.append(sp)

    def event(self, name: str, **attrs) -> None:
        """Zero-duration marker attached to the current span."""
        if not self.enabled or not self._stack:
            return
        sp = Span(name, "event", attrs)
        sp.t1 = sp.t0
        self._stack[-1].children.append(sp)

    # ------------------------------------------------------------- queries
    def spans(self, name: str | None = None) -> list[Span]:
        """All recorded spans (across finished roots), depth-first;
        filtered by ``name`` when given."""
        out: list[Span] = []
        for root in self.finished:
            out.extend(root.walk() if name is None else root.find(name))
        return out

    def seconds(self, name: str) -> float:
        """Total wall seconds across every span named ``name``."""
        return sum(s.seconds for s in self.spans(name))

    def last(self, name: str) -> Span | None:
        sp = self.spans(name)
        return sp[-1] if sp else None

    def clear(self) -> None:
        self.finished.clear()
        self._stack.clear()

    # ---------------------------------------------------------- aggregates
    def level_seconds(self) -> dict[str, float]:
        """Exclusive (self) seconds aggregated by span name — the
        "where did this query's time go" per-level accounting. Summing the
        values over all spans of a query reproduces the query wall time
        minus untracked host gaps."""
        agg: dict[str, float] = {}
        for sp in self.spans():
            agg[sp.name] = agg.get(sp.name, 0.0) + sp.self_seconds
        return agg
