"""Trace/metrics exporters: Chrome-trace (Perfetto) JSON and Prometheus
text snapshots.

``chrome_trace`` converts a ``Tracer``'s finished span trees into the
Chrome Trace Event Format (the ``traceEvents`` array of "X" complete
events, microsecond timestamps) that chrome://tracing and
https://ui.perfetto.dev load directly. Span attributes ride in ``args``;
each root span gets its own ``tid`` so concurrent queries lay out as
separate tracks.

``write_chrome_trace`` is the ``--trace out.json`` backend of
``launch/mine.py`` and ``launch/serve.py``. The Prometheus text form
lives on ``MetricsRegistry.prometheus_text`` and is re-exported here for
symmetry.
"""
from __future__ import annotations

import json
from pathlib import Path

from .registry import MetricsRegistry
from .trace import Span, Tracer

__all__ = ["chrome_trace", "write_chrome_trace", "prometheus_text"]


def _events(span: Span, pid: int, tid: int, out: list) -> None:
    out.append({
        "name": span.name,
        "cat": span.cat,
        "ph": "X",
        "ts": span.t0 * 1e6,                 # Chrome trace wants microseconds
        "dur": max(span.seconds, 0.0) * 1e6,
        "pid": pid,
        "tid": tid,
        "args": {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                     else str(v)) for k, v in span.attrs.items()},
    })
    for c in span.children:
        _events(c, pid, tid, out)


def chrome_trace(tracer: Tracer, pid: int = 1) -> dict:
    """Chrome Trace Event Format document for a tracer's finished spans."""
    events: list[dict] = []
    for tid, root in enumerate(tracer.finished, start=1):
        _events(root, pid, tid, events)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"exporter": "repro.obs",
                      "spans": len(events)},
    }


def write_chrome_trace(path, tracer: Tracer, registry=None) -> Path:
    """Write the Chrome-trace JSON (plus a metrics snapshot when a
    registry is given) to ``path``; returns the path."""
    doc = chrome_trace(tracer)
    if registry is not None:
        doc["otherData"]["metrics"] = registry.snapshot()
    p = Path(path)
    p.write_text(json.dumps(doc, indent=1))
    return p


def prometheus_text(registry: MetricsRegistry,
                    prefix: str = "mining_") -> str:
    return registry.prometheus_text(prefix=prefix)
