"""Mining telemetry: metrics registry + structured tracing + exporters.

One ``Telemetry`` object carries everything a session needs to answer
"where did this query's time go":

* ``telemetry.metrics`` — a ``MetricsRegistry`` of typed counters /
  gauges / histograms. Always on: the registry IS the backing store of
  the engine's legacy ``stats`` dicts (derived views, bit-identical to
  the dicts they replaced), so metrics cost what the old dict mutations
  cost.
* ``telemetry.tracer`` — a ``Tracer`` producing per-query span trees
  (query → compile/schedule/execute → per-level spans → per-dispatch
  spans with op kind, items, capacities, cache hit/miss and
  ``perf_counter`` wall time around dispatch + ``block_until_ready``).
  Off by default: a disabled tracer records nothing, adds no
  synchronization and no kernel dispatches.
* exporters — Chrome-trace/Perfetto JSON (``--trace out.json`` on
  ``launch/mine.py`` / ``launch/serve.py``), a Prometheus text snapshot,
  and ``snapshot()`` (metrics + per-span aggregates) consumed by
  ``benchmarks/bench_mining.py``.
* ``jax_profile(logdir)`` — optional ``jax.profiler`` start/stop hook
  around a traced query (XLA-level profile to go with the span tree).

Construction: ``Telemetry()`` is disabled tracing + live metrics (what
every ``WaveRunner``/``Miner`` builds when not handed one);
``Telemetry(enabled=True)`` turns the span tree on. Sessions share one
``Telemetry`` across Miner + runner so a query's spans and counters land
in one place.
"""
from __future__ import annotations

from contextlib import contextmanager

from .export import chrome_trace, prometheus_text, write_chrome_trace
from .registry import (Counter, Gauge, Histogram, LegacyStatsView,
                       MetricsRegistry)
from .trace import Span, Tracer

__all__ = ["Telemetry", "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LegacyStatsView", "Span", "Tracer", "chrome_trace",
           "prometheus_text", "write_chrome_trace"]


class Telemetry:
    """Registry + tracer + export surface for one mining session."""

    def __init__(self, enabled: bool = False,
                 registry: MetricsRegistry | None = None):
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = Tracer(enabled=enabled)

    # ------------------------------------------------------------- control
    @property
    def enabled(self) -> bool:
        return self.tracer.enabled

    def enable(self) -> None:
        self.tracer.enabled = True

    def disable(self) -> None:
        self.tracer.enabled = False

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Everything an external consumer wants in one dict: the metrics
        snapshot, per-span-name wall/self-time aggregates, and the root
        span summaries (name, seconds, #children)."""
        spans: dict[str, dict] = {}
        for sp in self.tracer.spans():
            agg = spans.setdefault(sp.name, {"count": 0, "seconds": 0.0,
                                             "self_seconds": 0.0})
            agg["count"] += 1
            agg["seconds"] += sp.seconds
            agg["self_seconds"] += sp.self_seconds
        return {
            "metrics": self.metrics.snapshot(),
            "spans": spans,
            "roots": [{"name": r.name, "cat": r.cat,
                       "seconds": r.seconds,
                       "spans": sum(1 for _ in r.walk())}
                      for r in self.tracer.finished],
        }

    def chrome_trace(self) -> dict:
        return chrome_trace(self.tracer)

    def write_trace(self, path):
        return write_chrome_trace(path, self.tracer, self.metrics)

    def prometheus_text(self, prefix: str = "mining_") -> str:
        return self.metrics.prometheus_text(prefix=prefix)

    # ------------------------------------------------------ jax profiler
    @contextmanager
    def jax_profile(self, logdir: str | None):
        """Optional ``jax.profiler`` start/stop hook around a traced
        query: ``with tel.jax_profile("/tmp/prof"): miner.count(...)``.
        ``logdir=None`` (or an unavailable profiler) degrades to a
        no-op, so callers can pass the CLI flag through unconditionally."""
        if not logdir:
            yield None
            return
        import jax
        jax.profiler.start_trace(logdir)
        try:
            yield logdir
        finally:
            jax.profiler.stop_trace()


# module-level disabled singleton: runners built without a session share
# this so bare WaveRunner construction never allocates tracer state; note
# its *registry* is still per-runner (each runner builds its own
# Telemetry unless handed one — see WaveRunner.__init__)
def null_telemetry() -> Telemetry:
    return Telemetry(enabled=False)
