"""IntersectX stream ISA (Table I of the paper) as composable JAX ops.

Every instruction becomes a pure, jit-able function on ``Stream`` pytrees with
*static shapes*: the paper's dependency-tracking property |A∩B| <= min(|A|,|B|)
(§IV-D) sizes the output buffers, so XLA sees fixed capacities while lengths
stay dynamic.

Bound semantics (the R3 operand, §III-B): results contain only keys strictly
below ``bound``; ``bound=None`` (the paper's -1) means unbounded — we pass
SENTINEL so there is a single code path. Early termination on TPU is realised
at the kernel level by skipping out-of-bound VMEM tiles (see
``repro.kernels.intersect``); at the ISA level bounds are masks.

These jnp implementations are the *semantic reference* (and the fast XLA:CPU
path). ``repro.kernels.ops`` provides the Pallas TPU path with identical
signatures; tests assert they agree element-for-element.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .stream import SENTINEL, Stream

# re-export for callers
stream_read = None  # set below to avoid circular docs


def _membership(a_keys: jax.Array, b_keys: jax.Array) -> jax.Array:
    """found[i] = a_keys[i] in b_keys (both sorted, sentinel-padded)."""
    idx = jnp.searchsorted(b_keys, a_keys)
    hit = b_keys[jnp.clip(idx, 0, b_keys.shape[0] - 1)] == a_keys
    return hit & (a_keys != SENTINEL)


def _compact(keys: jax.Array, keep: jax.Array, out_cap: int) -> tuple[jax.Array, jax.Array]:
    """Stable-compact kept keys to the front, sentinel-pad; returns (keys, count).

    Sorting keeps order because kept keys are already ascending and dropped
    slots become SENTINEL (> every valid key).
    """
    masked = jnp.where(keep, keys, SENTINEL)
    packed = jnp.sort(masked)[:out_cap]
    return packed, jnp.sum(keep, dtype=jnp.int32)


def _bound_or_sentinel(bound) -> jax.Array:
    if bound is None:
        return jnp.asarray(SENTINEL, jnp.int32)
    return jnp.asarray(bound, jnp.int32)


# ----------------------------------------------------------------------------
# S_INTER / S_INTER.C
# ----------------------------------------------------------------------------

def s_inter(a: Stream, b: Stream, bound=None) -> Stream:
    """S_INTER: out = {k in A ∩ B : k < bound}, capacity = min(capA, capB)."""
    ub = _bound_or_sentinel(bound)
    keep = _membership(a.keys, b.keys) & (a.keys < ub)
    out_cap = min(a.capacity, b.capacity)
    keys, count = _compact(a.keys, keep, out_cap)
    return Stream(keys=keys, length=count)


def s_inter_c(a: Stream, b: Stream, bound=None) -> jax.Array:
    """S_INTER.C: |{k in A ∩ B : k < bound}| (count only, no output stream)."""
    ub = _bound_or_sentinel(bound)
    keep = _membership(a.keys, b.keys) & (a.keys < ub)
    return jnp.sum(keep, dtype=jnp.int32)


# ----------------------------------------------------------------------------
# S_SUB / S_SUB.C
# ----------------------------------------------------------------------------

def s_sub(a: Stream, b: Stream, bound=None) -> Stream:
    """S_SUB: out = {k in A \\ B : k < bound}, capacity = capA."""
    ub = _bound_or_sentinel(bound)
    keep = (~_membership(a.keys, b.keys)) & (a.keys != SENTINEL) & (a.keys < ub)
    keys, count = _compact(a.keys, keep, a.capacity)
    return Stream(keys=keys, length=count)


def s_sub_c(a: Stream, b: Stream, bound=None) -> jax.Array:
    """S_SUB.C: |{k in A \\ B : k < bound}|."""
    ub = _bound_or_sentinel(bound)
    keep = (~_membership(a.keys, b.keys)) & (a.keys != SENTINEL) & (a.keys < ub)
    return jnp.sum(keep, dtype=jnp.int32)


# ----------------------------------------------------------------------------
# S_VINTER — sparse computation on values (SVPU, §IV-E)
# ----------------------------------------------------------------------------

VINTER_OPS = ("mac", "max", "min")


@partial(jax.jit, static_argnames=("op",))
def s_vinter(a: Stream, b: Stream, op: str = "mac") -> jax.Array:
    """S_VINTER: intersect keys, reduce over aligned value pairs.

    op='mac' : Σ va·vb   (sparse dot product)
    op='max' : Σ max(va, vb)
    op='min' : Σ min(va, vb)
    """
    if a.values is None or b.values is None:
        raise TypeError("S_VINTER requires (key,value) streams (paper: exception)")
    if op not in VINTER_OPS:
        raise ValueError(f"unknown SVPU op {op!r}; supported: {VINTER_OPS}")
    idx = jnp.clip(jnp.searchsorted(b.keys, a.keys), 0, b.capacity - 1)
    found = (b.keys[idx] == a.keys) & (a.keys != SENTINEL)
    va = a.values
    vb = b.values[idx]
    if op == "mac":
        terms = va * vb
    elif op == "max":
        terms = jnp.maximum(va, vb)
    else:
        terms = jnp.minimum(va, vb)
    return jnp.sum(jnp.where(found, terms, 0.0), dtype=jnp.float32)


# ----------------------------------------------------------------------------
# S_FETCH — stream element access
# ----------------------------------------------------------------------------

def s_fetch(s: Stream, offset) -> jax.Array:
    """S_FETCH: s.keys[offset], or SENTINEL ("EOS") past the end."""
    offset = jnp.asarray(offset, jnp.int32)
    key = s.keys[jnp.clip(offset, 0, s.capacity - 1)]
    return jnp.where(offset < s.length, key, SENTINEL)


# ----------------------------------------------------------------------------
# derived helpers used across mining apps
# ----------------------------------------------------------------------------

def s_union_count(a: Stream, b: Stream) -> jax.Array:
    """|A ∪ B| = |A| + |B| - |A ∩ B| (not a paper instruction; test invariant)."""
    return a.length + b.length - s_inter_c(a, b)
