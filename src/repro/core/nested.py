"""S_NESTINTER — the paper's CISC nested-intersection instruction (§III-B, §IV-F).

Semantics: given key stream S = [s_0..s_k] over a CSR graph loaded with
S_CSR,    C = Σ_i |S ∩ N(s_i)|.

The paper's hardware translates this into a µop sequence (S_READ/S_INTER.C/
S_FREE per key) buffered in a translation buffer. On TPU the translation is
*static*: gather the neighbor rows of every key of S (one vectorised gather
= the translator's load-queue traffic) and run one batched intersection
count against S. Degree bucketing bounds padding waste — the analogue of the
translation buffer never stalling on over-long streams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .batch import batch_inter_count
from .stream import SENTINEL, Stream, round_capacity


def s_nestinter(g, s: Stream, cap: int | None = None,
                bound_by_key: bool = False) -> jax.Array:
    """C = Σ_{i<len(S)} |S ∩ N(s_i)| (optionally bounded by s_i per key).

    ``bound_by_key=True`` is a beyond-paper extension: each inner intersection
    is bounded by its own key (counts only common neighbors < s_i), which is
    the inner loop of symmetry-broken clique counting.
    """
    from repro.graph.csr import padded_rows  # deferred: graph layer sits above core
    cap = round_capacity(cap if cap is not None else g.max_degree)
    rows, _ = padded_rows(g, s.keys, cap)           # (capS, cap) — SENTINEL keys
    valid = s.keys != SENTINEL                      # gather of SENTINEL key is garbage
    rows = jnp.where(valid[:, None], rows, SENTINEL)
    bounds = s.keys if bound_by_key else None
    a = jnp.broadcast_to(s.keys[None, :], (rows.shape[0], s.capacity))
    counts = batch_inter_count(a, rows, bounds)
    # int32 explicitly: without jax_enable_x64 an int64 request is silently
    # truncated (with a UserWarning); per-vertex counts fit int32 comfortably.
    return jnp.sum(jnp.where(valid, counts, 0), dtype=jnp.int32)
