"""Batched stream ops — the paper's "4 parallel IUs" as data parallelism.

Rows are sentinel-padded sorted int32 matrices (B, cap). ``bounds`` is a
per-row exclusive upper bound (SENTINEL = unbounded), realising the R3
early-termination operand per lane. These jnp forms are the semantic
reference and the XLA:CPU fast path; ``repro.kernels.ops`` exposes identical
signatures backed by Pallas TPU kernels and is tested to agree exactly.

Implementation note: membership is a vmapped binary search
(``jnp.searchsorted``) — O(capA · log capB) per row with no data-dependent
branches, which is what the VPU wants. The Pallas path instead uses all-pairs
tile compare with tile skipping (see kernels/intersect.py); both orders
agree because keys are strictly sorted sets.

Compaction contract (``batch_compact_rows`` / ``batch_compact_scan``): the
survivor streams and the flattened worklist are built by a segmented
prefix-sum scatter — O(B·cap) data movement, no sort. This is correct under
the **monotonicity precondition**: the base rows are sorted streams and the
keep mask preserves relative order (it selects, never reorders), so writing
survivor j to slot ``cumsum(keep)[j] - 1`` reproduces exactly what the old
masked sort (``jnp.where(keep, a, SENTINEL)`` + ``jnp.sort``) produced —
kept keys, in order, front-packed, SENTINEL-padded. Every level path in this
repo satisfies the precondition (bases are per-row sorted; items are emitted
row-major); ``batch_compact_items`` keeps the masked-sort form as the
semantic oracle the scan twins are tested against.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .stream import SENTINEL


def _row_membership(a_row: jax.Array, b_row: jax.Array) -> jax.Array:
    idx = jnp.searchsorted(b_row, a_row)
    hit = b_row[jnp.clip(idx, 0, b_row.shape[0] - 1)] == a_row
    return hit & (a_row != SENTINEL)


_membership = jax.vmap(_row_membership)


@jax.jit
def batch_member_mark(rows_a: jax.Array, rows_b: jax.Array) -> jax.Array:
    """mark[i, s] = A_i[s] ∈ B_i (and A_i[s] live) — the XLA twin of the
    Pallas mark kernel; the plan interpreter composes several of these into
    one keep-mask per level (multi-operand INTER/SUB µops, §IV-F)."""
    return _membership(rows_a, rows_b)


def _bounds(rows_a: jax.Array, bounds) -> jax.Array:
    if bounds is None:
        return jnp.full((rows_a.shape[0],), SENTINEL, jnp.int32)
    return jnp.asarray(bounds, jnp.int32)


def _lbounds(rows_a: jax.Array, lbounds) -> jax.Array:
    """Per-row exclusive lower bound; -1 = unbounded (vertex ids are >= 0)."""
    if lbounds is None:
        return jnp.full((rows_a.shape[0],), -1, jnp.int32)
    return jnp.asarray(lbounds, jnp.int32)


# ---------------------------------------------------------------------------
# segmented prefix-sum scatter compaction (sort-free; see module docstring
# for the monotonicity precondition)
# ---------------------------------------------------------------------------


def _scan_compact_parts(rows_a: jax.Array, keep: jax.Array, out_cap: int):
    """Shared segmented-prefix-sum core: (rows, counts, keep, pos, row).

    ``pos`` is each survivor's slot in its row stream; ``row`` the row index
    grid — the item scatter in ``batch_compact_scan`` reuses both. Survivors
    past ``out_cap`` are dropped (callers size out_cap from the §IV-D
    dependency bound, so none exist on the engine paths)."""
    B, cap = rows_a.shape
    keep = keep & (rows_a != SENTINEL)
    counts = jnp.sum(keep, axis=1, dtype=jnp.int32)
    pos = jnp.cumsum(keep, axis=1, dtype=jnp.int32) - 1
    col = jnp.where(keep, pos, out_cap)              # out_cap = dropped
    row = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, cap))
    rows = jnp.full((B, out_cap), SENTINEL, jnp.int32) \
        .at[row, col].set(rows_a, mode="drop")
    return rows, counts, keep, pos, row


def batch_compact_rows(rows_a: jax.Array, keep: jax.Array,
                       out_cap: int) -> tuple[jax.Array, jax.Array]:
    """Per-row survivor streams from a keep mask, by prefix-sum scatter.

    Returns (rows (B, out_cap) front-packed SENTINEL-padded, counts (B,)).
    Survivors keep their order (monotone input => sorted output) — the
    O(B·cap) replacement for the ``jnp.where`` + ``jnp.sort`` masked-sort
    tail."""
    rows, counts, _, _, _ = _scan_compact_parts(rows_a, keep, out_cap)
    return rows, counts


@partial(jax.jit, static_argnames=("out_cap", "out_items"))
def batch_compact_scan(rows_a: jax.Array, keep: jax.Array, out_cap: int,
                       out_items: int):
    """Fused survivor-stream + worklist compaction from one keep mask.

    The O(B·cap) scan-scatter twin of ``jnp.sort`` + ``batch_compact_items``:
    one segmented prefix sum assigns every survivor both its slot in the
    per-row stream and — offset by the exclusive row-count prefix — its slot
    in the flattened row-major worklist. Output contract matches
    ``kernels.ops.xinter_compact``:

      rows   (B, out_cap)   front-packed survivor streams
      counts (B,)           per-row survivor counts
      src    (out_items,)   item -> source row   (0 past total)
      verts  (out_items,)   item extension vertex (0 past total)
      total  ()             live item count
      maxc   ()             max per-row survivor count

    Item order is bit-identical to ``batch_compact_items`` on the masked-sort
    rows (row-major (i, j)), which is the order the host ``np.nonzero``
    oracle emits."""
    rows, counts, keep, pos, row = _scan_compact_parts(rows_a, keep, out_cap)
    offs = jnp.cumsum(counts, dtype=jnp.int32) - counts   # exclusive prefix
    ipos = jnp.where(keep, offs[:, None] + pos, out_items).reshape(-1)
    src = jnp.zeros((out_items,), jnp.int32) \
        .at[ipos].set(row.reshape(-1), mode="drop")
    verts = jnp.zeros((out_items,), jnp.int32) \
        .at[ipos].set(rows_a.reshape(-1), mode="drop")
    return rows, counts, src, verts, jnp.sum(counts), jnp.max(counts)


def compact_indices_scan(ok: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Order-preserving index compaction: positions of the set bits of
    ``ok``, front-packed (0 past the live count), plus the live count.

    The 1-D scan twin of the masked index sort (``jnp.sort(where(ok, iota,
    SENTINEL))``) used by the per-branch residual worklist pack."""
    n = ok.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    pos = jnp.cumsum(ok, dtype=jnp.int32) - 1
    tgt = jnp.where(ok, pos, n)
    order = jnp.zeros((n,), jnp.int32).at[tgt].set(idx, mode="drop")
    return order, jnp.sum(ok, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# multi-operand level (k INTER/SUB refs in one keep mask) — the XLA twin of
# kernels.intersect.intersect_multi_pallas
# ---------------------------------------------------------------------------


def _level_keep(rows_a, bs, pol, ub, lb, excludes):
    """keep = window ∧ excludes ∧ (∈ B_r ∀ INTER r) ∧ (∉ B_r ∀ SUB r)."""
    keep = (rows_a != SENTINEL) & (rows_a < ub[:, None]) \
        & (rows_a > lb[:, None])
    if excludes is not None:
        keep = keep & jnp.all(rows_a[:, :, None] != excludes[:, None, :],
                              axis=2)
    for r, p in enumerate(pol):
        m = _membership(rows_a, bs[r])
        keep = keep & m if p else keep & ~m
    return keep


@partial(jax.jit, static_argnames=("pol",))
def batch_level_count(rows_a, bs, pol, bounds=None, lbounds=None,
                      excludes=None):
    """counts[i] = |{k ∈ A_i : all pol-signed memberships, window, excl}| —
    the whole multi-operand level's S_*.C in one call (k = 0 degenerates to
    a pure window/injectivity count, no membership work)."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    return jnp.sum(_level_keep(rows_a, bs, pol, ub, lb, excludes), axis=1,
                   dtype=jnp.int32)


@partial(jax.jit, static_argnames=("pol", "out_cap", "out_items"))
def batch_level_compact(rows_a, bs, pol, bounds, lbounds, excludes,
                        out_cap: int, out_items: int):
    """Fused multi-operand level + scan compaction — ``xinter_compact``'s
    contract (rows, counts, src, verts, total, maxc) for any k-ref level."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _level_keep(rows_a, bs, pol, ub, lb, excludes)
    return batch_compact_scan(rows_a, keep, out_cap, out_items)


def _row_matched_vals(a_row, b_row, bv_row):
    """Per A-slot matched value in (B_r, V_r): bv at the matching key, 0.0
    on a miss — the searchsorted twin of the Pallas mask-MAC lane."""
    idx = jnp.clip(jnp.searchsorted(b_row, a_row), 0, b_row.shape[0] - 1)
    found = (b_row[idx] == a_row) & (a_row != SENTINEL)
    return jnp.where(found, bv_row[idx], 0.0)


_matched_vals = jax.vmap(_row_matched_vals)


@partial(jax.jit, static_argnames=("pol", "op"))
def batch_level_agg(rows_a, bs, pol, a_vals, b_vals, scale, op: str = "sum",
                    bounds=None, lbounds=None, excludes=None):
    """XLA twin of ``intersect_multi_agg_pallas`` -> (counts, vals).

    Same keep mask as ``batch_level_count``; each kept slot carries
    ``a_vals * Π_{INTER r} matched_val_r * scale[row]`` and ``vals`` reduces
    the kept slots per row with ``op`` (sum / max / min; op identity — 0.0 /
    -3.4e38 / +3.4e38 — for empty rows, same contract as the kernel)."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _level_keep(rows_a, bs, pol, ub, lb, excludes)
    contrib = a_vals.astype(jnp.float32)
    for r, p in enumerate(pol):
        if p:
            contrib = contrib * _matched_vals(rows_a, bs[r], b_vals[r])
    contrib = contrib * jnp.asarray(scale, jnp.float32)[:, None]
    counts = jnp.sum(keep, axis=1, dtype=jnp.int32)
    if op == "sum":
        vals = jnp.sum(jnp.where(keep, contrib, 0.0), axis=1,
                       dtype=jnp.float32)
    elif op == "max":
        vals = jnp.max(jnp.where(keep, contrib, -3.4e38), axis=1)
    elif op == "min":
        vals = jnp.min(jnp.where(keep, contrib, 3.4e38), axis=1)
    else:
        raise ValueError(f"unknown SVPU aggregate {op!r}")
    return counts, vals


@jax.jit
def batch_inter_count(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
                      lbounds=None) -> jax.Array:
    """counts[i] = |{k in A_i ∩ B_i : lbounds[i] < k < bounds[i]}| —
    batched S_INTER.C (ub = R3 operand, lb = the beyond-paper twin)."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _membership(rows_a, rows_b) & (rows_a < ub[:, None]) \
        & (rows_a > lb[:, None])
    return jnp.sum(keep, axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("out_cap",))
def batch_inter(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
                out_cap: int | None = None, lbounds=None):
    """Batched S_INTER. Returns (rows, counts) with rows (B, out_cap).

    out_cap defaults to min(capA, capB) — the paper's §IV-D dependency bound
    reused to size the output statically.
    """
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _membership(rows_a, rows_b) & (rows_a < ub[:, None]) \
        & (rows_a > lb[:, None])
    cap = out_cap or min(rows_a.shape[1], rows_b.shape[1])
    rows, counts = batch_compact_rows(rows_a, keep, cap)
    return rows, counts


@jax.jit
def batch_sub_count(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
                    lbounds=None) -> jax.Array:
    """counts[i] = |{k in A_i \\ B_i : lbounds[i] < k < bounds[i]}| —
    batched S_SUB.C."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = (~_membership(rows_a, rows_b)) & (rows_a != SENTINEL) \
        & (rows_a < ub[:, None]) & (rows_a > lb[:, None])
    return jnp.sum(keep, axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("out_cap",))
def batch_sub(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
              out_cap: int | None = None, lbounds=None):
    """Batched S_SUB. Returns (rows, counts), rows (B, out_cap or capA)."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = (~_membership(rows_a, rows_b)) & (rows_a != SENTINEL) \
        & (rows_a < ub[:, None]) & (rows_a > lb[:, None])
    cap = out_cap or rows_a.shape[1]
    rows, counts = batch_compact_rows(rows_a, keep, cap)
    return rows, counts


@partial(jax.jit, static_argnames=("out_cap", "out_items"))
def batch_sub_compact(rows_a: jax.Array, rows_b: jax.Array, bounds,
                      out_cap: int, out_items: int, lbounds=None):
    """Fused batched S_SUB + worklist compaction (device-resident SUB level).

    Mirrors ``batch_inter`` + the scan compaction but keeps the complement:
    survivors are keys of A not present in B (and < bounds). Returns
    (rows, counts, src, verts, total, maxc) with the same contract as
    ``kernels.ops.xinter_compact``.
    """
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = (~_membership(rows_a, rows_b)) & (rows_a != SENTINEL) \
        & (rows_a < ub[:, None]) & (rows_a > lb[:, None])
    return batch_compact_scan(rows_a, keep, out_cap, out_items)


@partial(jax.jit, static_argnames=("out_cap", "out_items"))
def batch_inter_compact(rows_a: jax.Array, rows_b: jax.Array, bounds,
                        out_cap: int, out_items: int, lbounds=None):
    """Fused batched S_INTER + worklist compaction (device-resident INTER
    level) — one keep mask feeding ``batch_compact_scan``; the XLA twin of
    the Pallas ``xinter_compact`` fast path, now sort-free end to end."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _membership(rows_a, rows_b) & (rows_a < ub[:, None]) \
        & (rows_a > lb[:, None])
    return batch_compact_scan(rows_a, keep, out_cap, out_items)


@partial(jax.jit, static_argnames=("out_items",))
def batch_compact_items(rows: jax.Array, counts: jax.Array, out_items: int):
    """Device-side worklist compaction — §IV-F's translation buffer on device.

    Every valid key rows[i, j] (j < counts[i]) becomes a work item; items are
    emitted in row-major (i, j) order — bit-identical to the host oracle's
    ``np.nonzero`` order. Returns:

      src    (out_items,) int32  source row index of each item (0 past total)
      verts  (out_items,) int32  extension vertex / bound    (0 past total)
      total  ()           int32  number of live items
      maxc   ()           int32  max per-row survivor count (next capacity)

    Padding items carry vert=0, i.e. bound 0: they contribute nothing
    downstream, so callers never need a validity mask on the fast path.
    Mechanism: masked sort of flattened slot indices (valid slots keep their
    row-major index, dead slots get int32-max) — a single XLA sort, no host
    round-trip. This O(B·cap·log) form is the *oracle*; the engine paths run
    the O(B·cap) ``batch_compact_scan`` scatter, tested item-identical.
    """
    B, cap = rows.shape
    counts = counts.astype(jnp.int32)
    col = jnp.arange(cap, dtype=jnp.int32)
    valid = col[None, :] < counts[:, None]
    flat_valid = valid.reshape(-1)
    slot = jnp.arange(B * cap, dtype=jnp.int32)
    key = jnp.where(flat_valid, slot, SENTINEL)
    if out_items > key.shape[0]:   # chunk-rounded item buffer > B*cap
        key = jnp.pad(key, (0, out_items - key.shape[0]),
                      constant_values=SENTINEL)
    order = jnp.sort(key)[:out_items]
    total = jnp.sum(flat_valid, dtype=jnp.int32)
    live = jnp.arange(out_items, dtype=jnp.int32) < total
    safe = jnp.where(live, order, 0)
    src = safe // cap
    verts = jnp.where(live, rows.reshape(-1)[safe], 0).astype(jnp.int32)
    return src, verts, total, jnp.max(counts)


@partial(jax.jit, static_argnames=("op",))
def batch_vinter(rows_a, vals_a, rows_b, vals_b, op: str = "mac") -> jax.Array:
    """Batched S_VINTER: per-row reduce over value pairs of intersected keys."""
    idx = jnp.clip(jax.vmap(jnp.searchsorted)(rows_b, rows_a), 0, rows_b.shape[1] - 1)
    found = (jnp.take_along_axis(rows_b, idx, axis=1) == rows_a) & (rows_a != SENTINEL)
    vb = jnp.take_along_axis(vals_b, idx, axis=1)
    if op == "mac":
        terms = vals_a * vb
    elif op == "max":
        terms = jnp.maximum(vals_a, vb)
    elif op == "min":
        terms = jnp.minimum(vals_a, vb)
    else:
        raise ValueError(f"unknown SVPU op {op!r}")
    return jnp.sum(jnp.where(found, terms, 0.0), axis=1, dtype=jnp.float32)
