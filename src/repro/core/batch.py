"""Batched stream ops — the paper's "4 parallel IUs" as data parallelism.

Rows are sentinel-padded sorted int32 matrices (B, cap). ``bounds`` is a
per-row exclusive upper bound (SENTINEL = unbounded), realising the R3
early-termination operand per lane. These jnp forms are the semantic
reference and the XLA:CPU fast path; ``repro.kernels.ops`` exposes identical
signatures backed by Pallas TPU kernels and is tested to agree exactly.

Implementation note: membership is a vmapped binary search
(``jnp.searchsorted``) — O(capA · log capB) per row with no data-dependent
branches, which is what the VPU wants. The Pallas path instead uses all-pairs
tile compare with tile skipping (see kernels/intersect.py); both orders
agree because keys are strictly sorted sets.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .stream import SENTINEL


def _row_membership(a_row: jax.Array, b_row: jax.Array) -> jax.Array:
    idx = jnp.searchsorted(b_row, a_row)
    hit = b_row[jnp.clip(idx, 0, b_row.shape[0] - 1)] == a_row
    return hit & (a_row != SENTINEL)


_membership = jax.vmap(_row_membership)


@jax.jit
def batch_member_mark(rows_a: jax.Array, rows_b: jax.Array) -> jax.Array:
    """mark[i, s] = A_i[s] ∈ B_i (and A_i[s] live) — the XLA twin of the
    Pallas mark kernel; the plan interpreter composes several of these into
    one keep-mask per level (multi-operand INTER/SUB µops, §IV-F)."""
    return _membership(rows_a, rows_b)


def _bounds(rows_a: jax.Array, bounds) -> jax.Array:
    if bounds is None:
        return jnp.full((rows_a.shape[0],), SENTINEL, jnp.int32)
    return jnp.asarray(bounds, jnp.int32)


def _lbounds(rows_a: jax.Array, lbounds) -> jax.Array:
    """Per-row exclusive lower bound; -1 = unbounded (vertex ids are >= 0)."""
    if lbounds is None:
        return jnp.full((rows_a.shape[0],), -1, jnp.int32)
    return jnp.asarray(lbounds, jnp.int32)


@jax.jit
def batch_inter_count(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
                      lbounds=None) -> jax.Array:
    """counts[i] = |{k in A_i ∩ B_i : lbounds[i] < k < bounds[i]}| —
    batched S_INTER.C (ub = R3 operand, lb = the beyond-paper twin)."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _membership(rows_a, rows_b) & (rows_a < ub[:, None]) \
        & (rows_a > lb[:, None])
    return jnp.sum(keep, axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("out_cap",))
def batch_inter(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
                out_cap: int | None = None, lbounds=None):
    """Batched S_INTER. Returns (rows, counts) with rows (B, out_cap).

    out_cap defaults to min(capA, capB) — the paper's §IV-D dependency bound
    reused to size the output statically.
    """
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = _membership(rows_a, rows_b) & (rows_a < ub[:, None]) \
        & (rows_a > lb[:, None])
    cap = out_cap or min(rows_a.shape[1], rows_b.shape[1])
    masked = jnp.where(keep, rows_a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :cap]
    return rows, jnp.sum(keep, axis=1, dtype=jnp.int32)


@jax.jit
def batch_sub_count(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
                    lbounds=None) -> jax.Array:
    """counts[i] = |{k in A_i \\ B_i : lbounds[i] < k < bounds[i]}| —
    batched S_SUB.C."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = (~_membership(rows_a, rows_b)) & (rows_a != SENTINEL) \
        & (rows_a < ub[:, None]) & (rows_a > lb[:, None])
    return jnp.sum(keep, axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("out_cap",))
def batch_sub(rows_a: jax.Array, rows_b: jax.Array, bounds=None,
              out_cap: int | None = None, lbounds=None):
    """Batched S_SUB. Returns (rows, counts), rows (B, out_cap or capA)."""
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = (~_membership(rows_a, rows_b)) & (rows_a != SENTINEL) \
        & (rows_a < ub[:, None]) & (rows_a > lb[:, None])
    cap = out_cap or rows_a.shape[1]
    masked = jnp.where(keep, rows_a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :cap]
    return rows, jnp.sum(keep, axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("out_cap", "out_items"))
def batch_sub_compact(rows_a: jax.Array, rows_b: jax.Array, bounds,
                      out_cap: int, out_items: int, lbounds=None):
    """Fused batched S_SUB + worklist compaction (device-resident SUB level).

    Mirrors ``batch_inter`` + ``batch_compact_items`` but keeps the
    complement: survivors are keys of A not present in B (and < bounds).
    Returns (rows, counts, src, verts, total, maxc) with the same contract
    as ``kernels.ops.xinter_compact``.
    """
    ub, lb = _bounds(rows_a, bounds), _lbounds(rows_a, lbounds)
    keep = (~_membership(rows_a, rows_b)) & (rows_a != SENTINEL) \
        & (rows_a < ub[:, None]) & (rows_a > lb[:, None])
    masked = jnp.where(keep, rows_a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :out_cap]
    counts = jnp.sum(keep, axis=1, dtype=jnp.int32)
    src, verts, total, maxc = batch_compact_items(rows, counts, out_items)
    return rows, counts, src, verts, total, maxc


@partial(jax.jit, static_argnames=("out_items",))
def batch_compact_items(rows: jax.Array, counts: jax.Array, out_items: int):
    """Device-side worklist compaction — §IV-F's translation buffer on device.

    Every valid key rows[i, j] (j < counts[i]) becomes a work item; items are
    emitted in row-major (i, j) order — bit-identical to the host oracle's
    ``np.nonzero`` order. Returns:

      src    (out_items,) int32  source row index of each item (0 past total)
      verts  (out_items,) int32  extension vertex / bound    (0 past total)
      total  ()           int32  number of live items
      maxc   ()           int32  max per-row survivor count (next capacity)

    Padding items carry vert=0, i.e. bound 0: they contribute nothing
    downstream, so callers never need a validity mask on the fast path.
    Mechanism: masked sort of flattened slot indices (valid slots keep their
    row-major index, dead slots get int32-max) — a single XLA sort, no host
    round-trip.
    """
    B, cap = rows.shape
    counts = counts.astype(jnp.int32)
    col = jnp.arange(cap, dtype=jnp.int32)
    valid = col[None, :] < counts[:, None]
    flat_valid = valid.reshape(-1)
    slot = jnp.arange(B * cap, dtype=jnp.int32)
    key = jnp.where(flat_valid, slot, SENTINEL)
    if out_items > key.shape[0]:   # chunk-rounded item buffer > B*cap
        key = jnp.pad(key, (0, out_items - key.shape[0]),
                      constant_values=SENTINEL)
    order = jnp.sort(key)[:out_items]
    total = jnp.sum(flat_valid, dtype=jnp.int32)
    live = jnp.arange(out_items, dtype=jnp.int32) < total
    safe = jnp.where(live, order, 0)
    src = safe // cap
    verts = jnp.where(live, rows.reshape(-1)[safe], 0).astype(jnp.int32)
    return src, verts, total, jnp.max(counts)


@partial(jax.jit, static_argnames=("op",))
def batch_vinter(rows_a, vals_a, rows_b, vals_b, op: str = "mac") -> jax.Array:
    """Batched S_VINTER: per-row reduce over value pairs of intersected keys."""
    idx = jnp.clip(jax.vmap(jnp.searchsorted)(rows_b, rows_a), 0, rows_b.shape[1] - 1)
    found = (jnp.take_along_axis(rows_b, idx, axis=1) == rows_a) & (rows_a != SENTINEL)
    vb = jnp.take_along_axis(vals_b, idx, axis=1)
    if op == "mac":
        terms = vals_a * vb
    elif op == "max":
        terms = jnp.maximum(vals_a, vb)
    elif op == "min":
        terms = jnp.minimum(vals_a, vb)
    else:
        raise ValueError(f"unknown SVPU op {op!r}")
    return jnp.sum(jnp.where(found, terms, 0.0), axis=1, dtype=jnp.float32)
