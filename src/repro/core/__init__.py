"""IntersectX stream ISA: Stream pytree + Table-I ops + batched/nested forms."""
from .stream import (LANE, SENTINEL, Stream, StreamTable, empty_stream,
                     make_stream, round_capacity, stream_from_slice, to_host)
from . import isa
from .batch import batch_inter, batch_inter_count, batch_sub, batch_sub_count, batch_vinter
from .nested import s_nestinter

__all__ = [
    "LANE", "SENTINEL", "Stream", "StreamTable", "empty_stream", "make_stream",
    "round_capacity", "stream_from_slice", "to_host", "isa",
    "batch_inter", "batch_inter_count", "batch_sub", "batch_sub_count",
    "batch_vinter", "s_nestinter",
]
