"""Stream: the paper's first-class sparse-vector data type, as a JAX pytree.

A stream is a sorted int32 key array of *static capacity*, padded with
``SENTINEL`` (2^31-1), plus a live length. (key,value) streams carry a values
array aligned with keys. All ISA ops (``repro.core.isa``) preserve the
invariants below, which are enforced by property tests:

  I1  keys[:length] strictly increasing (edge lists / sparse indices are sets)
  I2  keys[length:] == SENTINEL
  I3  0 <= length <= capacity
  I4  capacity % LANE == 0  (TPU lane alignment; the paper's 64-key S-Cache
      slot becomes a 128-key VMEM tile)

The paper's Stream Mapping Table (SMT) tracked stream-ID -> stream-register
mappings at decode time; in an AOT-compiled dataflow program that bookkeeping
is XLA buffer assignment. ``StreamTable`` keeps the *programming model*
(Table II handles with define/active bits) for the API layer.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.int32(np.iinfo(np.int32).max)  # 2147483647, "End Of Stream"
LANE = 128  # TPU lane width; minimum stream capacity granule


def round_capacity(n: int) -> int:
    """Smallest multiple of LANE >= max(n, 1)."""
    return max(LANE, ((int(n) + LANE - 1) // LANE) * LANE)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Stream:
    """A key stream or (key,value) stream (values is None for key streams)."""

    keys: jax.Array                     # (capacity,) int32, sorted, sentinel-padded
    length: jax.Array                   # ()        int32
    values: jax.Array | None = None    # (capacity,) float, aligned with keys

    @property
    def capacity(self) -> int:
        return self.keys.shape[-1]

    @property
    def has_values(self) -> bool:
        return self.values is not None


def make_stream(keys, values=None, capacity: int | None = None) -> Stream:
    """Build a stream from a host/np array of sorted unique keys."""
    keys = np.asarray(keys, dtype=np.int32)
    assert keys.ndim == 1
    n = int(keys.shape[0])
    cap = round_capacity(capacity if capacity is not None else n)
    out = np.full((cap,), SENTINEL, dtype=np.int32)
    out[:n] = keys
    vals = None
    if values is not None:
        values = np.asarray(values, dtype=np.float32)
        v = np.zeros((cap,), dtype=np.float32)
        v[:n] = values
        vals = jnp.asarray(v)
    return Stream(keys=jnp.asarray(out), length=jnp.asarray(n, jnp.int32), values=vals)


def empty_stream(capacity: int, with_values: bool = False) -> Stream:
    cap = round_capacity(capacity)
    return Stream(
        keys=jnp.full((cap,), SENTINEL, dtype=jnp.int32),
        length=jnp.asarray(0, jnp.int32),
        values=jnp.zeros((cap,), jnp.float32) if with_values else None,
    )


@partial(jax.jit, static_argnames=("capacity",))
def stream_from_slice(memory: jax.Array, start, length, capacity: int) -> Stream:
    """S_READ: initialize a key stream from ``memory[start : start+length]``.

    ``capacity`` is static (the stream-register slot size); ``start``/``length``
    are traced. Elements past ``length`` are sentinel-padded.
    """
    cap = round_capacity(capacity)
    # ALWAYS pad by cap: dynamic_slice clamps the start when start+cap runs
    # past the array end, silently shifting the window (a stream read near
    # the end of the edge array would return its neighbor's keys).
    mem = jnp.pad(memory, (0, cap), constant_values=SENTINEL)
    window = jax.lax.dynamic_slice(mem, (start,), (cap,))
    idx = jnp.arange(cap, dtype=jnp.int32)
    keys = jnp.where(idx < length, window, SENTINEL)
    return Stream(keys=keys, length=jnp.asarray(length, jnp.int32))


def to_host(s: Stream) -> np.ndarray:
    """Return the live keys as a host numpy array (test/debug helper)."""
    n = int(s.length)
    return np.asarray(s.keys)[:n]


class StreamTable:
    """Programming-model SMT: named handles with define/active bits.

    Mirrors §IV-B semantics at the API level: registering a handle sets
    V_D=V_A=1; releasing clears V_D immediately (later references raise) and
    V_A at "retire" (here: immediately, since execution is eager/traced).
    ``max_active`` models the paper's 16 stream registers; exceeding it is an
    error, mirroring the stall-on-full behaviour.
    """

    def __init__(self, max_active: int = 16):
        self.max_active = max_active
        self._streams: dict[int, Stream] = {}
        self._next = 0

    def register(self, s: Stream) -> int:
        if len(self._streams) >= self.max_active:
            raise RuntimeError(
                f"stream table full ({self.max_active} active); "
                "S_FREE (release) a stream first")
        sid = self._next
        self._next += 1
        self._streams[sid] = s
        return sid

    def get(self, sid: int) -> Stream:
        if sid not in self._streams:
            raise KeyError(f"stream id {sid} is not defined (S_FREE'd or never read)")
        return self._streams[sid]

    def release(self, sid: int) -> None:
        if sid not in self._streams:
            raise KeyError(f"stream id {sid} is not defined")
        del self._streams[sid]

    def __len__(self) -> int:
        return len(self._streams)
