"""Backend dispatch for the stream-intersection kernels.

Public entry points used by the engine and the sparse layer. ``backend``:
  'xla'     pure-jnp reference path (fast on XLA:CPU, the semantic oracle)
  'pallas'  Pallas kernels — compiled on TPU, interpret-mode on CPU
  'auto'    pallas on TPU, xla elsewhere (interpret mode is a correctness
            vehicle, not a fast path)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.batch import batch_inter, batch_inter_count, batch_vinter
from repro.core.stream import SENTINEL
from .bitmap import bitmap_and_count_pallas, bitmap_and_count_ref, keys_to_bitmap
from .intersect import intersect_count_pallas, intersect_mark_pallas
from .svinter import vinter_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def xinter_count(a, b, bounds=None, backend: str = "auto"):
    """Batched bounded S_INTER.C."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_inter_count(a, b, bounds)
    return intersect_count_pallas(a, b, bounds, interpret=not _on_tpu())


def xinter(a, b, bounds=None, out_cap: int | None = None, backend: str = "auto"):
    """Batched bounded S_INTER -> (rows, counts).

    Pallas path: the kernel produces the match mask (the O(n·m) compare hot
    spot); compaction is a fused XLA sort over the masked keys — keeping
    data movement in the compiler's hands, compute in the kernel's."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_inter(a, b, bounds, out_cap=out_cap)
    mark = intersect_mark_pallas(a, b, bounds, interpret=not _on_tpu())
    cap = out_cap or min(a.shape[1], b.shape[1])
    masked = jnp.where(mark > 0, a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :cap]
    return rows, jnp.sum(mark, axis=1, dtype=jnp.int32)


def xvinter_mac(a_keys, a_vals, b_keys, b_vals, op: str = "mac",
                backend: str = "auto"):
    """Batched S_VINTER (SVPU): reduce over value pairs of intersected keys."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_vinter(a_keys, a_vals, b_keys, b_vals, op=op)
    return vinter_pallas(a_keys, a_vals, b_keys, b_vals, op=op,
                         interpret=not _on_tpu())


def xbitmap_count(a_words, b_words, backend: str = "auto"):
    """Bitmap-path intersection count (beyond-paper dense path)."""
    backend = _resolve(backend)
    if backend == "xla":
        return bitmap_and_count_ref(a_words, b_words)
    return bitmap_and_count_pallas(a_words, b_words, interpret=not _on_tpu())


__all__ = ["xinter", "xinter_count", "xvinter_mac", "xbitmap_count",
           "keys_to_bitmap"]
