"""Backend dispatch for the stream-intersection kernels.

Public entry points used by the engine and the sparse layer. ``backend``:
  'xla'     pure-jnp reference path (fast on XLA:CPU, the semantic oracle)
  'pallas'  Pallas kernels — compiled on TPU, interpret-mode on CPU
  'auto'    pallas on TPU, xla elsewhere (interpret mode is a correctness
            vehicle, not a fast path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.batch import (batch_compact_rows, batch_compact_scan,
                              batch_inter, batch_inter_compact,
                              batch_inter_count, batch_level_agg,
                              batch_level_compact, batch_level_count,
                              batch_member_mark, batch_sub_compact,
                              batch_sub_count, batch_vinter)
from repro.core.stream import SENTINEL
from .bitmap import bitmap_and_count_pallas, bitmap_and_count_ref, keys_to_bitmap
from .intersect import (intersect_count_pallas, intersect_expand_pallas,
                        intersect_mark_pallas, intersect_multi_agg_pallas,
                        intersect_multi_pallas)
from .svinter import vinter_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def xinter_count(a, b, bounds=None, backend: str = "auto", lbounds=None):
    """Batched bounded S_INTER.C (``lbounds`` = exclusive lower bound; both
    bounds ride the Pallas tile schedule, so out-of-range tiles never DMA)."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_inter_count(a, b, bounds, lbounds=lbounds)
    return intersect_count_pallas(a, b, bounds, interpret=not _on_tpu(),
                                  lbounds=lbounds)


def xinter(a, b, bounds=None, out_cap: int | None = None, backend: str = "auto",
           lbounds=None):
    """Batched bounded S_INTER -> (rows, counts).

    Pallas path: the kernel produces the match mask (the O(n·m) compare hot
    spot); compaction is a fused XLA sort over the masked keys — keeping
    data movement in the compiler's hands, compute in the kernel's."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_inter(a, b, bounds, out_cap=out_cap, lbounds=lbounds)
    mark = intersect_mark_pallas(a, b, bounds, interpret=not _on_tpu(),
                                 lbounds=lbounds)
    cap = out_cap or min(a.shape[1], b.shape[1])
    rows, counts = batch_compact_rows(a, mark > 0, cap)
    return rows, counts


@functools.partial(jax.jit, static_argnames=("out_cap", "out_items", "interpret"))
def _xinter_compact_pallas(a, b, bounds, out_cap: int, out_items: int,
                           interpret: bool, lbounds):
    mark, counts = intersect_expand_pallas(a, b, bounds, interpret=interpret,
                                           lbounds=lbounds)
    rows, _, src, verts, total, maxc = batch_compact_scan(
        a, mark > 0, out_cap, out_items)
    return rows, counts, src, verts, total, maxc


def xinter_compact(a, b, bounds=None, out_cap: int | None = None,
                   out_items: int | None = None, backend: str = "auto",
                   lbounds=None):
    """Fused bounded S_INTER + worklist compaction, fully device-resident.

    One dispatch produces everything the next wavefront level needs:

      rows   (B, out_cap)    per-source survivor streams S_{l+1}
      counts (B,)            per-source survivor counts
      src    (out_items,)    compacted item -> source row index
      verts  (out_items,)    compacted item extension vertex (0 = padding)
      total  ()              live item count   (host-synced at level bounds)
      maxc   ()              max survivor count (sizes the next capacity)

    This replaces the engine's host ``np.nonzero`` + re-upload round-trip:
    the Pallas kernel owns the compare work, XLA owns the prefix-sum
    scatter (``batch_compact_scan`` — O(B·cap), no sort), and only two
    scalars ever cross to the host.
    """
    backend = _resolve(backend)
    cap = out_cap or min(a.shape[1], b.shape[1])
    items = out_items or a.shape[0] * cap
    if backend == "xla":
        return batch_inter_compact(a, b, bounds, cap, items, lbounds=lbounds)
    return _xinter_compact_pallas(a, b, bounds, cap, items,
                                  interpret=not _on_tpu(), lbounds=lbounds)


def xmark(a, b, backend: str = "auto"):
    """Batched membership mask: mark[i, s] = A_i[s] ∈ B_i (live slots only).

    The plan interpreter's multi-operand µop primitive: a level with several
    INTER/SUB references AND-combines one mark per reference (the §IV-F
    translation buffer issuing one stream instruction per operand pair).
    Pallas path reuses the tile-skipping mark kernel; bounds are applied by
    the caller so the same mark serves both INTER (mask) and SUB (~mask).
    """
    backend = _resolve(backend)
    if backend == "xla":
        return batch_member_mark(a, b)
    return intersect_mark_pallas(a, b, None, interpret=not _on_tpu()) > 0


def _sub_window(a, bounds, lbounds):
    """The complement's value window (lbound, bound) as a keep mask.

    SUB bounds live OUTSIDE the mark kernel: the kernel's bound operand masks
    *matches*, which is the wrong polarity for a complement (an out-of-window
    key must be dropped whether or not it matched)."""
    ub = jnp.full((a.shape[0],), SENTINEL, jnp.int32) if bounds is None \
        else jnp.asarray(bounds, jnp.int32)
    lb = jnp.full((a.shape[0],), -1, jnp.int32) if lbounds is None \
        else jnp.asarray(lbounds, jnp.int32)
    return (a != SENTINEL) & (a < ub[:, None]) & (a > lb[:, None])


def xsub_count(a, b, bounds=None, backend: str = "auto", lbounds=None):
    """Batched bounded S_SUB.C:
    counts[i] = |{k ∈ A_i \\ B_i : lbounds[i] < k < bounds[i]}|."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_sub_count(a, b, bounds, lbounds=lbounds)
    mark = intersect_mark_pallas(a, b, None, interpret=not _on_tpu())
    keep = (mark == 0) & _sub_window(a, bounds, lbounds)
    return jnp.sum(keep, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("out_cap", "out_items", "interpret"))
def _xsub_compact_pallas(a, b, bounds, out_cap: int, out_items: int,
                         interpret: bool, lbounds):
    # the mark kernel runs UNBOUNDED here (see _sub_window on polarity)
    mark = intersect_mark_pallas(a, b, None, interpret=interpret)
    keep = (mark == 0) & _sub_window(a, bounds, lbounds)
    return batch_compact_scan(a, keep, out_cap, out_items)


def xsub_compact(a, b, bounds=None, out_cap: int | None = None,
                 out_items: int | None = None, backend: str = "auto",
                 lbounds=None):
    """Fused bounded S_SUB + worklist compaction — ``xinter_compact``'s twin
    for SUB levels (induced non-edge constraints), same output contract:
    (rows, counts, src, verts, total, maxc), fully device-resident.
    """
    backend = _resolve(backend)
    cap = out_cap or a.shape[1]
    items = out_items or a.shape[0] * cap
    if backend == "xla":
        return batch_sub_compact(a, b, bounds, cap, items, lbounds=lbounds)
    return _xsub_compact_pallas(a, b, bounds, cap, items,
                                interpret=not _on_tpu(), lbounds=lbounds)


@functools.partial(jax.jit,
                   static_argnames=("pol", "out_cap", "out_items",
                                    "interpret"))
def _xlevel_compact_pallas(a, bs, pol, bounds, lbounds, excludes,
                           out_cap: int, out_items: int, interpret: bool):
    mark, _ = intersect_multi_pallas(a, bs, pol, bounds, interpret=interpret,
                                     lbounds=lbounds, excludes=excludes)
    return batch_compact_scan(a, mark > 0, out_cap, out_items)


def xlevel_count(a, bs, pol, bounds=None, backend: str = "auto",
                 lbounds=None, excludes=None):
    """Fused multi-operand level count — one dispatch for a whole
    INTER/SUB µop sequence.

    counts[i] = |{k ∈ A_i : k ∈ B^r_i ∀ INTER r, k ∉ B^r_i ∀ SUB r,
                  lbounds[i] < k < bounds[i], k ∉ excludes[i]}|

    ``bs`` is the (k, B, cap_b) operand stack (refs SENTINEL-padded to a
    common capacity), ``pol`` the static INTER-first polarity tuple — see
    ``kernels.intersect`` for the k-operand contract. ``pol = ()`` (no
    membership refs, pure window/injectivity level) is served by the XLA
    form on every backend: there is no stream work for a kernel to fuse.
    Replaces the per-ref ``xmark`` + combine loop: k mark dispatches (each
    re-reading the A-tiles) become one pass over one shared schedule.
    """
    backend = _resolve(backend)
    if backend == "xla" or not pol:
        return batch_level_count(a, bs, pol, bounds, lbounds, excludes)
    _, cnt = intersect_multi_pallas(a, bs, pol, bounds,
                                    interpret=not _on_tpu(), lbounds=lbounds,
                                    excludes=excludes)
    return cnt


def xlevel_compact(a, bs, pol, bounds=None, out_cap: int | None = None,
                   out_items: int | None = None, backend: str = "auto",
                   lbounds=None, excludes=None):
    """Fused multi-operand level + worklist compaction, device-resident.

    ``xinter_compact``'s contract — (rows, counts, src, verts, total, maxc)
    — for a level with any number of INTER/SUB references: the multi-operand
    kernel produces the conjunctive keep mask + count in one pass
    (``intersect_multi_pallas``) and its epilogue is the O(B·cap)
    prefix-sum scatter (``batch_compact_scan``), replacing k mark dispatches
    + an O(B·cap·log) masked sort."""
    backend = _resolve(backend)
    cap = out_cap or a.shape[1]
    items = out_items or a.shape[0] * cap
    if backend == "xla" or not pol:
        return batch_level_compact(a, bs, pol, bounds, lbounds, excludes,
                                   cap, items)
    return _xlevel_compact_pallas(a, bs, pol, bounds, lbounds, excludes,
                                  cap, items, interpret=not _on_tpu())


def xlevel_agg(a, bs, pol, a_vals, b_vals, scale, op: str = "sum",
               bounds=None, backend: str = "auto", lbounds=None,
               excludes=None):
    """Fused multi-operand level count + SVPU value aggregate (§IV-E) —
    (counts, vals) in ONE dispatch on the SAME tile schedule as
    ``xlevel_count``.

    Membership contract is ``xlevel_count``'s; additionally each kept slot
    carries ``a_vals * Π_{INTER r} matched_val_r * scale[row]`` and
    ``vals[i]`` reduces row i's kept slots with ``op`` ('sum'/'max'/'min';
    op identity for empty rows — callers mask with counts). ``b_vals`` is
    the (k, B, cap_b) value stack aligned with ``bs`` (0.0 where keys are
    SENTINEL; SUB refs' values ignored). ``pol = ()`` levels are served by
    the XLA form on every backend, like ``xlevel_count``.

    The point of the shared entry: the value lane rides the membership
    dispatch — a weighted query issues exactly the kernel dispatches and
    feed passes of its unweighted twin (gated in ci_gate.py --values)."""
    backend = _resolve(backend)
    if backend == "xla" or not pol:
        return batch_level_agg(a, bs, pol, a_vals, b_vals, scale, op=op,
                               bounds=bounds, lbounds=lbounds,
                               excludes=excludes)
    _, cnt, val = intersect_multi_agg_pallas(
        a, bs, pol, a_vals, b_vals, scale, op=op, bounds=bounds,
        interpret=not _on_tpu(), lbounds=lbounds, excludes=excludes)
    return cnt, val


def xvinter(a_keys, a_vals, b_keys, b_vals, op: str = "mac",
            backend: str = "auto"):
    """Batched S_VINTER (SVPU, §IV-E): per-row reduce over value pairs of
    intersected keys — the shared value-intersect entry the sparse layer
    (``sparse.spmm`` / ``sparse.ttv``) routes through.

    ``op``: 'mac' (Σ va·vb — sparse dot), 'max'/'min' (Σ of per-pair
    max/min over matches). Backend dispatch like every other entry here:
    'xla' is ``core.batch.batch_vinter``, 'pallas' is the mask-MAC kernel
    (``kernels.svinter``), parity-tested in tests/test_sparse.py."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_vinter(a_keys, a_vals, b_keys, b_vals, op=op)
    return vinter_pallas(a_keys, a_vals, b_keys, b_vals, op=op,
                         interpret=not _on_tpu())


def xvinter_mac(a_keys, a_vals, b_keys, b_vals, op: str = "mac",
                backend: str = "auto"):
    """Deprecated alias of ``xvinter`` (kept for source compatibility)."""
    return xvinter(a_keys, a_vals, b_keys, b_vals, op=op, backend=backend)


def xbitmap_count(a_words, b_words, backend: str = "auto"):
    """Bitmap-path intersection count (beyond-paper dense path)."""
    backend = _resolve(backend)
    if backend == "xla":
        return bitmap_and_count_ref(a_words, b_words)
    return bitmap_and_count_pallas(a_words, b_words, interpret=not _on_tpu())


__all__ = ["xinter", "xinter_count", "xinter_compact", "xmark", "xsub_count",
           "xsub_compact", "xlevel_count", "xlevel_compact", "xlevel_agg",
           "xvinter", "xvinter_mac", "xbitmap_count", "keys_to_bitmap"]
