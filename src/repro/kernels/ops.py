"""Backend dispatch for the stream-intersection kernels.

Public entry points used by the engine and the sparse layer. ``backend``:
  'xla'     pure-jnp reference path (fast on XLA:CPU, the semantic oracle)
  'pallas'  Pallas kernels — compiled on TPU, interpret-mode on CPU
  'auto'    pallas on TPU, xla elsewhere (interpret mode is a correctness
            vehicle, not a fast path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.batch import (batch_compact_items, batch_inter,
                              batch_inter_count, batch_member_mark,
                              batch_sub_compact, batch_sub_count,
                              batch_vinter)
from repro.core.stream import SENTINEL
from .bitmap import bitmap_and_count_pallas, bitmap_and_count_ref, keys_to_bitmap
from .intersect import (intersect_count_pallas, intersect_expand_pallas,
                        intersect_mark_pallas)
from .svinter import vinter_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def xinter_count(a, b, bounds=None, backend: str = "auto", lbounds=None):
    """Batched bounded S_INTER.C (``lbounds`` = exclusive lower bound; both
    bounds ride the Pallas tile schedule, so out-of-range tiles never DMA)."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_inter_count(a, b, bounds, lbounds=lbounds)
    return intersect_count_pallas(a, b, bounds, interpret=not _on_tpu(),
                                  lbounds=lbounds)


def xinter(a, b, bounds=None, out_cap: int | None = None, backend: str = "auto",
           lbounds=None):
    """Batched bounded S_INTER -> (rows, counts).

    Pallas path: the kernel produces the match mask (the O(n·m) compare hot
    spot); compaction is a fused XLA sort over the masked keys — keeping
    data movement in the compiler's hands, compute in the kernel's."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_inter(a, b, bounds, out_cap=out_cap, lbounds=lbounds)
    mark = intersect_mark_pallas(a, b, bounds, interpret=not _on_tpu(),
                                 lbounds=lbounds)
    cap = out_cap or min(a.shape[1], b.shape[1])
    masked = jnp.where(mark > 0, a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :cap]
    return rows, jnp.sum(mark, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("out_cap", "out_items"))
def _xinter_compact_xla(a, b, bounds, out_cap: int, out_items: int, lbounds):
    rows, counts = batch_inter(a, b, bounds, out_cap=out_cap, lbounds=lbounds)
    src, verts, total, maxc = batch_compact_items(rows, counts, out_items)
    return rows, counts, src, verts, total, maxc


@functools.partial(jax.jit, static_argnames=("out_cap", "out_items", "interpret"))
def _xinter_compact_pallas(a, b, bounds, out_cap: int, out_items: int,
                           interpret: bool, lbounds):
    mark, counts = intersect_expand_pallas(a, b, bounds, interpret=interpret,
                                           lbounds=lbounds)
    masked = jnp.where(mark > 0, a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :out_cap]
    src, verts, total, maxc = batch_compact_items(rows, counts, out_items)
    return rows, counts, src, verts, total, maxc


def xinter_compact(a, b, bounds=None, out_cap: int | None = None,
                   out_items: int | None = None, backend: str = "auto",
                   lbounds=None):
    """Fused bounded S_INTER + worklist compaction, fully device-resident.

    One dispatch produces everything the next wavefront level needs:

      rows   (B, out_cap)    per-source survivor streams S_{l+1}
      counts (B,)            per-source survivor counts
      src    (out_items,)    compacted item -> source row index
      verts  (out_items,)    compacted item extension vertex (0 = padding)
      total  ()              live item count   (host-synced at level bounds)
      maxc   ()              max survivor count (sizes the next capacity)

    This replaces the engine's host ``np.nonzero`` + re-upload round-trip:
    the Pallas kernel owns the compare work, XLA owns the masked sort /
    prefix-scatter, and only two scalars ever cross to the host.
    """
    backend = _resolve(backend)
    cap = out_cap or min(a.shape[1], b.shape[1])
    items = out_items or a.shape[0] * cap
    if backend == "xla":
        return _xinter_compact_xla(a, b, bounds, cap, items, lbounds)
    return _xinter_compact_pallas(a, b, bounds, cap, items,
                                  interpret=not _on_tpu(), lbounds=lbounds)


def xmark(a, b, backend: str = "auto"):
    """Batched membership mask: mark[i, s] = A_i[s] ∈ B_i (live slots only).

    The plan interpreter's multi-operand µop primitive: a level with several
    INTER/SUB references AND-combines one mark per reference (the §IV-F
    translation buffer issuing one stream instruction per operand pair).
    Pallas path reuses the tile-skipping mark kernel; bounds are applied by
    the caller so the same mark serves both INTER (mask) and SUB (~mask).
    """
    backend = _resolve(backend)
    if backend == "xla":
        return batch_member_mark(a, b)
    return intersect_mark_pallas(a, b, None, interpret=not _on_tpu()) > 0


def _sub_window(a, bounds, lbounds):
    """The complement's value window (lbound, bound) as a keep mask.

    SUB bounds live OUTSIDE the mark kernel: the kernel's bound operand masks
    *matches*, which is the wrong polarity for a complement (an out-of-window
    key must be dropped whether or not it matched)."""
    ub = jnp.full((a.shape[0],), SENTINEL, jnp.int32) if bounds is None \
        else jnp.asarray(bounds, jnp.int32)
    lb = jnp.full((a.shape[0],), -1, jnp.int32) if lbounds is None \
        else jnp.asarray(lbounds, jnp.int32)
    return (a != SENTINEL) & (a < ub[:, None]) & (a > lb[:, None])


def xsub_count(a, b, bounds=None, backend: str = "auto", lbounds=None):
    """Batched bounded S_SUB.C:
    counts[i] = |{k ∈ A_i \\ B_i : lbounds[i] < k < bounds[i]}|."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_sub_count(a, b, bounds, lbounds=lbounds)
    mark = intersect_mark_pallas(a, b, None, interpret=not _on_tpu())
    keep = (mark == 0) & _sub_window(a, bounds, lbounds)
    return jnp.sum(keep, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("out_cap", "out_items", "interpret"))
def _xsub_compact_pallas(a, b, bounds, out_cap: int, out_items: int,
                         interpret: bool, lbounds):
    # the mark kernel runs UNBOUNDED here (see _sub_window on polarity)
    mark = intersect_mark_pallas(a, b, None, interpret=interpret)
    keep = (mark == 0) & _sub_window(a, bounds, lbounds)
    masked = jnp.where(keep, a, SENTINEL)
    rows = jnp.sort(masked, axis=1)[:, :out_cap]
    counts = jnp.sum(keep, axis=1, dtype=jnp.int32)
    src, verts, total, maxc = batch_compact_items(rows, counts, out_items)
    return rows, counts, src, verts, total, maxc


def xsub_compact(a, b, bounds=None, out_cap: int | None = None,
                 out_items: int | None = None, backend: str = "auto",
                 lbounds=None):
    """Fused bounded S_SUB + worklist compaction — ``xinter_compact``'s twin
    for SUB levels (induced non-edge constraints), same output contract:
    (rows, counts, src, verts, total, maxc), fully device-resident.
    """
    backend = _resolve(backend)
    cap = out_cap or a.shape[1]
    items = out_items or a.shape[0] * cap
    if backend == "xla":
        return batch_sub_compact(a, b, bounds, cap, items, lbounds=lbounds)
    return _xsub_compact_pallas(a, b, bounds, cap, items,
                                interpret=not _on_tpu(), lbounds=lbounds)


def xvinter_mac(a_keys, a_vals, b_keys, b_vals, op: str = "mac",
                backend: str = "auto"):
    """Batched S_VINTER (SVPU): reduce over value pairs of intersected keys."""
    backend = _resolve(backend)
    if backend == "xla":
        return batch_vinter(a_keys, a_vals, b_keys, b_vals, op=op)
    return vinter_pallas(a_keys, a_vals, b_keys, b_vals, op=op,
                         interpret=not _on_tpu())


def xbitmap_count(a_words, b_words, backend: str = "auto"):
    """Bitmap-path intersection count (beyond-paper dense path)."""
    backend = _resolve(backend)
    if backend == "xla":
        return bitmap_and_count_ref(a_words, b_words)
    return bitmap_and_count_pallas(a_words, b_words, interpret=not _on_tpu())


__all__ = ["xinter", "xinter_count", "xinter_compact", "xmark", "xsub_count",
           "xsub_compact", "xvinter_mac", "xbitmap_count", "keys_to_bitmap"]
