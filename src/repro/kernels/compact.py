"""Segmented prefix-scatter compaction as a Pallas kernel.

The Pallas twin of ``core.batch.batch_compact_rows``: per row, an inclusive
prefix sum over the keep mask assigns each survivor its output slot, and the
scatter is realised branch-free as a one-hot gather — out[t] = Σ_j a[j] ·
[keep[j] ∧ pos[j] == t] — which maps onto the VPU/MXU (a 0/1 matrix times
the key vector) instead of a data-dependent store. O(B·cap·out_cap) compares
but O(B·cap) *data movement*, vs the masked sort's O(B·cap·log²cap) compare
network AND movement; on TPU the one-hot never leaves VMEM.

This is the compaction the fused level kernels' epilogue wants to share a
pass with (mark -> scan -> scatter without an HBM round-trip). Two
deployment notes, measured as ROADMAP follow-ons:

* the (out_cap, cap) one-hot intermediate must be tiled for rows beyond
  ~1k keys to stay inside the ~16 MB VMEM budget (carry the running prefix
  in SMEM across tiles);
* ``jnp.cumsum`` inside a kernel lowers via associative scan — fine in
  interpret mode (this container), to be profiled against the log-step
  shift-add formulation on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.stream import SENTINEL


def _compact_rows_kernel(out_cap: int, a_ref, keep_ref, out_ref, cnt_ref):
    a = a_ref[0, :]
    keep = (keep_ref[0, :] > 0) & (a != SENTINEL)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1          # survivor slots
    total = jnp.sum(keep.astype(jnp.int32))
    slot = jax.lax.broadcasted_iota(jnp.int32, (out_cap, a.shape[0]), 0)
    onehot = keep[None, :] & (pos[None, :] == slot)
    gathered = jnp.sum(jnp.where(onehot, a[None, :], 0), axis=1)
    live = jax.lax.broadcasted_iota(jnp.int32, (out_cap,), 0) < total
    out_ref[0, :] = jnp.where(live, gathered, SENTINEL)
    cnt_ref[0, 0] = total


@functools.partial(jax.jit, static_argnames=("out_cap", "interpret"))
def compact_rows_pallas(a, keep, out_cap: int, interpret: bool = True):
    """Front-pack each row's kept keys -> (rows (B, out_cap), counts (B,)).

    Bit-identical to ``core.batch.batch_compact_rows`` (tested) under the
    same monotonicity precondition: ``a`` rows sorted, ``keep`` selects.
    """
    B, cap = a.shape
    kernel = functools.partial(_compact_rows_kernel, out_cap)
    rows, cnt = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda bi: (bi, 0)),
            pl.BlockSpec((1, cap), lambda bi: (bi, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, out_cap), lambda bi: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi: (bi, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, out_cap), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ),
        interpret=interpret,
    )(a, keep.astype(jnp.int32))
    return rows, cnt[:, 0]
