"""Pure-jnp oracles for every Pallas kernel (tests assert exact agreement).

These re-export the semantic reference implementations from ``repro.core``
— the kernels must match them bit-for-bit (integer counts/masks) or to
float32 tolerance (S_VINTER reductions, whose summation order differs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.batch import batch_inter, batch_inter_count, batch_vinter
from repro.core.stream import SENTINEL
from .bitmap import bitmap_and_count_ref, keys_to_bitmap


def intersect_count_ref(a, b, bounds=None):
    return batch_inter_count(a, b, bounds)


def intersect_mark_ref(a, b, bounds=None):
    """Oracle for the mark kernel: membership mask over A slots."""
    idx = jax.vmap(jnp.searchsorted)(b, a)
    hit = jnp.take_along_axis(b, jnp.clip(idx, 0, b.shape[1] - 1), axis=1) == a
    hit &= a != SENTINEL
    if bounds is not None:
        hit &= a < jnp.asarray(bounds, jnp.int32)[:, None]
    return hit.astype(jnp.int32)


def intersect_rows_ref(a, b, bounds=None, out_cap=None):
    return batch_inter(a, b, bounds, out_cap=out_cap)


def vinter_ref(a_keys, a_vals, b_keys, b_vals, op="mac"):
    return batch_vinter(a_keys, a_vals, b_keys, b_vals, op=op)


__all__ = [
    "intersect_count_ref", "intersect_mark_ref", "intersect_rows_ref",
    "vinter_ref", "bitmap_and_count_ref", "keys_to_bitmap",
]
