"""S_VINTER as a Pallas kernel: intersect keys, MAC value pairs on the MXU.

The paper's SVPU (§IV-E) collects (val0, val1) pairs through the load queue
and feeds a scalar FMA per matched key. The TPU-native form turns the whole
tile-pair into two dense ops: with the (TA x TB) match mask M (a permutation
sub-matrix, keys being strict sets),

        Σ_matched va·vb  =  vaᵀ · M · vb

i.e. one MXU mat-vec (M·vb) and one VPU dot — the sparse MAC becomes dense
systolic work with zero gather/scatter. MAX/MIN reductions use the mask on
the VPU directly (no MXU form exists for them).

Uses the same scalar-prefetched tile-overlap schedule as intersect.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.stream import SENTINEL
from .intersect import TA, TB, tile_schedule


def _vinter_kernel(op: str, lo_ref, nv_ref, ak_ref, av_ref, bk_ref, bv_ref,
                   out_ref):
    bi, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    ak = ak_ref[0, :]
    av = av_ref[0, :]
    bk = bk_ref[0, :]
    bv = bv_ref[0, :]
    valid = ak != SENTINEL
    m = ((ak[:, None] == bk[None, :]) & valid[:, None]).astype(jnp.float32)
    if op == "mac":
        # vaᵀ·M·vb : MXU mat-vec then VPU dot
        mv = jnp.dot(m, bv[:, None], preferred_element_type=jnp.float32)[:, 0]
        contrib = jnp.sum(av * mv)
    elif op == "max":
        pair = jnp.maximum(av[:, None], bv[None, :]) * m
        contrib = jnp.sum(pair)
    else:  # min
        pair = jnp.minimum(av[:, None], bv[None, :]) * m
        contrib = jnp.sum(pair)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[0, 0] = 0.0

    @pl.when(j < nv_ref[bi, i])
    def _acc():
        out_ref[0, 0] += contrib


@functools.partial(jax.jit, static_argnames=("op", "max_visits", "interpret"))
def vinter_pallas(a_keys, a_vals, b_keys, b_vals, op: str = "mac",
                  max_visits=None, interpret: bool = True):
    """out[i] = Σ_{k ∈ A_i ∩ B_i} op(valA_i[k], valB_i[k]) — batched S_VINTER."""
    B, cap_a = a_keys.shape
    cap_b = b_keys.shape[1]
    bounds = jnp.full((B,), SENTINEL, jnp.int32)   # S_VINTER is unbounded
    lo_t, nv = tile_schedule(a_keys, b_keys, bounds)
    if max_visits is None:
        max_visits = cap_b // TB
    grid = (B, cap_a // TA, int(max_visits))
    kern = functools.partial(_vinter_kernel, op)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, TB),
                             lambda bi, i, j, lo, nv:
                             (bi, jnp.minimum(lo[bi, i] + j, cap_b // TB - 1))),
                pl.BlockSpec((1, TB),
                             lambda bi, i, j, lo, nv:
                             (bi, jnp.minimum(lo[bi, i] + j, cap_b // TB - 1))),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(lo_t, nv, a_keys, a_vals, b_keys, b_vals)
    return out[:, 0]
