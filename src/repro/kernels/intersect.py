"""Batched bounded sorted-set intersection — the IntersectX IU as a Pallas kernel.

TPU adaptation of the paper's Intersection Unit (§IV-C):

* The paper's IU walks two streams with a branchy two-pointer merge; its
  S-Cache prefetches 64-key slots because the access pattern is known. On a
  TPU there are no scalar branches worth taking: we compare whole 128-key
  VMEM tiles against each other on the VPU — an all-pairs (TA x TB) equality
  mask — which is branch-free and saturates the vector unit.

* Sorted-ness makes most tile pairs disjoint. We precompute, per (row,
  A-tile), the first overlapping B-tile and the number of overlapping
  B-tiles (one vmapped searchsorted over tile boundary keys) and feed both
  tables through *scalar prefetch*, so the grid's index_map only ever DMAs
  B-tiles that can intersect: the S-Cache prefetcher reborn as a static
  schedule. Total tile visits obey the merge bound O((|A|+|B|)/T) per row.

* Early termination (the R3 bound operand, §III-B) zeroes the visit count of
  every A-tile whose minimum exceeds the bound — whole tiles are skipped,
  the same data-movement saving the paper gets by retiring the instruction
  early — and in-tile keys >= bound are masked.

Two kernels share the schedule:
  count: Σ matches (S_INTER.C / S_SUB.C via |A|-count)
  mark:  per-A-slot match bitmask (uint8) — S_INTER materialisation is then
         a cheap XLA scan-compaction over the mask (the kernel owns the
         O(n·m) compare work; XLA owns the data movement it already fuses).

Multi-operand levels (``intersect_multi_pallas``) fuse k B-stream operands
into ONE grid pass — the §IV-F translation buffer's whole µop sequence for a
level as a single dispatch, instead of one mark kernel per INTER/SUB
reference. The k-operand contract:

  * ``bs`` is (k, B, cap_b): the k reference streams, stacked; refs gathered
    at different capacities are SENTINEL-padded to a common cap_b (padding
    keeps rows sorted, so each ref's tile schedule stays valid);
  * ``pol`` is a static length-k tuple of 1 (S_INTER: keep members) / 0
    (S_SUB: keep non-members). Polarity is folded into a per-slot weighted
    hit score — +1 per INTER hit, -(k+1) per SUB hit — so ``score ==
    #INTER refs`` iff every INTER ref matched and no SUB ref did; one int32
    accumulator replaces k boolean mask combines;
  * each ref gets its own prefetched tile schedule (lo/nv are (k, B, nA)),
    so per-ref B-tile DMA still obeys the merge bound and the R3/lb
    whole-tile skipping — one *dispatch*, k tile-schedules;
  * the bound window (lbound < key < bound), the per-row bound-0 row kill
    and the per-item injectivity ``excludes`` (B, E) are applied in the
    kernel's finalize step, which emits both the keep mask and the
    survivor count in the same pass (no second kernel for S_*.C).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.stream import SENTINEL

TA = 128  # A-tile keys (paper slot = 64 keys; we use the TPU lane width)
TB = 128  # B-tile keys


def tile_schedule(a: jax.Array, b: jax.Array, bounds: jax.Array,
                  lbounds: jax.Array | None = None):
    """Per (row, A-tile) overlap table: (lo_tile, n_visits), both (B, nA).

    lo = first B-tile containing a key >= max(tile_min, lbound+1);
    n  = #B-tiles holding keys in [that, min(tile_max, bound-1)].

    ``lbounds`` is the per-row exclusive *lower* bound (the plan's
    ``LevelOp.lb``, e.g. three-chain's b > a): A-tiles entirely <= lbound
    are skipped whole, mirroring the R3 upper-bound early termination.
    """
    cap_b = b.shape[1]
    a_lo = a[:, ::TA]                                   # (B, nA) tile minima
    a_hi = a[:, TA - 1:: TA]                            # (B, nA) tile maxima
    eff_lo = a_lo if lbounds is None else \
        jnp.maximum(a_lo, lbounds[:, None] + 1)
    lo_idx = jax.vmap(jnp.searchsorted)(b, eff_lo)
    eff_hi = jnp.minimum(a_hi, bounds[:, None] - 1)
    hi_idx = jax.vmap(lambda bb, x: jnp.searchsorted(bb, x, side="right"))(b, eff_hi)
    lo_t = (lo_idx // TB).astype(jnp.int32)
    hi_t = ((hi_idx + TB - 1) // TB).astype(jnp.int32)
    nv = jnp.maximum(hi_t - lo_t, 0)
    # whole-tile early termination: A-tile entirely >= bound, entirely
    # <= lbound, or all-sentinel
    dead = (a_lo >= jnp.minimum(bounds[:, None], SENTINEL))
    if lbounds is not None:
        dead = dead | (a_hi <= lbounds[:, None])
    nv = jnp.where(dead, 0, nv).astype(jnp.int32)
    lo_t = jnp.minimum(lo_t, max(cap_b // TB - 1, 0))
    return lo_t, nv


def _count_kernel(lo_ref, nv_ref, a_ref, b_ref, bound_ref, lbound_ref,
                  out_ref):
    bi, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    a = a_ref[0, :]
    bt = b_ref[0, :]
    bound = bound_ref[0, 0]
    valid = (a != SENTINEL) & (a < bound) & (a > lbound_ref[0, 0])
    m = (a[:, None] == bt[None, :]) & valid[:, None]
    cnt = jnp.sum(m.astype(jnp.int32))

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[0, 0] = 0

    @pl.when(j < nv_ref[bi, i])
    def _acc():
        out_ref[0, 0] += cnt


def _mark_kernel(lo_ref, nv_ref, a_ref, b_ref, bound_ref, lbound_ref, out_ref):
    bi, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    a = a_ref[0, :]
    bt = b_ref[0, :]
    bound = bound_ref[0, 0]
    valid = (a != SENTINEL) & (a < bound) & (a > lbound_ref[0, 0])
    hit = (jnp.sum(((a[:, None] == bt[None, :]) & valid[:, None])
                   .astype(jnp.int32), axis=1) > 0)

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = jnp.zeros_like(out_ref[0, :])

    @pl.when(j < nv_ref[bi, i])
    def _acc():
        out_ref[0, :] = out_ref[0, :] | hit.astype(jnp.int32)


def _expand_kernel(lo_ref, nv_ref, a_ref, b_ref, bound_ref, lbound_ref,
                   mark_ref, cnt_ref):
    """Fused mark + count: one pass over the tile schedule feeds both the
    compaction mask and the survivor count (the device expand_compact path
    needs both; issuing two kernels would double the B-tile DMA traffic)."""
    bi, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    a = a_ref[0, :]
    bt = b_ref[0, :]
    bound = bound_ref[0, 0]
    valid = (a != SENTINEL) & (a < bound) & (a > lbound_ref[0, 0])
    hit = (jnp.sum(((a[:, None] == bt[None, :]) & valid[:, None])
                   .astype(jnp.int32), axis=1) > 0)

    @pl.when(j == 0)
    def _init_mark():
        mark_ref[0, :] = jnp.zeros_like(mark_ref[0, :])

    @pl.when((i == 0) & (j == 0))
    def _init_cnt():
        cnt_ref[0, 0] = 0

    @pl.when(j < nv_ref[bi, i])
    def _acc():
        # B-rows are sorted sets: an A-slot matches in at most one B-tile,
        # so summing per-visit hits never double counts.
        mark_ref[0, :] = mark_ref[0, :] | hit.astype(jnp.int32)
        cnt_ref[0, 0] += jnp.sum(hit.astype(jnp.int32))


def _common(a, b, bounds, max_visits, lbounds=None):
    B, cap_a = a.shape
    cap_b = b.shape[1]
    assert cap_a % TA == 0 and cap_b % TB == 0, "streams are LANE-padded"
    if bounds is None:
        bounds = jnp.full((B,), SENTINEL, jnp.int32)
    bounds = jnp.asarray(bounds, jnp.int32)
    if lbounds is None:
        lbounds = jnp.full((B,), -1, jnp.int32)   # ids >= 0: no-op bound
    lbounds = jnp.asarray(lbounds, jnp.int32)
    lo_t, nv = tile_schedule(a, b, bounds, lbounds)
    if max_visits is None:
        max_visits = cap_b // TB          # static worst case (merge bound
        #                                   tightens this when known on host)
    grid = (B, cap_a // TA, int(max_visits))
    return bounds, lbounds, lo_t, nv, grid, cap_b


def _b_index(bi, i, j, lo, nv, cap_b):
    # visit lo+j, clamped (skipped steps re-point at a resident tile: no DMA)
    return (bi, jnp.minimum(lo[bi, i] + j, cap_b // TB - 1))


@functools.partial(jax.jit, static_argnames=("max_visits", "interpret"))
def intersect_count_pallas(a, b, bounds=None, max_visits=None, interpret=True,
                           lbounds=None):
    """counts[i] = |{k ∈ A_i ∩ B_i : lbounds[i] < k < bounds[i]}|
    (paper S_INTER.C; the lower bound is the beyond-paper lb operand)."""
    bounds, lbounds, lo_t, nv, grid, cap_b = _common(a, b, bounds, max_visits,
                                                     lbounds)
    out = pl.pallas_call(
        _count_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, TB),
                             lambda bi, i, j, lo, nv: _b_index(bi, i, j, lo, nv, cap_b)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(lo_t, nv, a, b, bounds.reshape(-1, 1), lbounds.reshape(-1, 1))
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("max_visits", "interpret"))
def intersect_expand_pallas(a, b, bounds=None, max_visits=None, interpret=True,
                            lbounds=None):
    """Fused S_INTER mark + count in one schedule pass -> (mark, counts).

    The device expand_compact path consumes both outputs; fusing them halves
    the B-tile DMA traffic vs running the mark and count kernels separately.
    """
    bounds, lbounds, lo_t, nv, grid, cap_b = _common(a, b, bounds, max_visits,
                                                     lbounds)
    mark, cnt = pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, TB),
                             lambda bi, i, j, lo, nv: _b_index(bi, i, j, lo, nv, cap_b)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
            ],
            out_specs=(
                pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
            ),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(a.shape, jnp.int32),
            jax.ShapeDtypeStruct((a.shape[0], 1), jnp.int32),
        ),
        interpret=interpret,
    )(lo_t, nv, a, b, bounds.reshape(-1, 1), lbounds.reshape(-1, 1))
    return mark, cnt[:, 0]


@functools.partial(jax.jit, static_argnames=("max_visits", "interpret"))
def intersect_mark_pallas(a, b, bounds=None, max_visits=None, interpret=True,
                          lbounds=None):
    """mark[i, s] = 1 iff A_i[s] ∈ B_i and lbounds[i] < A_i[s] < bounds[i].

    S_INTER materialisation = sort-compact A over this mask (ops.xinter)."""
    bounds, lbounds, lo_t, nv, grid, cap_b = _common(a, b, bounds, max_visits,
                                                     lbounds)
    out = pl.pallas_call(
        _mark_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, TB),
                             lambda bi, i, j, lo, nv: _b_index(bi, i, j, lo, nv, cap_b)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, 1), lambda bi, i, j, lo, nv: (bi, 0)),
            ],
            out_specs=pl.BlockSpec((1, TA), lambda bi, i, j, lo, nv: (bi, i)),
        ),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int32),
        interpret=interpret,
    )(lo_t, nv, a, b, bounds.reshape(-1, 1), lbounds.reshape(-1, 1))
    return out


# ---------------------------------------------------------------------------
# fused multi-operand level kernel (k B-streams per grid step)
# ---------------------------------------------------------------------------


def _multi_kernel(n_refs: int, n_inter: int, max_visits: int,
                  lo_ref, nv_ref, a_ref, b_ref, bound_ref, lbound_ref,
                  excl_ref, mark_ref, cnt_ref):
    """One level's whole µop sequence in a single pass.

    Grid (B, nA, k, max_visits): for each (row, A-tile) the k refs stream
    their scheduled B-tiles through VMEM one after another while the A-tile
    and its score accumulator stay resident. The score is a weighted hit sum
    (+1 INTER, -(k+1) SUB; sorted sets hit at most once per ref, so the sum
    never aliases): score == n_inter  <=>  all INTER refs matched, no SUB
    ref did. The final grid step folds the bound window and the injectivity
    excludes and converts the score into the 0/1 keep mask + count."""
    bi, i, r, j = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                   pl.program_id(3))
    a = a_ref[0, :]
    bt = b_ref[0, 0, :]
    hit = (jnp.sum((a[:, None] == bt[None, :]).astype(jnp.int32), axis=1) > 0)
    weight = jnp.where(r < n_inter, 1, -(n_refs + 1))

    @pl.when((r == 0) & (j == 0))
    def _init_mark():
        mark_ref[0, :] = jnp.zeros_like(mark_ref[0, :])

    @pl.when((i == 0) & (r == 0) & (j == 0))
    def _init_cnt():
        cnt_ref[0, 0] = 0

    @pl.when(j < nv_ref[r, bi, i])
    def _acc():
        mark_ref[0, :] += hit.astype(jnp.int32) * weight

    @pl.when((r == n_refs - 1) & (j == max_visits - 1))
    def _finalize():
        bound = bound_ref[0, 0]
        valid = (a != SENTINEL) & (a < bound) & (a > lbound_ref[0, 0])
        ex = excl_ref[0, :]
        valid = valid & jnp.all(a[:, None] != ex[None, :], axis=1)
        keep = valid & (mark_ref[0, :] == n_inter)
        mark_ref[0, :] = keep.astype(jnp.int32)
        cnt_ref[0, 0] += jnp.sum(keep.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("pol", "max_visits", "interpret"))
def intersect_multi_pallas(a, bs, pol, bounds=None, max_visits=None,
                           interpret=True, lbounds=None, excludes=None):
    """Fused k-operand level: conjunctive mark + count in ONE schedule pass.

    mark[i, s] = 1 iff   A_i[s] ∈ B^r_i   for every INTER ref r (pol[r]=1)
               and       A_i[s] ∉ B^r_i   for every SUB ref r  (pol[r]=0)
               and       lbounds[i] < A_i[s] < bounds[i]
               and       A_i[s] != excludes[i, e]  for every e;
    counts[i] = Σ_s mark[i, s].

    ``bs`` is the (k, B, cap_b) operand stack (see module docstring for the
    padding contract); ``pol`` the static INTER/SUB polarity tuple, which
    must be sorted INTER-first (the engine stacks refs that way; the kernel
    exploits it to derive the per-ref weight from the ref index alone).
    Replacing the per-ref ``xmark`` loop, every B-tile is DMA'd exactly once
    across the whole level instead of once per mark dispatch re-reading the
    A-tiles, and the count rides the same pass (S_*.C for free).
    """
    assert bs.ndim == 3 and bs.shape[0] == len(pol) >= 1, \
        "bs must be (k, B, cap_b) matching pol"
    assert all(p == 1 for p in pol[:sum(pol)]) \
        and all(p == 0 for p in pol[sum(pol):]), "pol must be INTER-first"
    B, cap_a = a.shape
    cap_b = bs.shape[2]
    assert cap_a % TA == 0 and cap_b % TB == 0, "streams are LANE-padded"
    if bounds is None:
        bounds = jnp.full((B,), SENTINEL, jnp.int32)
    bounds = jnp.asarray(bounds, jnp.int32)
    if lbounds is None:
        lbounds = jnp.full((B,), -1, jnp.int32)
    lbounds = jnp.asarray(lbounds, jnp.int32)
    if excludes is None:
        excludes = jnp.full((B, 1), -1, jnp.int32)   # ids >= 0: no-op
    excludes = jnp.asarray(excludes, jnp.int32)
    lo_t, nv = jax.vmap(tile_schedule, in_axes=(None, 0, None, None))(
        a, bs, bounds, lbounds)                      # (k, B, nA) each
    if max_visits is None:
        max_visits = cap_b // TB
    k = len(pol)
    grid = (B, cap_a // TA, k, int(max_visits))
    n_excl = excludes.shape[1]
    kernel = functools.partial(_multi_kernel, k, int(sum(pol)),
                               int(max_visits))
    mark, cnt = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec(
                    (1, 1, TB),
                    lambda bi, i, r, j, lo, nv: (
                        r, bi, jnp.minimum(lo[r, bi, i] + j,
                                           cap_b // TB - 1))),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, n_excl),
                             lambda bi, i, r, j, lo, nv: (bi, 0)),
            ],
            out_specs=(
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
            ),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(a.shape, jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ),
        interpret=interpret,
    )(lo_t, nv, a, bs, bounds.reshape(-1, 1), lbounds.reshape(-1, 1),
      excludes)
    return mark, cnt[:, 0]


# ---------------------------------------------------------------------------
# value-carrying multi-operand level kernel (the SVPU lane, §IV-E)
# ---------------------------------------------------------------------------

AGG_IDS = {"sum": 0, "max": 1, "min": 2}
F32_MAX = 3.4e38      # masked-reduce identities (finite: inf trips asserts)


def _multi_agg_kernel(n_refs: int, n_inter: int, max_visits: int, op_id: int,
                      lo_ref, nv_ref, a_ref, b_ref, bound_ref, lbound_ref,
                      excl_ref, aval_ref, bval_ref, scale_ref,
                      mark_ref, cnt_ref, vsum_ref, vprod_ref, val_ref):
    """``_multi_kernel`` with a value lane riding the SAME tile schedule.

    The membership side is byte-identical to ``_multi_kernel`` (same score
    accumulator, same finalize). The value side is svinter's mask-MAC
    (§IV-E): per visited tile, ``m @ bv`` recovers each A-slot's matched
    value for the current ref (sorted sets: at most one match, so the MAC
    *is* the matched value). ``vsum`` accumulates that per ref across its
    visits; at each INTER ref's last visit it folds into the running
    product ``vprod``. The finalize step multiplies in the slot's own feed
    value and the per-row prefix scale, masks by keep, and reduces into the
    per-row aggregate with the op's identity — zero extra B-tile DMA, one
    extra VPU MAC per visit."""
    bi, i, r, j = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                   pl.program_id(3))
    a = a_ref[0, :]
    bt = b_ref[0, 0, :]
    m = (a[:, None] == bt[None, :])
    hit = jnp.sum(m.astype(jnp.int32), axis=1) > 0
    weight = jnp.where(r < n_inter, 1, -(n_refs + 1))
    bv = bval_ref[0, 0, :]
    mv = jnp.dot(m.astype(jnp.float32), bv[:, None],
                 preferred_element_type=jnp.float32)[:, 0]

    @pl.when((r == 0) & (j == 0))
    def _init_mark():
        mark_ref[0, :] = jnp.zeros_like(mark_ref[0, :])
        vprod_ref[0, :] = jnp.ones_like(vprod_ref[0, :])

    @pl.when(j == 0)
    def _init_vsum():
        vsum_ref[0, :] = jnp.zeros_like(vsum_ref[0, :])

    @pl.when((i == 0) & (r == 0) & (j == 0))
    def _init_cnt():
        cnt_ref[0, 0] = 0
        val_ref[0, 0] = jnp.float32(
            0.0 if op_id == 0 else (-F32_MAX if op_id == 1 else F32_MAX))

    @pl.when(j < nv_ref[r, bi, i])
    def _acc():
        mark_ref[0, :] += hit.astype(jnp.int32) * weight
        vsum_ref[0, :] += mv

    @pl.when((r < n_inter) & (j == max_visits - 1))
    def _fold():
        vprod_ref[0, :] *= vsum_ref[0, :]

    @pl.when((r == n_refs - 1) & (j == max_visits - 1))
    def _finalize():
        bound = bound_ref[0, 0]
        valid = (a != SENTINEL) & (a < bound) & (a > lbound_ref[0, 0])
        ex = excl_ref[0, :]
        valid = valid & jnp.all(a[:, None] != ex[None, :], axis=1)
        keep = valid & (mark_ref[0, :] == n_inter)
        mark_ref[0, :] = keep.astype(jnp.int32)
        cnt_ref[0, 0] += jnp.sum(keep.astype(jnp.int32))
        contrib = aval_ref[0, :] * vprod_ref[0, :] * scale_ref[0, 0]
        if op_id == 0:
            val_ref[0, 0] += jnp.sum(jnp.where(keep, contrib, 0.0))
        elif op_id == 1:
            val_ref[0, 0] = jnp.maximum(
                val_ref[0, 0], jnp.max(jnp.where(keep, contrib, -F32_MAX)))
        else:
            val_ref[0, 0] = jnp.minimum(
                val_ref[0, 0], jnp.min(jnp.where(keep, contrib, F32_MAX)))


@functools.partial(jax.jit,
                   static_argnames=("pol", "op", "max_visits", "interpret"))
def intersect_multi_agg_pallas(a, bs, pol, a_vals, b_vals, scale, op="sum",
                               bounds=None, max_visits=None, interpret=True,
                               lbounds=None, excludes=None):
    """``intersect_multi_pallas`` + SVPU value lane -> (mark, counts, vals).

    Same k-operand membership contract (see ``intersect_multi_pallas``);
    additionally each kept slot s of row i carries the value

        a_vals[i, s] * Π_{INTER refs r} matched_val_r(i, s) * scale[i]

    and ``vals[i]`` reduces the kept slots' values with ``op`` (``sum`` /
    ``max`` / ``min``; empty rows yield the op identity — 0.0 / -3.4e38 /
    +3.4e38 — callers mask with ``counts``). ``b_vals`` is the (k, B,
    cap_b) value stack aligned with ``bs`` (SUB refs' values are ignored);
    ``scale`` is the per-row (B,) prefix product the caller folded outside
    the kernel. One dispatch, the same B-tile DMA schedule as the
    unweighted kernel — the value lane is pure VPU work on tiles already
    resident."""
    assert bs.ndim == 3 and bs.shape[0] == len(pol) >= 1, \
        "bs must be (k, B, cap_b) matching pol"
    assert all(p == 1 for p in pol[:sum(pol)]) \
        and all(p == 0 for p in pol[sum(pol):]), "pol must be INTER-first"
    assert b_vals.shape == bs.shape and a_vals.shape == a.shape
    B, cap_a = a.shape
    cap_b = bs.shape[2]
    assert cap_a % TA == 0 and cap_b % TB == 0, "streams are LANE-padded"
    if bounds is None:
        bounds = jnp.full((B,), SENTINEL, jnp.int32)
    bounds = jnp.asarray(bounds, jnp.int32)
    if lbounds is None:
        lbounds = jnp.full((B,), -1, jnp.int32)
    lbounds = jnp.asarray(lbounds, jnp.int32)
    if excludes is None:
        excludes = jnp.full((B, 1), -1, jnp.int32)
    excludes = jnp.asarray(excludes, jnp.int32)
    scale = jnp.asarray(scale, jnp.float32)
    lo_t, nv = jax.vmap(tile_schedule, in_axes=(None, 0, None, None))(
        a, bs, bounds, lbounds)
    if max_visits is None:
        max_visits = cap_b // TB
    k = len(pol)
    grid = (B, cap_a // TA, k, int(max_visits))
    n_excl = excludes.shape[1]
    kernel = functools.partial(_multi_agg_kernel, k, int(sum(pol)),
                               int(max_visits), AGG_IDS[op])

    def _b_spec(bi, i, r, j, lo, nv):
        return (r, bi, jnp.minimum(lo[r, bi, i] + j, cap_b // TB - 1))

    mark, cnt, _vs, _vp, val = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, 1, TB), _b_spec),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, n_excl),
                             lambda bi, i, r, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, 1, TB), _b_spec),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
            ],
            out_specs=(
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, TA), lambda bi, i, r, j, lo, nv: (bi, i)),
                pl.BlockSpec((1, 1), lambda bi, i, r, j, lo, nv: (bi, 0)),
            ),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(a.shape, jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct(a.shape, jnp.float32),
            jax.ShapeDtypeStruct(a.shape, jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ),
        interpret=interpret,
    )(lo_t, nv, a, bs, bounds.reshape(-1, 1), lbounds.reshape(-1, 1),
      excludes, a_vals, b_vals, scale.reshape(-1, 1))
    return mark, cnt[:, 0], val[:, 0]
