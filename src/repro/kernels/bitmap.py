"""Bitmap intersection path — a beyond-paper, TPU-only optimization.

The paper's IU merges sorted key lists; its hardware cannot exploit dense
neighborhoods. The VPU can: encode a high-degree vertex's neighbor list as
an adjacency bitmap (32 keys per int32 word), then |A ∩ B| is AND +
popcount at 32 keys/lane/op — asymptotically worse (O(V/32) regardless of
list length) but with a constant so small it wins whenever both lists are
dense in the key space. ``benchmarks/bench_kernels.py`` sweeps the
merge-vs-bitmap crossover density.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.stream import SENTINEL

TW = 256  # words per tile (256 * 4B = 1KB per row-tile; lane-aligned)


def keys_to_bitmap(keys: jax.Array, num_bits: int) -> jax.Array:
    """(B, cap) sentinel-padded sorted keys -> (B, W) int32 bitmap words.

    Keys are unique per row, so every (word, bit) pair is unique and the
    scatter-ADD of disjoint single-bit values is exactly bitwise OR.
    """
    words = -(-num_bits // 32)
    w_pad = -(-words // TW) * TW
    valid = keys != SENTINEL
    word_idx = jnp.where(valid, keys // 32, 0).astype(jnp.int32)
    bit = jnp.where(valid,
                    jnp.left_shift(jnp.int32(1), (keys % 32).astype(jnp.int32)),
                    0).astype(jnp.int32)
    out = jnp.zeros(keys.shape[:-1] + (w_pad,), jnp.int32)
    row = jnp.arange(keys.shape[0])[:, None]
    return out.at[row, word_idx].add(bit)


def _and_count_kernel(a_ref, b_ref, out_ref):
    j = pl.program_id(1)
    anded = a_ref[0, :] & b_ref[0, :]
    cnt = jnp.sum(jax.lax.population_count(anded))

    @pl.when(j == 0)
    def _init():
        out_ref[0, 0] = 0

    out_ref[0, 0] += cnt


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_and_count_pallas(a_words: jax.Array, b_words: jax.Array,
                            interpret: bool = True) -> jax.Array:
    """counts[i] = popcount(A_i & B_i) over int32 word rows."""
    B, W = a_words.shape
    assert b_words.shape == (B, W) and W % TW == 0
    out = pl.pallas_call(
        _and_count_kernel,
        grid=(B, W // TW),
        in_specs=[
            pl.BlockSpec((1, TW), lambda i, j: (i, j)),
            pl.BlockSpec((1, TW), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(a_words, b_words)
    return out[:, 0]


def bitmap_and_count_ref(a_words: jax.Array, b_words: jax.Array) -> jax.Array:
    """Pure-jnp oracle."""
    return jnp.sum(jax.lax.population_count(a_words & b_words), axis=1)
