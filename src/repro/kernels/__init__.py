"""Pallas TPU kernels for the paper's compute hot spot: sorted-set intersection.

  intersect.py  batched bounded intersection (count / match-mark) with the
                scalar-prefetched tile-overlap schedule (the S-Cache
                prefetcher as a static schedule)
  svinter.py    S_VINTER: intersect keys then MAC the value pairs on the MXU
  bitmap.py     beyond-paper bitmap path: AND + popcount for dense rows
  ops.py        backend dispatch (pallas on TPU, interpret on CPU, xla ref)
  ref.py        pure-jnp oracles
"""
from .ops import xinter, xinter_count, xvinter_mac, xbitmap_count

__all__ = ["xinter", "xinter_count", "xvinter_mac", "xbitmap_count"]
