import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces the compiled artifact's
  memory_analysis()  -> bytes/device (proves the cell fits 16 GB/chip)
  cost_analysis()    -> HLO FLOPs / bytes (per device under SPMD)
  compiled HLO text  -> collective bytes by op
plus a *calibration lower* (2 units, scan unrolled) that disentangles the
layer-scan body cost from the outside cost — XLA cost analysis counts a
while body once regardless of trip count (verified in tests/test_roofline):

    body_sum = X(unroll2) - X(scan)
    total(G) = X(scan) + (G - 1) * body_sum

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch rwkv6-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, SHAPES, get_arch
from repro.configs.common import ArchSpec
from repro.distributed.sharding import (DEFAULT_RULES, FSDP_RULES, Axes,
                                        mesh_context, named_sharding,
                                        shard_params_tree)
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models.transformer import Model, shapes_and_axes
from repro.roofline.analysis import (V5E, collective_bytes, model_flops_6nd,
                                     parse_cost, roofline_report)
from repro.train.optimizer import OptConfig, adamw_init, opt_state_shardings
from repro.train.train_step import (batch_shardings, make_train_step)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _rules_for(spec: ArchSpec):
    return FSDP_RULES if spec.rules == "fsdp" else DEFAULT_RULES


def _cache_shapes_and_axes(model: Model, batch: int, max_len: int):
    box = {}

    def build():
        c, a = model.init_cache(batch, max_len)
        box["axes"] = a
        if model.cfg.first_dense:
            d, da = model.init_dense_cache(batch, max_len)
            c["dense"] = d
            box["axes"]["dense"] = da
        return c

    shapes = jax.eval_shape(build)
    return shapes, box["axes"]


def _active_params(model: Model) -> tuple[int, int]:
    """(total, active) parameter counts (active discounts unrouted experts,
    identified by the 'experts' logical axis)."""
    import math as _math
    from repro.distributed.sharding import is_axes
    cfg = model.cfg
    shapes, axes = shapes_and_axes(model)
    flat_s = jax.tree.leaves(shapes)
    flat_a = jax.tree.leaves(axes, is_leaf=is_axes)
    total = sum(_math.prod(s.shape) for s in flat_s)
    if cfg.moe is None:
        return total, total
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    expert = sum(_math.prod(s.shape) for s, a in zip(flat_s, flat_a)
                 if "experts" in a)
    active = total - int(expert * (1 - k / E))
    return total, active


def _measure(lowered, label: str):
    t0 = time.perf_counter()
    compiled = lowered.compile()
    dt = time.perf_counter() - t0
    ca = compiled.cost_analysis()
    cost = parse_cost(ca[0] if isinstance(ca, (list, tuple)) else ca)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    mem["peak_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                         + mem["temp_bytes"] - mem["alias_bytes"])
    coll = collective_bytes(compiled.as_text())
    return {"label": label, "compile_s": dt, "cost": cost, "memory": mem,
            "collectives": coll}


def _combine(scan_m: dict, unroll2_m: dict, units: int) -> dict:
    """total(G) = X(scan) + (G-1) * (X(unroll2) - X(scan)) per metric."""
    def comb(a, b):
        return a + (units - 1) * max(b - a, 0.0)

    flops = comb(scan_m["cost"]["flops"], unroll2_m["cost"]["flops"])
    byts = comb(scan_m["cost"]["bytes"], unroll2_m["cost"]["bytes"])
    coll = {}
    keys = set(scan_m["collectives"]) | set(unroll2_m["collectives"])
    for k in keys:
        coll[k] = int(comb(scan_m["collectives"].get(k, 0),
                           unroll2_m["collectives"].get(k, 0)))
    return {"flops": flops, "bytes": byts, "collectives": coll}


def _calib_config(cfg, kind: str):
    """2-unit unrolled twin of a config (same shapes per layer)."""
    upd = dict(num_layers=cfg.first_dense + 2 * len(cfg.unit),
               unroll_units=True)
    if cfg.encoder_layers:
        upd["encoder_layers"] = 2
    return dataclasses.replace(cfg, **upd)


# ---------------------------------------------------------------------------
# per-kind lowering
# ---------------------------------------------------------------------------


def _bf16(shapes):
    """Params ride bf16 on the wire/HBM; the fp32 master lives (ZeRO-
    sharded) in the optimizer state."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, shapes)


def lower_train(spec: ArchSpec, model: Model, mesh, rules, shape_spec):
    opt_cfg = OptConfig(state_bits=spec.opt_bits, master_weights=True)
    shapes, axes = shapes_and_axes(model)
    shapes = _bf16(shapes)
    p_shard = shard_params_tree(shapes, axes, mesh, rules)
    o_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), shapes)
    o_shard = opt_state_shardings(shapes, axes, mesh, rules, opt_cfg)
    batch_spec = spec.input_specs_for(model.cfg, shape_spec)
    b_shard = batch_shardings(batch_spec, mesh, rules)
    rep = named_sharding(Axes(), mesh, rules)
    fn = make_train_step(model, mesh, rules, opt_cfg)
    jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard, rep),
                     out_shardings=(p_shard, o_shard,
                                    {"loss": rep, "gnorm": rep, "lr": rep}),
                     donate_argnums=(0, 1))
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(shapes, o_shapes, batch_spec, step)


def lower_prefill(spec: ArchSpec, model: Model, mesh, rules, shape_spec):
    shapes, axes = shapes_and_axes(model)
    shapes = _bf16(shapes)
    p_shard = shard_params_tree(shapes, axes, mesh, rules)
    batch_spec = spec.input_specs_for(model.cfg, shape_spec)
    batch_spec.pop("targets", None)
    b_shard = batch_shardings(batch_spec, mesh, rules)

    def fwd(params, batch):
        with mesh_context(mesh, rules):
            logits, _ = model.apply(params, batch)
            return logits[:, -1]

    jitted = jax.jit(fwd, in_shardings=(p_shard, b_shard))
    return jitted.lower(shapes, batch_spec)


def lower_decode(spec: ArchSpec, model: Model, mesh, rules, shape_spec):
    cfg = model.cfg
    B, S = shape_spec["batch"], shape_spec["seq"]
    shapes, axes = shapes_and_axes(model)
    shapes = _bf16(shapes)
    p_shard = shard_params_tree(shapes, axes, mesh, rules)
    c_shapes, c_axes = _cache_shapes_and_axes(model, B, S)
    c_shard = shard_params_tree(c_shapes, c_axes, mesh, rules)
    rep = named_sharding(Axes(), mesh, rules)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = named_sharding(Axes("batch", None), mesh, rules, (B, 1))
    args = [shapes, tok, jax.ShapeDtypeStruct((), jnp.int32), c_shapes]
    shards = [p_shard, tok_shard, rep, c_shard]
    if cfg.encoder_layers:
        se = min(4096, S)
        enc = jax.ShapeDtypeStruct((B, se, cfg.d_model), cfg.dtype)
        encp = jax.ShapeDtypeStruct((B, se), jnp.int32)
        args += [enc, encp]
        shards += [named_sharding(Axes("batch", "seq", "embed"), mesh, rules,
                                  (B, se, cfg.d_model)),
                   named_sharding(Axes("batch", "seq"), mesh, rules, (B, se))]

    def step(params, token, pos, caches, *enc_args):
        with mesh_context(mesh, rules):
            return model.decode_step(params, token, pos, caches, *enc_args)

    logit_shard = named_sharding(Axes("batch", None, "vocab"), mesh, rules,
                                 (B, 1, cfg.vocab_size))
    jitted = jax.jit(step,
                     in_shardings=tuple(shards),
                     out_shardings=(logit_shard, c_shard),
                     donate_argnums=(3,))
    return jitted.lower(*args)


LOWER = {"train": lower_train, "prefill": lower_prefill,
         "decode": lower_decode}


# ---------------------------------------------------------------------------
# cell driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR, calibrate: bool = True,
             overrides: dict | None = None) -> dict:
    spec = get_arch(arch)
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    reason = spec.skips.get(shape_name)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "time": time.time()}
    if reason:
        record.update({"status": "skipped", "reason": reason})
        json.dump(record, open(path, "w"), indent=1)
        return record

    sh = dict(SHAPES[shape_name])
    sh["name"] = shape_name
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = _rules_for(spec)
    cfg = spec.config
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = Model(cfg)
    kind = sh["kind"]
    try:
        t0 = time.perf_counter()
        lowered = LOWER[kind](spec, model, mesh, rules, sh)
        lower_s = time.perf_counter() - t0
        scan_m = _measure(lowered, "scan")
        chips = mesh_chips(mesh)
        units = cfg.num_units
        combined = {"flops": scan_m["cost"]["flops"],
                    "bytes": scan_m["cost"]["bytes"],
                    "collectives": scan_m["collectives"]}
        calib_m = None
        if calibrate and units > 2:
            calib_model = Model(_calib_config(cfg, kind))
            lowered2 = LOWER[kind](spec, calib_model, mesh, rules, sh)
            calib_m = _measure(lowered2, "unroll2")
            combined = _combine(scan_m, calib_m, units)
        total, active = _active_params(model)
        if kind == "train":
            tokens = sh["batch"] * sh["seq"]
            mf = model_flops_6nd(total, tokens, active)
        elif kind == "prefill":
            tokens = sh["batch"] * sh["seq"]
            mf = model_flops_6nd(total, tokens, active) / 3.0   # fwd only
        else:
            mf = model_flops_6nd(total, sh["batch"], active) / 3.0
        roof = roofline_report(combined["flops"], combined["bytes"],
                               combined["collectives"], chips,
                               model_flops=mf)
        record.update({
            "status": "ok", "kind": kind, "chips": chips,
            "lower_s": lower_s,
            "params_total": total, "params_active": active,
            "units": units,
            "scan_measure": scan_m, "calib_measure": calib_m,
            "combined": combined, "roofline": roof,
            "fits_hbm": scan_m["memory"]["peak_bytes"] < V5E.hbm_bytes,
        })
    except Exception as e:  # record the failure — dry-run bugs are bugs
        record.update({"status": "error", "error": repr(e),
                       "trace": traceback.format_exc()})
    json.dump(record, open(path, "w"), indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    from repro.launch.cli import add_out_args
    add_out_args(ap, default_out=OUT_DIR)
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    for a, s in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            path = os.path.join(args.out, f"{a}__{s}__{mesh_name}.json")
            if not args.force and os.path.exists(path):
                prev = json.load(open(path))
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {a} {s} {mesh_name}: cached "
                          f"({prev['status']})", flush=True)
                    continue
            r = run_cell(a, s, mp, args.out, calibrate=not args.no_calibrate)
            status = r.get("status")
            extra = ""
            if status == "ok":
                roof = r["roofline"]
                extra = (f" dominant={roof['dominant']} "
                         f"peakGB={r['scan_measure']['memory']['peak_bytes']/1e9:.2f} "
                         f"fit={r['fits_hbm']}")
            elif status == "error":
                extra = " " + r["error"][:120]
            print(f"[dryrun] {a} {s} {'multi' if mp else 'single'}: "
                  f"{status}{extra}", flush=True)


if __name__ == "__main__":
    main()
