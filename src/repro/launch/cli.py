"""Shared CLI flag builders for the launch drivers.

``mine.py``, ``serve.py`` and ``dryrun.py`` historically each declared
their own copies of the common flags; this module is the single place
those flags are defined so spellings, defaults and help text cannot
drift between entry points. Each builder adds one coherent flag group to
an ``argparse`` parser; the resulting namespace is what
``MinerConfig.from_args`` consumes (``--shards`` -> ``mesh``,
``--trace`` -> tracing-enabled ``Telemetry``, ``--chunk`` -> ``chunk``).
"""
from __future__ import annotations

import argparse

__all__ = ["add_graph_args", "add_out_args", "add_service_args",
           "add_session_args"]


def add_graph_args(ap: argparse.ArgumentParser, dataset_flag: str = "--dataset",
                   default: str = "email-eu-core", choices=None,
                   help: str | None = None) -> None:  # noqa: A002
    """Dataset selection: ``--dataset`` (or an alias like serve's
    ``--mine``, which doubles as its mode switch) + ``--scale``."""
    ap.add_argument(dataset_flag, default=default, choices=choices,
                    help=help or "dataset name (repro.graph.datasets)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="subsample the dataset to this fraction")


def add_session_args(ap: argparse.ArgumentParser) -> None:
    """Session construction + observability flags, shared by every driver
    that builds a ``Miner`` (consumed by ``MinerConfig.from_args``)."""
    ap.add_argument("--shards", type=int, default=0,
                    help="mine data-parallel over an N-way device mesh "
                         "(on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="wave chunk size (default: auto-sized)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="enable span tracing and write a Chrome-trace "
                         "(Perfetto) JSON of the run's span tree")
    ap.add_argument("--session-stats", action="store_true",
                    help="print session/service cache+retrace counters and "
                         "the Prometheus-style metrics snapshot")


def add_service_args(ap: argparse.ArgumentParser) -> None:
    """Mining-service load flags (``serve.py``): traffic shape and the
    per-request deadline for the admission/timeout path."""
    ap.add_argument("--qps", type=float, default=0.0,
                    help="run the threaded load generator at this target "
                         "qps instead of deterministic rounds (0 = rounds)")
    ap.add_argument("--clients", type=int, default=4,
                    help="load-generator client threads")
    ap.add_argument("--requests", type=int, default=48,
                    help="total load-generator requests")
    ap.add_argument("--timeout-ms", type=float, default=0.0,
                    help="per-request deadline in milliseconds "
                         "(0 = no deadline); expired requests complete "
                         "with the typed timeout rejection")


def add_out_args(ap: argparse.ArgumentParser, default_out: str) -> None:
    """Artifact output flags (``dryrun.py``-style drivers)."""
    ap.add_argument("--out", default=default_out,
                    help="artifact output directory")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells whose artifact already exists")
