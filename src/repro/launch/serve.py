"""Serving driver: batched greedy decoding against the KV/state caches,
or a concurrent graph-mining service (``repro.serving``).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --tokens 32
  PYTHONPATH=src python -m repro.launch.serve --mine email-eu-core --rounds 4
  PYTHONPATH=src python -m repro.launch.serve --mine email-eu-core \\
      --qps 50 --clients 4 --timeout-ms 2000

``--mine`` is a thin driver over ``serving.MiningService``: the app mix
(T/TC/TT/4C + the 4-motif batch) is submitted as CONCURRENT requests and
each round's tick merges them into shared forest schedules across
requests (fused feed passes < sum of the requests' independent
schedules). Round mode is deterministic — steady-state rounds must be
bit-identical with 0 retraces; ``--qps`` switches to the threaded load
generator and reports p50/p99/qps. ``--shards N`` adds a mesh-sharded
worker class serving the heavy motif batch, mixed with the unsharded
default class in one pool.

Observability (repro.obs): ``--session-stats`` appends the service's
Prometheus-style metrics snapshot (the scrape-endpoint text a real server
would expose); ``--trace out.json`` span-traces the service's ticks and
writes the Chrome-trace/Perfetto JSON on exit.
"""
from __future__ import annotations

import argparse
import time


def serve_mining(args) -> None:
    """Serve the app mix through one ``MiningService``.

    Round mode (default): each round submits the mix as concurrent
    requests and ticks once — counts must repeat bit-identically and
    steady-state rounds must retrace nothing. ``--qps`` mode drives the
    threaded ``LoadGenerator`` instead."""
    from repro.graph import get_dataset
    from repro.graph.datasets import dataset_stats
    from repro.mining import FOUR_MOTIF_SHAPES, MinerConfig
    from repro.obs import Telemetry
    from repro.serving import LoadGenerator, MiningService, WorkerSpec

    if args.rounds < 1:
        raise SystemExit("[serve] --rounds must be >= 1")
    g = get_dataset(args.mine, scale=args.scale)
    print(f"[serve] mining {args.mine} x{args.scale}: {dataset_stats(g)}")
    telemetry = Telemetry(enabled=bool(args.trace))
    # worker pool: an unsharded default class; --shards N adds a
    # mesh-sharded class that serves the heavy motif batch
    specs = [WorkerSpec("default", MinerConfig.from_args(args, mesh=None))]
    bulk = "default"
    if args.shards > 1:
        specs.append(WorkerSpec("bulk", MinerConfig.from_args(args)))
        bulk = "bulk"
    svc = MiningService(
        g, workers=tuple(specs), telemetry=telemetry, cache_results=False,
        timeout_s=(args.timeout_ms / 1e3) if args.timeout_ms else None)
    for spec in specs:
        w = svc.pool.worker(spec.traffic_class)
        if w.mesh is not None:
            print(f"[serve] worker {spec.traffic_class!r}: mesh "
                  f"{dict(w.mesh.shape)}")
    # the request mix: four single-pattern requests + the 4-motif batch,
    # heterogeneous on purpose — the tick merges them across requests
    motif_names = list(FOUR_MOTIF_SHAPES)
    requests = [("triangle",), ("three-chain",), ("tailed-triangle",),
                ("4-clique",), tuple(motif_names)]
    classes = ["default"] * 4 + [bulk]
    labels = ["T", "TC", "TT", "4C"] + motif_names
    queries_per_round = len(requests)

    if args.qps:
        lg = LoadGenerator(
            svc, list(zip(requests, classes)), requests=args.requests,
            clients=args.clients, qps=args.qps,
            timeout_s=(args.timeout_ms / 1e3) if args.timeout_ms else None)
        res = lg.run()
        fp = res["feed_passes"]
        print(f"[serve] load: {res['completed']}/{res['requests']} done "
              f"({res['rejected']} rejected, {res['timeouts']} timed out) "
              f"in {res['wall_s']:.2f}s = {res['qps']:.1f} queries/s")
        print(f"[serve] latency: p50 {res['p50_s'] * 1e3:.1f}ms, "
              f"p99 {res['p99_s'] * 1e3:.1f}ms")
        print(f"[serve] sharing: {fp['fused']} fused feed passes vs "
              f"{fp['independent']} independent (cross-request batching)")
    else:
        first = None
        warm_retraces = steady = 0.0
        fp_round = None
        for r in range(args.rounds):
            before = svc.stats["retraces"]
            t0 = time.perf_counter()
            handles = [svc.submit(qs, traffic_class=tc)
                       for qs, tc in zip(requests, classes)]
            tick = svc.tick()
            flat = [v for h in handles for v in h.result(0)]
            res = dict(zip(labels, flat))
            dt = time.perf_counter() - t0
            retraces = svc.stats["retraces"] - before
            fp_round = tick["feed_passes"]
            if first is None:
                first, warm_retraces = res, retraces
            else:
                assert res == first, (res, first)
                assert retraces == 0, \
                    "steady-state round rebuilt an executable"
                steady += dt
            print(f"[serve] round {r}: {dt:.3f}s, "
                  f"{tick['requests']} requests merged, {retraces} retraces"
                  + ("  (warm-up: schedules + traces)" if r == 0 else ""))
        assert fp_round["fused"] < fp_round["independent"], fp_round
        print(f"[serve] sharing: {fp_round['fused']} fused feed passes vs "
              f"{fp_round['independent']} independent per tick "
              f"(cross-request batching)")
        if args.rounds > 1:
            per = steady / (args.rounds - 1)
            print(f"[serve] steady state: {per:.3f}s/round = "
                  f"{queries_per_round / max(per, 1e-9):.1f} queries/s, "
                  f"0 retraces (resident sessions + executable caches; "
                  f"warm-up traced {warm_retraces})")
        print(f"[serve] counts sample: T={first['T']} 4C={first['4C']}")
    st = svc.stats
    print(f"[serve] service: {st['service_requests']} requests "
          f"({st['service_queries']} queries) over {st['service_ticks']} "
          f"ticks, workers {sorted(st['workers'])}, "
          f"{st['retraces']} traces total")
    if args.trace:
        path = svc.write_trace(args.trace)
        print(f"[serve] trace: "
              f"{sum(1 for _ in telemetry.tracer.spans())} spans -> {path}")
    if args.session_stats:
        print("[serve] metrics:")
        print(svc.prometheus_text(), end="")


def main(argv=None):
    from repro.launch.cli import add_graph_args, add_service_args, \
        add_session_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    add_graph_args(ap, dataset_flag="--mine", default="",
                   help="serve the mining app mix through a MiningService "
                        "on this dataset instead of LLM decoding")
    ap.add_argument("--rounds", type=int, default=3,
                    help="with --mine: deterministic serving rounds")
    add_session_args(ap)
    add_service_args(ap)
    args = ap.parse_args(argv)

    if args.mine:
        serve_mining(args)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCH_NAMES, get_arch
    from repro.distributed.sharding import DEFAULT_RULES, mesh_context
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import Model

    if args.arch not in ARCH_NAMES:
        ap.error(f"--arch must be one of {ARCH_NAMES}")

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    model = Model(cfg)
    mesh = make_host_mesh()
    params, _ = model.init(jax.random.PRNGKey(0))
    caches, _ = model.init_cache(args.batch, args.max_len)
    if cfg.first_dense:
        caches["dense"] = model.init_dense_cache(args.batch, args.max_len)[0]
    enc = encp = None
    if cfg.encoder_layers:
        frames = jnp.zeros((args.batch, 16, cfg.d_model), jnp.float32)
        with mesh_context(mesh, DEFAULT_RULES):
            enc, encp = model._encode(params, {"frames": frames})

    @jax.jit
    def step(params, tok, pos, caches):
        with mesh_context(mesh, DEFAULT_RULES):
            if enc is not None:
                return model.decode_step(params, tok, pos, caches, enc, encp)
            return model.decode_step(params, tok, pos, caches)

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, caches = step(params, tok, jnp.int32(i), caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)[..., 0][:, None]
        out.append(tok)
    dt = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"[serve] {args.arch}: {args.batch}x{args.tokens} tokens in "
          f"{dt:.2f}s = {args.batch*args.tokens/dt:.1f} tok/s")
    print("[serve] sample:", seqs[0, :16].tolist())


if __name__ == "__main__":
    main()
