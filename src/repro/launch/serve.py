"""Serving driver: batched greedy decoding against the KV/state caches,
or a graph-mining query service against a resident ``Miner`` session.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --tokens 32
  PYTHONPATH=src python -m repro.launch.serve --mine email-eu-core --rounds 4

``--mine`` serves the full mining app mix (T/TC/TT/4C + the fused 4-motif
batch) from ONE ``mining.session.Miner``: the graph is staged to device
once, schedules and executables are derived on the first round, and every
later round is pure cache-hit execution — the serving story the session
API exists for. Reports per-round latency, steady-state queries/s and the
retrace counter (0 after warm-up).

Observability (repro.obs): ``--session-stats`` appends the session's
Prometheus-style metrics snapshot (the scrape-endpoint text a real server
would expose); ``--trace out.json`` span-traces every round and writes
the Chrome-trace/Perfetto JSON on exit.
"""
from __future__ import annotations

import argparse
import time


def serve_mining(dataset: str, scale: float, rounds: int,
                 shards: int = 0, trace: str = "",
                 session_stats: bool = False) -> None:
    """Serve ``rounds`` passes of the app mix from one resident session.

    ``shards > 1`` serves from a mesh-sharded session (data-parallel
    wavefronts, ``mining.shard``): the 0-retrace steady-state contract is
    identical — sharded executables live in the same session cache."""
    from repro.graph import get_dataset
    from repro.graph.datasets import dataset_stats
    from repro.mining.plan import FOUR_MOTIF_SHAPES
    from repro.mining.session import Miner
    from repro.obs import Telemetry

    if rounds < 1:
        raise SystemExit("[serve] --rounds must be >= 1")
    g = get_dataset(dataset, scale=scale)
    print(f"[serve] mining {dataset} x{scale}: {dataset_stats(g)}")
    telemetry = Telemetry(enabled=bool(trace))
    miner = Miner(g, mesh=shards if shards > 1 else None,
                  telemetry=telemetry)
    if miner.mesh is not None:
        print(f"[serve] mesh: {dict(miner.mesh.shape)}")
    motif_names = list(FOUR_MOTIF_SHAPES)

    def mix() -> dict:
        out = {"T": miner.count("triangle"),
               "TC": miner.count("three-chain"),
               "TT": miner.count("tailed-triangle"),
               "4C": miner.count("4-clique")}
        out.update(zip(motif_names, miner.count_many(motif_names)))
        return out

    first = None
    queries_per_round = 5                  # 4 single counts + 1 fused batch
    warm_retraces = steady = 0.0
    for r in range(rounds):
        before = miner.stats["retraces"]
        t0 = time.perf_counter()
        res = mix()
        dt = time.perf_counter() - t0
        retraces = miner.stats["retraces"] - before
        if first is None:
            first, warm_retraces = res, retraces
        else:
            assert res == first, (res, first)
            assert retraces == 0, "steady-state round rebuilt an executable"
            steady += dt
        print(f"[serve] round {r}: {dt:.3f}s, {retraces} retraces"
              + ("  (warm-up: schedules + traces)" if r == 0 else ""))
    if rounds > 1:
        per = steady / (rounds - 1)
        print(f"[serve] steady state: {per:.3f}s/round = "
              f"{queries_per_round / max(per, 1e-9):.1f} queries/s, "
              f"0 retraces (session-resident graph + executable cache; "
              f"warm-up traced {warm_retraces})")
    st = miner.stats
    print(f"[serve] session: {st['queries']} queries, exec cache "
          f"{st['exec_cache']['hits']} hits / {st['exec_cache']['misses']} "
          f"traces, counts sample: T={first['T']} 4C={first['4C']}")
    if trace:
        path = telemetry.write_trace(trace)
        print(f"[serve] trace: "
              f"{sum(1 for _ in telemetry.tracer.spans())} spans -> {path}")
    if session_stats:
        print("[serve] metrics:")
        print(telemetry.prometheus_text(), end="")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mine", default="",
                    help="serve the mining app mix from one Miner session "
                         "on this dataset instead of LLM decoding")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--shards", type=int, default=0,
                    help="with --mine: serve from an N-way mesh-sharded "
                         "session")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="with --mine: span-trace the rounds and write a "
                         "Chrome-trace (Perfetto) JSON")
    ap.add_argument("--session-stats", action="store_true",
                    help="with --mine: print the Prometheus-style metrics "
                         "snapshot after serving")
    args = ap.parse_args(argv)

    if args.mine:
        serve_mining(args.mine, args.scale, args.rounds, args.shards,
                     trace=args.trace, session_stats=args.session_stats)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCH_NAMES, get_arch
    from repro.distributed.sharding import DEFAULT_RULES, mesh_context
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import Model

    if args.arch not in ARCH_NAMES:
        ap.error(f"--arch must be one of {ARCH_NAMES}")

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    model = Model(cfg)
    mesh = make_host_mesh()
    params, _ = model.init(jax.random.PRNGKey(0))
    caches, _ = model.init_cache(args.batch, args.max_len)
    if cfg.first_dense:
        caches["dense"] = model.init_dense_cache(args.batch, args.max_len)[0]
    enc = encp = None
    if cfg.encoder_layers:
        frames = jnp.zeros((args.batch, 16, cfg.d_model), jnp.float32)
        with mesh_context(mesh, DEFAULT_RULES):
            enc, encp = model._encode(params, {"frames": frames})

    @jax.jit
    def step(params, tok, pos, caches):
        with mesh_context(mesh, DEFAULT_RULES):
            if enc is not None:
                return model.decode_step(params, tok, pos, caches, enc, encp)
            return model.decode_step(params, tok, pos, caches)

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, caches = step(params, tok, jnp.int32(i), caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)[..., 0][:, None]
        out.append(tok)
    dt = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"[serve] {args.arch}: {args.batch}x{args.tokens} tokens in "
          f"{dt:.2f}s = {args.batch*args.tokens/dt:.1f} tok/s")
    print("[serve] sample:", seqs[0, :16].tolist())


if __name__ == "__main__":
    main()
