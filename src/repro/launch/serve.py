"""Serving driver: batched greedy decoding against the KV/state caches.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_arch
from repro.distributed.sharding import DEFAULT_RULES, mesh_context
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    model = Model(cfg)
    mesh = make_host_mesh()
    params, _ = model.init(jax.random.PRNGKey(0))
    caches, _ = model.init_cache(args.batch, args.max_len)
    if cfg.first_dense:
        caches["dense"] = model.init_dense_cache(args.batch, args.max_len)[0]
    enc = encp = None
    if cfg.encoder_layers:
        frames = jnp.zeros((args.batch, 16, cfg.d_model), jnp.float32)
        with mesh_context(mesh, DEFAULT_RULES):
            enc, encp = model._encode(params, {"frames": frames})

    @jax.jit
    def step(params, tok, pos, caches):
        with mesh_context(mesh, DEFAULT_RULES):
            if enc is not None:
                return model.decode_step(params, tok, pos, caches, enc, encp)
            return model.decode_step(params, tok, pos, caches)

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens):
        logits, caches = step(params, tok, jnp.int32(i), caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)[..., 0][:, None]
        out.append(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"[serve] {args.arch}: {args.batch}x{args.tokens} tokens in "
          f"{dt:.2f}s = {args.batch*args.tokens/dt:.1f} tok/s")
    print("[serve] sample:", seqs[0, :16].tolist())


if __name__ == "__main__":
    main()
