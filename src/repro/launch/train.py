"""Training driver: real steps on the host mesh (CPU here, pods on TPU).

Demonstrates the full production loop on any --arch (smoke config by
default on CPU): sharded init, pjit'd train step, deterministic data
pipeline, step-granular checkpointing, NaN-step rejection, crash/restart
(--inject-failure kills the process mid-run; rerunning with the same
--ckpt resumes bit-exactly), and elastic restore onto a different mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 50
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_arch
from repro.distributed.fault_tolerance import StepGuard
from repro.distributed.sharding import DEFAULT_RULES, FSDP_RULES
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticLMData
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import jit_train_step
from repro.train.data import input_spec_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU pods); default: smoke config")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="crash after this step (restart demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.config if args.full else spec.smoke_config
    model = Model(cfg)
    mesh = make_host_mesh(args.model_parallel)
    rules = FSDP_RULES if (spec.rules == "fsdp" and args.full) else DEFAULT_RULES
    opt_cfg = OptConfig(lr=args.lr, state_bits=spec.opt_bits if args.full else 32)

    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)
    batch_spec = input_spec_batch(cfg.vocab_size, args.seq, args.batch)
    if spec.extras:
        ex = spec.extras("train", cfg, args.batch, args.seq)
        batch_spec.update(ex)

    step_fn, (p_shard, o_shard, shapes, axes) = jit_train_step(
        model, mesh, rules, opt_cfg, batch_spec, total_steps=args.steps)

    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    params = opt_state = None
    if ckpt and ckpt.latest() is not None:
        o_like = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), shapes)
        params, opt_state, manifest = ckpt.restore(
            None, shapes, o_like, mesh, p_shard, o_shard)
        data.restore(manifest["data_state"])
        start = manifest["step"] + 1
        print(f"[train] restored step {manifest['step']} from {args.ckpt}")
    if params is None:
        with mesh:
            params = jax.jit(lambda k: model.init(k)[0],
                             out_shardings=p_shard)(jax.random.PRNGKey(args.seed))
            opt_state = jax.jit(lambda p: adamw_init(p, opt_cfg),
                                out_shardings=o_shard)(params)

    guard = StepGuard()
    extras = {}
    if spec.extras:
        extras = {k: jnp.zeros(v.shape, v.dtype)
                  for k, v in spec.extras("train", cfg, args.batch,
                                          args.seq).items()}
    for step in range(start, args.steps):
        hb = data.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        batch.update(extras)
        t0 = time.time()
        new_params, new_opt, metrics = step_fn(params, opt_state, batch,
                                               jnp.int32(step))
        metrics = jax.device_get(metrics)
        dt = time.time() - t0
        if guard.ok(metrics):
            params, opt_state = new_params, new_opt
        else:
            print(f"[train] step {step}: REJECTED (loss={metrics['loss']}, "
                  f"gnorm={metrics['gnorm']})")
            if guard.should_restore and ckpt:
                print("[train] too many rejections — restoring checkpoint")
                o_like = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), shapes)
                params, opt_state, manifest = ckpt.restore(
                    None, shapes, o_like, mesh, p_shard, o_shard)
        print(f"[train] step {step} loss={metrics['loss']:.4f} "
              f"gnorm={metrics['gnorm']:.3f} lr={metrics['lr']:.2e} "
              f"{dt*1000:.0f}ms", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            data.step = step
            ckpt.save(step, params, opt_state, data.state())
        if step == args.inject_failure:
            print("[train] injected failure — killing process", flush=True)
            os._exit(17)
    if ckpt:
        ckpt.save(args.steps - 1, params, opt_state,
                  {"step": args.steps - 1, "seed": args.seed})
    print("[train] done")
    return params


if __name__ == "__main__":
    main()
