"""Graph mining driver — one ``Miner`` session serving the paper's workload.

  PYTHONPATH=src python -m repro.launch.mine --app T --dataset wiki-vote
  PYTHONPATH=src python -m repro.launch.mine --app FSM --dataset citeseer \\
      --support 100

The driver is a thin consumer of the session API: it builds a single
``mining.session.Miner`` for the dataset and issues every query against
it, so schedules and executables are derived once per invocation
(``--session-stats`` prints the cache counters that prove it, plus the
full Prometheus-style metrics snapshot).

Observability flags (repro.obs): ``--trace out.json`` enables span
tracing on the session and writes a Chrome-trace/Perfetto JSON of the
query's span tree; ``--jax-profile LOGDIR`` additionally wraps the query
in ``jax.profiler`` start/stop for an XLA-level profile.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.distributed.fault_tolerance import balanced_vertex_partition
from repro.graph import get_dataset
from repro.graph.datasets import DATASETS, dataset_stats
from repro.mining import baseline, exhaustive
from repro.mining.fsm import fsm, random_labels, sfsm
from repro.mining.plan import FOUR_MOTIF_SHAPES, TRIANGLE, \
    THREE_CHAIN_INDUCED
from repro.mining.session import Miner, MinerConfig

# per-pattern 4-motif codes (auto-scheduled Motif queries, zero engine code)
PATTERN_APPS = {"DM": "diamond", "CY": "4-cycle", "PW": "paw",
                "P4": "4-path", "S4": "4-star"}
# F4M / F3M: the motif batches through the session's schedule stage, with
# the static sharing report printed (4M / TM also fuse — these codes force
# the verbose forest path and honour --independent for A/B runs)
APPS = ["T", "TS", "TC", "TT", "TM", "4C", "5C", "4M", "F3M", "F4M",
        *PATTERN_APPS, "FSM", "sFSM"]

THREE_MOTIF_QUERIES = (TRIANGLE, THREE_CHAIN_INDUCED)


def run_app(app: str, miner: Miner, support: int = 100, labels=None,
            fused: bool = True):
    """Serve one app code from the session."""
    if app == "T":
        return miner.count("triangle")
    if app == "TS":
        return miner.count("triangle-nested")
    if app == "TC":
        return miner.count("three-chain")
    if app == "TT":
        return miner.count("tailed-triangle")
    if app in ("TM", "F3M"):
        if fused:
            t, chains = miner.count_many(list(THREE_MOTIF_QUERIES))
        else:
            t = miner.count(TRIANGLE)
            chains = miner.count(THREE_CHAIN_INDUCED)
        return {"triangle": t, "chain": chains}
    if app == "4C":
        return miner.count("4-clique")
    if app == "5C":
        return miner.count("5-clique")
    if app in ("4M", "F4M"):
        names = list(FOUR_MOTIF_SHAPES)
        if fused:
            return dict(zip(names, miner.count_many(names)))
        return {name: miner.count(name) for name in names}
    if app in PATTERN_APPS:
        return miner.count(PATTERN_APPS[app])
    if app in ("FSM", "sFSM"):
        fn = fsm if app == "FSM" else sfsm
        res = fn(miner.graph, labels, support, miner=miner)
        return {"frequent_patterns": len(res)}
    raise ValueError(app)


def _forest_report(app: str, miner: Miner) -> str:
    """Static sharing stats for the F3M/F4M batches (the session's
    schedule stage: auto matching-order search + forest merge)."""
    queries = list(FOUR_MOTIF_SHAPES) if app == "F4M" \
        else list(THREE_MOTIF_QUERIES)
    st = miner.schedule(queries).sharing_stats()
    levels = sorted({lv for _, lv in st["plan_ops"]})
    per_level = " ".join(
        f"L{lv}:{sum(v for (k, l2), v in st['plan_ops'].items() if l2 == lv)}"
        f"->{sum(v for (k, l2), v in st['forest_ops'].items() if l2 == lv)}"
        for lv in levels)
    return (f"{st['plans']} plans, ops {per_level}, feed passes "
            f"{st['feed_passes']['independent']}->{st['feed_passes']['fused']}")


def run_baseline(app: str, g):
    return {
        "T": lambda: baseline.triangle_count(g),
        "TC": lambda: baseline.three_chain_count(g, induced=True),
        "TT": lambda: baseline.tailed_triangle_count(g),
        "TM": lambda: baseline.three_motif(g),
        "4C": lambda: baseline.clique_count(g, 4),
        "5C": lambda: baseline.clique_count(g, 5),
    }[app]()


def main(argv=None):
    from repro.launch.cli import add_graph_args, add_session_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--app", choices=APPS, default="T")
    add_graph_args(ap, choices=list(DATASETS))
    ap.add_argument("--support", type=int, default=100)
    ap.add_argument("--labels", type=int, default=4)
    ap.add_argument("--baseline", action="store_true",
                    help="also run InHouseAutoMine (scalar CPU)")
    ap.add_argument("--independent", action="store_true",
                    help="run motif batches as independent per-pattern plans "
                         "instead of the fused PlanForest")
    ap.add_argument("--check", action="store_true",
                    help="F3M/F4M: assert fused counts == independent "
                         "per-plan counts (and == the brute-force census "
                         "when the graph is small enough)")
    ap.add_argument("--exhaustive", default="",
                    help="also run GRAMER-style exhaustive check for PATTERN")
    ap.add_argument("--partitions", type=int, default=0,
                    help="print degree-balanced partition stats (straggler)")
    add_session_args(ap)
    ap.add_argument("--jax-profile", default="", metavar="LOGDIR",
                    help="wrap the query in jax.profiler start/stop "
                         "(XLA-level trace written to LOGDIR)")
    args = ap.parse_args(argv)

    g = get_dataset(args.dataset, scale=args.scale)
    print(f"[mine] {args.dataset} x{args.scale}: {dataset_stats(g)}")
    miner = Miner(g, MinerConfig.from_args(args))
    telemetry = miner.telemetry
    if miner.mesh is not None:
        print(f"[mine] mesh: {args.shards}-way "
              f"({dict(miner.mesh.shape)})")
    labels = random_labels(g.num_vertices, args.labels, seed=1) \
        if args.app in ("FSM", "sFSM") else None
    if args.app in ("F3M", "F4M"):
        print(f"[mine] forest: {_forest_report(args.app, miner)}")
    t0 = time.perf_counter()
    with telemetry.jax_profile(args.jax_profile or None):
        res = run_app(args.app, miner, args.support, labels,
                      fused=not args.independent)
    dt = time.perf_counter() - t0
    print(f"[mine] {args.app} = {res}  ({dt:.2f}s, IntersectX engine)")
    if args.trace:
        path = telemetry.write_trace(args.trace)
        agg = telemetry.tracer.level_seconds()
        top = sorted(agg.items(), key=lambda kv: -kv[1])[:6]
        print(f"[mine] trace: {sum(1 for _ in telemetry.tracer.spans())} "
              f"spans -> {path}; self-time "
              + " ".join(f"{k}={v*1e3:.1f}ms" for k, v in top))
    if args.check and args.app in ("F3M", "F4M"):
        indep = run_app(args.app, miner, args.support, labels, fused=False)
        assert res == indep, (res, indep)
        print("[mine] fused == independent per-plan counts OK")
        if args.app == "F4M" and g.num_vertices <= 256:
            from repro.mining import reference
            census = reference.four_motif_counts(g)
            assert res == census, (res, census)
            print("[mine] fused == brute-force census OK")
    if args.baseline and args.app in ("T", "TC", "TT", "TM", "4C", "5C"):
        t0 = time.perf_counter()
        rb = run_baseline(args.app, g)
        dtb = time.perf_counter() - t0
        assert rb == res, (rb, res)
        print(f"[mine] baseline(InHouseAutoMine) = {rb} ({dtb:.2f}s) "
              f"=> engine speedup {dtb/max(dt,1e-9):.1f}x")
    if args.exhaustive:
        t0 = time.perf_counter()
        re_ = exhaustive.exhaustive_count(g, args.exhaustive)
        print(f"[mine] exhaustive({args.exhaustive}) = {re_} "
              f"({time.perf_counter()-t0:.2f}s, GRAMER-style)")
    if args.partitions:
        assign = balanced_vertex_partition(np.asarray(g.degrees),
                                           args.partitions)
        cost = np.asarray(g.degrees, dtype=np.float64) ** 2
        loads = np.bincount(assign, weights=cost, minlength=args.partitions)
        print(f"[mine] {args.partitions} partitions: load imbalance "
              f"max/mean = {loads.max()/loads.mean():.3f}")
    if args.session_stats:
        st = miner.stats
        print(f"[mine] session: {st['queries']} queries, "
              f"exec cache {st['exec_cache']['hits']} hits / "
              f"{st['exec_cache']['misses']} traces, "
              f"plan cache {st['plan_hits']}/{st['plan_misses']}, "
              f"schedule cache {st['schedule_hits']}/{st['schedule_misses']}")
        if miner.mesh is not None:
            rs = st["runner"]
            fi = rs["shard_feed_items"]
            print(f"[mine] shards: feed items {fi} "
                  f"(max/min {max(fi)/max(min(fi), 1):.2f}), "
                  f"{rs['psum_reductions']} psum reductions")
        print("[mine] metrics:")
        print(telemetry.prometheus_text(), end="")


if __name__ == "__main__":
    main()
