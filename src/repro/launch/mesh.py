"""Production meshes (TPU v5e pods).

Importing this module never touches jax device state — meshes are built
lazily by the functions (the dry-run sets XLA_FLAGS *before* any jax
import; tests/benches see the 1 real device).
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import make_mesh_compat, make_mining_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=('data','model') single pod; (2,16,16)=('pod','data','model')
    two pods = 512 chips. Uses a prefix of the available devices so the
    single-pod mesh builds in the 512-device dry-run process."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run via "
            "launch/dryrun.py which sets xla_force_host_platform_device_count")
    return make_mesh_compat(shape, axes, devices=devs[:need])


def make_host_mesh(model_parallel: int | None = None):
    """Largest (data, model) mesh over the actually-present devices —
    used by tests, examples and CPU training runs."""
    n = len(jax.devices())
    mp = model_parallel or 1
    assert n % mp == 0
    return make_mesh_compat((n // mp, mp), ("data", "model"))


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size)


__all__ = ["make_host_mesh", "make_mining_mesh", "make_production_mesh",
           "mesh_chips"]
