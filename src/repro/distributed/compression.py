"""int8-compressed cross-pod gradient all-reduce with error feedback.

Data-parallel gradient synchronisation dominates the multi-pod collective
budget (the 'pod' axis rides the slow inter-pod links). We compress that
hop: per-tensor int8 quantisation inside a shard_map over the pod axis,
all-reduce in int32, dequantise, and keep the quantisation residual in an
error-feedback buffer added to the next step's gradient (so compression
error does not bias the optimizer, only delays information).

The intra-pod ('data' axis) reduction stays full precision — ICI is fast
and the paper-of-record tricks (1-bit Adam etc.) all compress only the slow
hop. EXPERIMENTS.md §Perf quantifies the collective-bytes saving from the
dry-run HLO (4x on the pod axis for f32 grads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quant(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), scale


def compressed_mean(x: jax.Array, axis_name: str, err: jax.Array | None = None):
    """Mean over ``axis_name`` of x (+err), int8 on the wire.

    Returns (mean, new_err). Must run inside shard_map/pmap context where
    ``axis_name`` is bound."""
    n = jax.lax.psum(1, axis_name)
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    q, scale = _quant(xf)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)   # shared dequant scale
    mean = (total.astype(jnp.float32) * scale_max) / n
    new_err = xf - q.astype(jnp.float32) * scale  # local residual
    return mean.astype(x.dtype), new_err


def tree_compressed_mean(grads, mesh, axis_name: str, err_tree=None):
    """Compressed-mean every leaf over ``axis_name`` via one shard_map.

    Gradients entering here must be *partial* over the pod axis (i.e. the
    loss was averaged per pod); the call completes the DP reduction.
    """
    specs = jax.tree.map(lambda _: P(), grads)   # replicated within region

    def body(g_tree):
        return jax.tree.map(lambda g: compressed_mean(g, axis_name)[0], g_tree)

    fn = shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs,
                   check_rep=False)
    return fn(grads)
