from .sharding import (
    Axes, DEFAULT_RULES, FSDP_RULES, ShardingRules, constrain,
    input_sharding, is_axes, logical_to_physical, mesh_context,
    named_sharding, shard_params_tree, with_sharding_constraint,
)

__all__ = [
    "Axes", "DEFAULT_RULES", "FSDP_RULES", "ShardingRules", "constrain",
    "input_sharding", "is_axes", "logical_to_physical", "mesh_context",
    "named_sharding", "shard_params_tree", "with_sharding_constraint",
]
