"""Logical-axis sharding rules with divisibility-aware resolution.

Every parameter/activation carries *logical* axis names (Axes('experts',
'd_ff', 'embed'), ...). ``ShardingRules`` maps logical names to mesh axes;
resolution drops a mesh axis whenever the dimension does not divide the
axis size (e.g. 4 KV heads on a 16-way 'model' axis => replicated), so
every config lowers on every mesh without hand-tuning.

Meshes (launch/mesh.py):
  single pod  (16, 16)      axes ('data', 'model')
  multi pod   (2, 16, 16)   axes ('pod', 'data', 'model')

Conventions:
  batch      -> ('pod', 'data')   pure DP
  embed      -> None (replicated); FSDP_RULES shards it over ('data',)
  heads/q    -> 'model'           Megatron TP
  kv_heads   -> 'model' (drops to replication when #kv % axis != 0)
  d_ff       -> 'model'
  experts    -> 'model'           expert parallelism
  vocab      -> 'model'           sharded embeddings + logits
  kv_seq     -> 'model'           sequence-sharded decode KV caches
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh_compat(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """``jax.make_mesh`` across jax versions.

    jax >= 0.5 accepts (and on some versions wants) ``axis_types``; 0.4.x
    does not have ``jax.sharding.AxisType`` at all. Everything in this repo
    uses plain Auto axes, so the portable call simply omits the kwarg when
    the enum is missing.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def make_mining_mesh(shards: int | None = None, axis: str = "mine", *,
                     devices=None) -> Mesh:
    """1-D device mesh for data-parallel pattern mining (mining.shard).

    ``shards=None`` takes every visible device; an explicit count uses the
    first ``shards`` devices (a strict prefix keeps the mesh deterministic,
    so cache signatures and psum groups are stable across runs). The mining
    axis is the only axis — wavefront sharding is pure DP over the level-1
    edge feed, there is no model axis to compose with.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = int(shards) if shards else len(devs)
    if n < 1:
        raise ValueError(f"mining mesh needs >= 1 shard, got {n}")
    if n > len(devs):
        raise ValueError(
            f"mining mesh wants {n} shards but only {len(devs)} device(s) "
            f"are visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return make_mesh_compat((n,), (axis,), devices=devs[:n])


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across jax versions.

    0.4.x takes a single ``((name, size), ...)`` tuple; newer jax takes
    ``(axis_shapes, axis_names)``. Only axis sizes matter for resolution
    logic, so either spelling yields an equivalent mesh here.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_shapes)))


class Axes(tuple):
    """Logical axes annotation; subclassing tuple but treated as a pytree
    leaf in the axes trees (axes trees only ever contain Axes leaves, and we
    always flatten with is_leaf=is_axes)."""

    def __new__(cls, *names):
        return super().__new__(cls, names)


def is_axes(x) -> bool:
    return isinstance(x, Axes)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (str), tuple of mesh axes, or None."""

    rules: dict

    def get(self, name: str):
        return self.rules.get(name, None)

    def replace(self, **kw) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(d)


DEFAULT_RULES = ShardingRules({
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "kv_seq": "model",          # decode caches: sequence-sharded (flash-decode)
    "state": None,              # SSM / RWKV recurrent state dims
    "conv": None,
    "opt": ("data", "pod"),     # ZeRO extra sharding for optimizer state
    "moe_groups": ("pod", "data"),  # sort-dispatch token groups (local sort)
    "moe_cap": ("pod", "data"),     # expert capacity dim after the a2a
    "bh": ("pod", "data", "model"),  # merged batch x heads (rwkv wkv)
})

FSDP_RULES = DEFAULT_RULES.replace(embed=("data",))


def _axis_sizes(mesh) -> dict[str, int]:
    # works for both Mesh and AbstractMesh (no .devices on the latter)
    return dict(mesh.shape)


def logical_to_physical(axes: Axes, mesh: Mesh, rules: ShardingRules,
                        shape: tuple | None = None) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-dividing axes."""
    sizes = _axis_sizes(mesh)
    used: set[str] = set()
    spec = []
    for d, name in enumerate(axes):
        mesh_axes = rules.get(name) if name else None
        if mesh_axes is None:
            spec.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = []
        prod = 1
        for ax in mesh_axes:
            if ax not in sizes or ax in used:
                continue
            nxt = prod * sizes[ax]
            if shape is not None and shape[d] % nxt != 0:
                continue
            picked.append(ax)
            prod = nxt
        used.update(picked)
        spec.append(tuple(picked) if len(picked) > 1
                    else (picked[0] if picked else None))
    return P(*spec)


def named_sharding(axes: Axes, mesh: Mesh, rules: ShardingRules,
                   shape: tuple | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_physical(axes, mesh, rules, shape))


def shard_params_tree(param_shapes, param_axes, mesh: Mesh,
                      rules: ShardingRules):
    """ShapeDtypeStruct tree + Axes tree -> NamedSharding tree."""
    flat_s, treedef = jax.tree.flatten(param_shapes)
    flat_a = jax.tree.flatten(param_axes, is_leaf=is_axes)[0]
    assert len(flat_s) == len(flat_a), "param/axes trees out of sync"
    out = [named_sharding(a, mesh, rules, tuple(s.shape))
           for s, a in zip(flat_s, flat_a)]
    return jax.tree.unflatten(treedef, out)


def input_sharding(mesh: Mesh, rules: ShardingRules, *names) -> NamedSharding:
    return named_sharding(Axes(*names), mesh, rules)


# ---------------------------------------------------------------------------
# mesh context: lets model code write constrain(x, 'batch','seq','embed')
# without plumbing the mesh through every function signature.
# ---------------------------------------------------------------------------

_TLS = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, rules: ShardingRules = DEFAULT_RULES):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_mesh():
    ctx = getattr(_TLS, "ctx", None)
    return ctx if ctx is not None else (None, DEFAULT_RULES)


def constrain(x, *names):
    """Logical sharding constraint; no-op when no mesh context is active."""
    mesh, rules = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_physical(Axes(*names), mesh, rules, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def with_sharding_constraint(x, axes: Axes, mesh: Mesh | None = None,
                             rules: ShardingRules = DEFAULT_RULES):
    if mesh is None:
        return constrain(x, *axes)
    spec = logical_to_physical(axes, mesh, rules, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
