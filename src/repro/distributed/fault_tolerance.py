"""Fault tolerance: NaN-step rejection, restart orchestration, elastic
re-mesh, straggler-aware partitioning.

Container reality: one process, fake devices — so what we *prove* here is
the control plane: every decision function is pure and unit-tested, the
restart path is exercised end-to-end by examples/fault_tolerance.py
(train -> kill -> restore -> bit-exact continuation), and the elastic path
restores a 512-chip checkpoint onto a different mesh (tests/test_checkpoint
does 1-device <-> 8-device round trips).

At 1000+ nodes the same pieces compose: heartbeat timeouts mark a pod lost,
the job re-enters ``elastic_remesh`` with the surviving device set, restores
the latest checkpoint with re-resolved shardings, and the deterministic
data pipeline (pure f(seed, step)) replays the exact token stream.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


# ---------------------------------------------------------------------------
# NaN / divergence guard
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepGuard:
    """Rejects steps whose loss/gnorm is non-finite or explodes.

    Keeps the previous (params, opt_state) alive until the new step's
    metrics are verified — the standard skip-and-continue recipe. Tracks a
    consecutive-rejection budget; exceeding it signals restore-from-
    checkpoint (data corruption / hardware fault rather than transient).
    """

    max_consecutive: int = 5
    gnorm_ceiling: float = 1e4
    rejected: int = 0
    consecutive: int = 0

    def ok(self, metrics: dict) -> bool:
        loss = float(metrics["loss"])
        gnorm = float(metrics["gnorm"])
        good = np.isfinite(loss) and np.isfinite(gnorm) and \
            gnorm < self.gnorm_ceiling
        if good:
            self.consecutive = 0
        else:
            self.rejected += 1
            self.consecutive += 1
        return good

    @property
    def should_restore(self) -> bool:
        return self.consecutive >= self.max_consecutive


# ---------------------------------------------------------------------------
# heartbeats / straggler detection (control-plane logic, pure + testable)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HeartbeatMonitor:
    """Marks workers dead after ``timeout`` without a beat; flags stragglers
    whose step time exceeds ``straggler_factor`` x median."""

    num_workers: int
    timeout: float = 60.0
    straggler_factor: float = 2.0

    def __post_init__(self):
        now = time.time()
        self.last_beat = {w: now for w in range(self.num_workers)}
        self.step_times: dict[int, float] = {}

    def beat(self, worker: int, step_time: float | None = None,
             now: float | None = None):
        self.last_beat[worker] = now if now is not None else time.time()
        if step_time is not None:
            self.step_times[worker] = step_time

    def dead(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [w for w, t in self.last_beat.items() if now - t > self.timeout]

    def stragglers(self) -> list[int]:
        if len(self.step_times) < max(2, self.num_workers // 2):
            return []
        med = float(np.median(list(self.step_times.values())))
        return [w for w, t in self.step_times.items()
                if t > self.straggler_factor * med]


def elastic_remesh(alive_workers: int, chips_per_worker: int,
                   model_parallel: int = 16):
    """Largest (data, model) mesh shape fitting the surviving fleet.

    Keeps the model axis fixed (reshaping TP mid-run would re-lay weights);
    shrinks/grows the data axis to the largest power-of-two that fits, which
    keeps global batch divisibility. Returns (shape, axis_names, dropped)."""
    total = alive_workers * chips_per_worker
    data = total // model_parallel
    if data < 1:
        raise RuntimeError(
            f"{total} chips cannot hold model_parallel={model_parallel}")
    p2 = 1
    while p2 * 2 <= data:
        p2 *= 2
    dropped = total - p2 * model_parallel
    return (p2, model_parallel), ("data", "model"), dropped


# ---------------------------------------------------------------------------
# straggler-aware static partitioning (mining jobs)
# ---------------------------------------------------------------------------

def balanced_vertex_partition(degrees: np.ndarray, num_parts: int,
                              alpha: float = 1.0) -> np.ndarray:
    """Assign vertices to workers balancing Σ deg^(1+alpha) (intersection
    cost ~ deg^2 for the mining wavefront): greedy LPT on the cost.

    Deterministic => any worker can recompute any partition (work stealing
    at bucket granularity needs no coordination)."""
    cost = degrees.astype(np.float64) ** (1.0 + alpha)
    order = np.argsort(-cost)
    load = np.zeros(num_parts)
    assign = np.zeros(len(degrees), dtype=np.int32)
    for v in order:
        w = int(np.argmin(load))
        assign[v] = w
        load[w] += cost[v]
    return assign
