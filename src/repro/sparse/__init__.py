from .matrix import SparseCSR, SparseCSC, from_dense, random_sparse
from .spmm import spmsp_matmul
from .ttv import CSFTensor, random_csf, ttv

__all__ = ["SparseCSR", "SparseCSC", "from_dense", "random_sparse",
           "spmsp_matmul", "CSFTensor", "random_csf", "ttv"]
