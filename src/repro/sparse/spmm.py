"""Sparse x sparse matrix multiplication via S_VINTER (paper §VI-I).

The paper converts B to CSC and computes C[i,j] = S_VINTER(row_i(A),
col_j(B), MAC) — every output element is one sparse dot of two (key,value)
streams. We batch those dots: a row-block of A against a column-block of B
forms a (RB x CB) grid of stream pairs evaluated in one kernel launch.

Pairs where either stream is empty are skipped at the block level (an empty
row/column zeroes the whole block row/col — the paper's dependency bound
|A∩B| <= min lengths, used for work elision instead of buffer sizing).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.ops import xvinter
from .matrix import SparseCSC, SparseCSR


def spmsp_matmul(a: SparseCSR, b: SparseCSC, row_block: int = 64,
                 col_block: int = 64, backend: str = "auto") -> np.ndarray:
    """C = A @ B, A in CSR, B in CSC; returns dense (M, N) float32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out = np.zeros((m, n), np.float32)
    a_nnz = np.diff(a.indptr)
    b_nnz = np.diff(b.indptr)
    rows_alive = np.nonzero(a_nnz > 0)[0]
    cols_alive = np.nonzero(b_nnz > 0)[0]
    if rows_alive.size == 0 or cols_alive.size == 0:
        return out
    for r0 in range(0, rows_alive.size, row_block):
        rsel = rows_alive[r0: r0 + row_block]
        ak, av = a.padded_rows(rsel)
        for c0 in range(0, cols_alive.size, col_block):
            csel = cols_alive[c0: c0 + col_block]
            bk, bv = b.padded_rows(csel)
            # all (row, col) pairs in the block: tile both batches
            nr, nc = len(rsel), len(csel)
            AK = jnp.asarray(np.repeat(ak, nc, axis=0))
            AV = jnp.asarray(np.repeat(av, nc, axis=0))
            BK = jnp.asarray(np.tile(bk, (nr, 1)))
            BV = jnp.asarray(np.tile(bv, (nr, 1)))
            vals = np.asarray(xvinter(AK, AV, BK, BV, backend=backend))
            out[np.repeat(rsel, nc), np.tile(csel, nr)] = vals
    return out
