"""Tensor-times-vector over CSF (paper §VI-I):  A_ij = Σ_k T_ijk · B_k.

The CSF last-mode fibers T(i,j,:) are (key,value) streams; TTV is one
batched S_VINTER of all fibers against the (shared) vector stream. The
paper reports its largest SVPU speedups here (23x) because every fiber
reuses the same B stream — on TPU that reuse is a broadcast, so the whole
operation is a single kernel launch over the fiber batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.stream import SENTINEL, round_capacity
from repro.kernels.ops import xvinter


@dataclasses.dataclass(frozen=True)
class CSFTensor:
    """3-mode CSF: root mode i -> fibers (i,j) -> last-mode (k, val) streams."""

    i_ids: np.ndarray       # (F,) root coordinate per fiber
    j_ids: np.ndarray       # (F,) second coordinate per fiber
    fiber_ptr: np.ndarray   # (F+1,) into k_ids/vals
    k_ids: np.ndarray       # (nnz,) sorted within each fiber
    vals: np.ndarray        # (nnz,)
    shape: tuple[int, int, int]

    @property
    def nnz(self) -> int:
        return int(self.k_ids.shape[0])

    @property
    def num_fibers(self) -> int:
        return int(self.i_ids.shape[0])


def from_coo(coords: np.ndarray, values: np.ndarray,
             shape: tuple[int, int, int]) -> CSFTensor:
    order = np.lexsort((coords[:, 2], coords[:, 1], coords[:, 0]))
    coords, values = coords[order], values[order]
    fiber_key = coords[:, 0].astype(np.int64) * shape[1] + coords[:, 1]
    uniq, starts = np.unique(fiber_key, return_index=True)
    fiber_ptr = np.concatenate([starts, [len(values)]]).astype(np.int64)
    return CSFTensor(
        i_ids=(uniq // shape[1]).astype(np.int32),
        j_ids=(uniq % shape[1]).astype(np.int32),
        fiber_ptr=fiber_ptr,
        k_ids=coords[:, 2].astype(np.int32),
        vals=values.astype(np.float32),
        shape=shape)


def random_csf(shape: tuple[int, int, int], nnz: int, seed: int = 0) -> CSFTensor:
    rng = np.random.default_rng(seed)
    flat = rng.choice(shape[0] * shape[1] * shape[2], size=nnz, replace=False)
    coords = np.stack(np.unravel_index(flat, shape), axis=1).astype(np.int64)
    return from_coo(coords, rng.normal(size=nnz).astype(np.float32), shape)


def ttv(t: CSFTensor, vec_keys: np.ndarray, vec_vals: np.ndarray,
        fiber_block: int = 512, backend: str = "auto"):
    """A_ij = Σ_k T_ijk B_k with B a sparse vector (key,value) stream.

    Returns (i_ids, j_ids, values) — the nonzero output matrix in COO.
    Dense B is the special case vec_keys = arange(K)."""
    cap_k = round_capacity(int(np.diff(t.fiber_ptr).max()) if t.num_fibers else 1)
    cap_v = round_capacity(len(vec_keys))
    vk = np.full((cap_v,), SENTINEL, np.int32)
    vk[: len(vec_keys)] = vec_keys
    vv = np.zeros((cap_v,), np.float32)
    vv[: len(vec_keys)] = vec_vals
    out = np.zeros((t.num_fibers,), np.float32)
    for f0 in range(0, t.num_fibers, fiber_block):
        f1 = min(f0 + fiber_block, t.num_fibers)
        nb = f1 - f0
        fk = np.full((nb, cap_k), SENTINEL, np.int32)
        fv = np.zeros((nb, cap_k), np.float32)
        for i, f in enumerate(range(f0, f1)):
            lo, hi = t.fiber_ptr[f], t.fiber_ptr[f + 1]
            fk[i, : hi - lo] = t.k_ids[lo:hi]
            fv[i, : hi - lo] = t.vals[lo:hi]
        VK = jnp.asarray(np.broadcast_to(vk, (nb, cap_v)))
        VV = jnp.asarray(np.broadcast_to(vv, (nb, cap_v)))
        out[f0:f1] = np.asarray(
            xvinter(jnp.asarray(fk), jnp.asarray(fv), VK, VV,
                    backend=backend))
    return t.i_ids, t.j_ids, out
