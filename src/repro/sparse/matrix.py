"""Sparse matrix containers for the S_VINTER applications (§VI-I).

Rows (CSR) / columns (CSC) are exactly the paper's (key,value) streams:
sorted index keys plus aligned values. ``padded_rows`` materialises a batch
of them as LANE-padded matrices for the batched SVPU ops.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.stream import SENTINEL, round_capacity


@dataclasses.dataclass(frozen=True)
class SparseCSR:
    indptr: np.ndarray   # (M+1,)
    indices: np.ndarray  # (nnz,) column keys, sorted per row
    values: np.ndarray   # (nnz,)
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def max_row_nnz(self) -> int:
        return int(np.diff(self.indptr).max()) if self.shape[0] else 0

    def padded_rows(self, rows: np.ndarray, cap: int | None = None):
        """(keys, vals) LANE-padded matrices for a batch of row ids."""
        cap = round_capacity(cap or self.max_row_nnz())
        keys = np.full((len(rows), cap), SENTINEL, np.int32)
        vals = np.zeros((len(rows), cap), np.float32)
        for i, r in enumerate(rows):
            lo, hi = self.indptr[r], self.indptr[r + 1]
            n = min(hi - lo, cap)
            keys[i, :n] = self.indices[lo: lo + n]
            vals[i, :n] = self.values[lo: lo + n]
        return keys, vals

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        for r in range(self.shape[0]):
            lo, hi = self.indptr[r], self.indptr[r + 1]
            out[r, self.indices[lo:hi]] = self.values[lo:hi]
        return out


class SparseCSC(SparseCSR):
    """CSC is CSR of the transpose: indptr over columns, keys are row ids."""

    @property
    def shape_t(self) -> tuple[int, int]:
        return (self.shape[1], self.shape[0])


def from_dense(a: np.ndarray, fmt: str = "csr") -> SparseCSR:
    a = np.asarray(a, np.float32)
    if fmt == "csc":
        t = from_dense(a.T, "csr")
        return SparseCSC(t.indptr, t.indices, t.values, a.shape)
    m, n = a.shape
    indptr = np.zeros(m + 1, np.int64)
    idx, val = [], []
    for r in range(m):
        cols = np.nonzero(a[r])[0]
        indptr[r + 1] = indptr[r] + len(cols)
        idx.append(cols)
        val.append(a[r, cols])
    return SparseCSR(indptr,
                     np.concatenate(idx).astype(np.int32) if idx else np.zeros(0, np.int32),
                     np.concatenate(val).astype(np.float32) if val else np.zeros(0, np.float32),
                     (m, n))


def random_sparse(m: int, n: int, density: float, seed: int = 0,
                  fmt: str = "csr") -> SparseCSR:
    rng = np.random.default_rng(seed)
    mask = rng.random((m, n)) < density
    a = np.where(mask, rng.normal(size=(m, n)).astype(np.float32), 0.0)
    return from_dense(a, fmt)
