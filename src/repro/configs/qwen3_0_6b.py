"""qwen3-0.6b [dense] — 28L d=1024 16H (kv=8) d_ff=3072 vocab=151936,
qk_norm, tied embeddings.  [hf:Qwen/Qwen3-0.6B; hf]
"""
from repro.models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "qwen3-0.6b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=28, d_model=1024, num_heads=16,
        num_kv_heads=8, head_dim=128, d_ff=3072, vocab_size=151936,
        qk_norm=True, tie_embeddings=True, kv_repeat=2, rope_theta=1e6,
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        qk_norm=True, tie_embeddings=True, kv_repeat=2,
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP})
