"""gemma2-27b [dense] — 46L d=4608 32H (kv=16) d_ff=36864 vocab=256000,
alternating local(4096)/global attention, attn+final logit softcap, tied
embeddings.  [arXiv:2408.00118; hf]
"""
from repro.models.transformer import ModelConfig
from .common import ArchSpec

NAME = "gemma2-27b"

SKIP_LONG = ("alternating local/global: the global layers are full " +
             "attention, so long_500k is skipped (local-only window would " +
             "misrepresent the arch) — DESIGN.md §Arch-applicability")


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=46, d_model=4608, num_heads=32,
        num_kv_heads=16, head_dim=128, d_ff=36864, vocab_size=256000,
        pattern=("attn", "attn"), windows=(4096, None),
        softcap=50.0, logit_softcap=30.0, tie_embeddings=True,
        act="gelu",
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        pattern=("attn", "attn"), windows=(16, None),
        softcap=50.0, logit_softcap=30.0, tie_embeddings=True, act="gelu",
        kv_repeat=2,
    )
    return ArchSpec(NAME, full, smoke, skips={"long_500k": SKIP_LONG},
                    rules="fsdp")
