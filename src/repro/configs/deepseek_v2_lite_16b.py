"""deepseek-v2-lite-16b [moe] — 27L d=2048 16H MLA(kv_lora=512) MoE 64e top-6
+ 2 shared, vocab 102400.  [arXiv:2405.04434; hf]

MLA: q heads 16 x (128 nope + 64 rope); v_head 128; kv compressed to 512.
Layer 0 is dense (d_ff 10944), layers 1..26 MoE (expert d_ff 1408).
"""
from repro.models.layers import MLAConfig
from repro.models.transformer import ModelConfig, MoEConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "deepseek-v2-lite-16b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=27, d_model=2048, num_heads=16,
        num_kv_heads=16, head_dim=192, d_ff=1408, vocab_size=102400,
        attention="mla",
        mla=MLAConfig(d_model=2048, num_heads=16, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, num_shared=2,
                      shared_d_ff=2816, dispatch="sort"),
        first_dense=1, first_dense_ff=10944,
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=24, d_ff=48, vocab_size=512,
        attention="mla",
        mla=MLAConfig(d_model=64, num_heads=4, kv_lora_rank=32,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=48, num_shared=2,
                      shared_d_ff=96, dispatch="sort"),
        first_dense=1, first_dense_ff=128,
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP}, rules="fsdp")
