"""jamba-1.5-large-398b [hybrid] — 72L d=8192 64H (kv=8) d_ff=24576
vocab=65536, Mamba:attn 7:1 interleave, MoE 16e top-2 every other layer,
no positional embedding on attention.  [arXiv:2403.19887; hf]

Runs long_500k: 63/72 layers carry O(1) SSM state; the 9 attention layers'
500k KV caches are sequence-sharded over the model axis.
Optimizer state is int8 (state_bits=8) so master+m+v fit 16GB/chip — see
EXPERIMENTS.md §Dry-run.
"""
from repro.models.mamba import MambaConfig
from repro.models.transformer import ModelConfig, MoEConfig
from .common import ArchSpec

NAME = "jamba-1.5-large-398b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=72, d_model=8192, num_heads=64,
        num_kv_heads=8, head_dim=128, d_ff=24576, vocab_size=65536,
        pattern=("mamba", "mamba", "mamba", "attn",
                 "mamba", "mamba", "mamba", "mamba"),
        use_rope=False, kv_repeat=2,
        mamba=MambaConfig(d_model=8192, d_inner=16384, d_state=16, chunk=128),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, dispatch="sort"),
        moe_period=2,
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=8, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        pattern=("mamba", "mamba", "mamba", "attn",
                 "mamba", "mamba", "mamba", "mamba"),
        use_rope=False, kv_repeat=2,
        mamba=MambaConfig(d_model=64, d_inner=128, d_state=8, chunk=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=128, dispatch="sort"),
        moe_period=2,
    )
    return ArchSpec(NAME, full, smoke, skips={}, rules="fsdp", opt_bits=8)
