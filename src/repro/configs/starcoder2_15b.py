"""starcoder2-15b [dense] — 40L d=6144 48H (kv=4) d_ff=24576 vocab=49152,
GQA + RoPE, LayerNorm.  [arXiv:2402.19173; hf]
"""
from repro.models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "starcoder2-15b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=40, d_model=6144, num_heads=48,
        num_kv_heads=4, head_dim=128, d_ff=24576, vocab_size=49152,
        kv_repeat=4, norm="layernorm", act="gelu", rope_theta=1e5,
        gated_mlp=False,
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, head_dim=8, d_ff=128, vocab_size=512,
        kv_repeat=2, norm="layernorm", act="gelu", gated_mlp=False,
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP}, rules="fsdp")
