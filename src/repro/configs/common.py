"""Shared shape grid + ArchSpec plumbing for the assigned architectures.

Every arch module exports ``spec() -> ArchSpec`` with:
  config        the full published configuration (dry-run only — never
                materialised on CPU)
  smoke_config  a reduced same-family config for CPU smoke tests
  skips         {shape_name: reason} — e.g. long_500k on full-attention
  extras(shape) additional input ShapeDtypeStructs (modality stubs)

Shape grid (assignment): train_4k / prefill_32k / decode_32k / long_500k.
``decode_*``/``long_*`` lower ``serve_step`` (1 new token against a KV/state
cache of seq_len); ``prefill_32k`` lowers the forward pass at full length.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import Model, ModelConfig

SHAPES: dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

FULL_ATTN_SKIP = "pure full-attention arch: 500k decode cache/step budget " \
    "requires sub-quadratic family (see DESIGN.md §Arch-applicability)"


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    config: ModelConfig
    smoke_config: ModelConfig
    skips: dict
    rules: str = "default"              # 'default' | 'fsdp'
    opt_bits: int = 32                  # 8 => int8 optimizer state
    extras: Callable | None = None      # (shape_name, cfg) -> dict of SDS

    def model(self, smoke: bool = False) -> Model:
        return Model(self.smoke_config if smoke else self.config)

    def input_specs_for(self, cfg, sh: dict) -> dict:
        """ShapeDtypeStruct stand-ins for a shape dict (see SHAPES)."""
        B, S = sh["batch"], sh["seq"]
        name = sh.get("name", "")
        if sh["kind"] in ("train", "prefill"):
            spec = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
            if self.extras:
                spec.update(self.extras(name, cfg, B, S))
            return spec
        # decode: one token; caches/encoder states are built by the launcher
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}

    def input_specs(self, shape_name: str, smoke: bool = False) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.smoke_config if smoke else self.config
        sh = dict(SHAPES[shape_name])
        sh["name"] = shape_name
        if smoke:
            sh["batch"] = max(2, sh["batch"] // 128)
            sh["seq"] = min(sh["seq"], 64)
        return self.input_specs_for(cfg, sh)


def smoke_shrink(cfg: ModelConfig, **overrides) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)
