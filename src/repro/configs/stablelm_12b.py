"""stablelm-12b [dense] — 40L d=5120 32H (kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-12b; hf]
"""
from repro.models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "stablelm-12b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=40, d_model=5120, num_heads=32,
        num_kv_heads=8, head_dim=160, d_ff=13824, vocab_size=100352,
        kv_repeat=2, norm="layernorm",
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        kv_repeat=2, norm="layernorm",
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP}, rules="fsdp")
