"""seamless-m4t-medium [audio] — enc-dec 12L+12L d=1024 16H (kv=16)
d_ff=4096 vocab=256206.  [arXiv:2308.11596; hf]

Frontend stub per assignment: ``input_specs`` provides precomputed audio
frame embeddings (B, S_enc, d_model). Vocab is padded 256206 -> 256256
(multiple of 256) for sharding — standard embedding padding, noted in
EXPERIMENTS.md.
"""
import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "seamless-m4t-medium"
VOCAB_PAD = 256256   # 256206 padded to /256
ENC_FRAMES = 4096    # encoder frames for decode shapes


def _extras(shape_name, cfg, B, S):
    se = min(ENC_FRAMES, S) if shape_name.startswith(("decode", "long")) else S
    return {"frames": jax.ShapeDtypeStruct((B, se, cfg.d_model), jnp.bfloat16)}


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=12, d_model=1024, num_heads=16,
        num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=VOCAB_PAD,
        encoder_layers=12, frontend="audio", act="gelu", gated_mlp=False,
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_layers=2, frontend="audio", act="gelu", gated_mlp=False,
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP}, extras=_extras)
