"""qwen3-moe-235b-a22b [moe] — 94L d=4096 64H (kv=4) moe_ff=1536, 128e top-8,
vocab 151936, qk_norm.  [hf:Qwen/Qwen3-235B-A22B; hf]
"""
from repro.models.transformer import ModelConfig, MoEConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "qwen3-moe-235b-a22b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=94, d_model=4096, num_heads=64,
        num_kv_heads=4, head_dim=128, d_ff=1536, vocab_size=151936,
        qk_norm=True, kv_repeat=4, rope_theta=1e6,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536, dispatch="sort"),
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=512,
        qk_norm=True, kv_repeat=2,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=64, dispatch="sort"),
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP}, rules="fsdp",
                    opt_bits=8)
