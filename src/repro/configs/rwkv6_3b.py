"""rwkv6-3b "Finch" [ssm] — 32L d=2560, attn-free, d_ff=8960 vocab=65536,
data-dependent decay.  [arXiv:2404.05892; hf]

Runs long_500k: the recurrent state is O(1) in sequence length.
"""
from repro.models.rwkv import RWKVConfig
from repro.models.transformer import ModelConfig
from .common import ArchSpec

NAME = "rwkv6-3b"


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=32, d_model=2560, num_heads=40,
        num_kv_heads=40, d_ff=8960, vocab_size=65536,
        pattern=("rwkv",),
        rwkv=RWKVConfig(d_model=2560, d_ff=8960, head_size=64, chunk=32),
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=512,
        pattern=("rwkv",),
        rwkv=RWKVConfig(d_model=64, d_ff=128, head_size=16, chunk=8),
    )
    return ArchSpec(NAME, full, smoke, skips={})
