"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

from importlib import import_module

from .common import SHAPES, ArchSpec

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma2-27b": "gemma2_27b",
    "stablelm-12b": "stablelm_12b",
    "qwen3-0.6b": "qwen3_0_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_NAMES = list(_MODULES)


def get_arch(name: str) -> ArchSpec:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; options: {ARCH_NAMES}")
    return import_module(f"repro.configs.{_MODULES[name]}").spec()


def all_cells():
    """Every (arch, shape) pair with skip reasons resolved."""
    for name in ARCH_NAMES:
        s = get_arch(name)
        for shape in SHAPES:
            yield name, shape, s.skips.get(shape)


__all__ = ["get_arch", "all_cells", "ARCH_NAMES", "SHAPES", "ArchSpec"]
