"""qwen2-vl-72b [vlm] — 80L d=8192 64H (kv=8) d_ff=29568 vocab=152064,
M-RoPE (t/h/w sections 16/24/24 of head_dim/2), dynamic-resolution vision.
[arXiv:2409.12191; hf]

Per assignment, the modality frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, d_model) merged at the sequence
head, plus the 3-stream M-RoPE position ids.
"""
import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig
from .common import FULL_ATTN_SKIP, ArchSpec

NAME = "qwen2-vl-72b"
N_PATCHES = 1024      # frontend stub: patches per sample in train/prefill


def _extras(shape_name, cfg, B, S):
    if shape_name.startswith("decode") or shape_name.startswith("long"):
        return {"mrope_positions": jax.ShapeDtypeStruct((3, B, 1), jnp.int32)}
    n = min(N_PATCHES, S // 2)
    return {
        "input_embeds": jax.ShapeDtypeStruct((B, n, cfg.d_model), jnp.bfloat16),
        "mrope_positions": jax.ShapeDtypeStruct((3, B, S), jnp.int32),
    }


def spec() -> ArchSpec:
    full = ModelConfig(
        name=NAME, num_layers=80, d_model=8192, num_heads=64,
        num_kv_heads=8, head_dim=128, d_ff=29568, vocab_size=152064,
        kv_repeat=2, mrope_sections=(16, 24, 24), rope_theta=1e6,
        frontend="vision",
    )
    smoke = ModelConfig(
        name=NAME + "-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        kv_repeat=2, mrope_sections=(4, 2, 2), frontend="vision",
    )
    return ArchSpec(NAME, full, smoke,
                    skips={"long_500k": FULL_ATTN_SKIP}, rules="fsdp",
                    opt_bits=8, extras=_extras)
