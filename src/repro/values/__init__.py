"""SVPU value plane (paper §IV-E, §VI-I): weighted pattern mining.

The value plane threads (key, value) stream pairs through the existing
mining stack without adding dispatches: a weighted CSR carries one f32 per
directed edge aligned with ``graph.csr`` key storage
(``graph.with_edge_values`` / ``padded_value_rows``), aggregate plans stamp
the count leaf with a value disposition (``mining.plan.compile_pattern``'s
``aggregate=``), and the engine's aggregate leaf rides the same membership
kernels as the unweighted leaf (``kernels.ops.xlevel_agg``) — the value
lane is pure VPU work on tiles the count already visits.

This package holds the parts that belong to neither the graph nor the
kernels: per-(row, key) weight lookup against CSR storage (``plane``).
"""
from .plane import edge_value_lookup, prefix_scale

__all__ = ["edge_value_lookup", "prefix_scale"]
