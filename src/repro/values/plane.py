"""Value-plane lookups: per-(vertex, key) edge weights out of weighted CSR.

The aggregate leaf (``mining.engine.WaveRunner._agg_body``) needs two weight
sources the membership kernels cannot provide:

* **prefix-prefix edges** — pattern edges wholly inside the matched prefix
  (incl. the (0,1) feed edge). Their endpoints are per-item scalars, so the
  weight is one lookup per item, folded into the kernel's per-row ``scale``
  operand (``prefix_scale``).
* **carry-covered candidate edges** — when a leaf reuses the parent's
  survivor stream (``use_carry``) or has candidate-adjacent columns beyond
  its own INTER refs, the membership test that proved candidate ∈ N(v_c)
  happened at an *ancestor* level and its matched value was never captured.
  ``edge_value_lookup`` recovers it per (item, slot).

Both are the same primitive: a broadcast binary search of keys into each
source vertex's CSR window [indptr[u], indptr[u+1]) — O(log max_degree)
steps, branch-free, jit-safe (static step count from the graph's padded max
degree). A miss (key not adjacent, or SENTINEL padding) yields 0.0, which
downstream masking discards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph

__all__ = ["edge_value_lookup", "prefix_scale"]


def edge_value_lookup(g: CSRGraph, us, keys) -> jax.Array:
    """Weight of edge (us[i], keys[i, ...]) per element; 0.0 on a miss.

    ``us`` is (N,) int32 source vertices; ``keys`` is (N,) or (N, K) int32
    target keys (SENTINEL padding allowed). Returns f32 of ``keys``' shape.
    Lower-bound binary search into ``g.indices`` restricted to each source
    vertex's neighbor window; step count is static (log2 of the padded max
    degree), so the whole lookup traces into one fused XLA loop nest.
    """
    if g.edge_values is None:
        raise ValueError("graph has no edge_values (see with_edge_values)")
    us = jnp.asarray(us, jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    kk = keys if keys.ndim == 2 else keys[:, None]
    win_lo = g.indptr[us].astype(jnp.int32)
    win_hi = g.indptr[us + 1].astype(jnp.int32)
    lo = jnp.broadcast_to(win_lo[:, None], kk.shape)
    hi = jnp.broadcast_to(win_hi[:, None], kk.shape)
    last = g.indices.shape[0] - 1
    # lower_bound: invariant indices[win_lo:lo] < key <= indices[hi:win_hi];
    # once lo == hi the update is a no-op, so a static over-count of steps
    # is safe
    for _ in range(max(int(g.padded_max_degree).bit_length(), 1) + 1):
        active = lo < hi
        mid = (lo + hi) >> 1
        go_right = active & (g.indices[jnp.clip(mid, 0, last)] < kk)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    idx = jnp.clip(lo, 0, last)
    found = (lo < jnp.broadcast_to(win_hi[:, None], kk.shape)) \
        & (g.indices[idx] == kk)
    out = jnp.where(found, g.edge_values[idx], 0.0)
    return out if keys.ndim == 2 else out[:, 0]


def prefix_scale(g: CSRGraph, get: dict, edges) -> jax.Array:
    """Per-item product of prefix-prefix pattern-edge weights.

    ``get`` maps prefix column -> (N,) matched-vertex vector; ``edges`` is
    the leaf's ``agg_scale_edges``. Empty ``edges`` yields ones — the
    neutral scale operand."""
    cols = next(iter(get.values()))
    scale = jnp.ones((cols.shape[0],), jnp.float32)
    for i, j in edges:
        scale = scale * edge_value_lookup(g, get[i], get[j])
    return scale
