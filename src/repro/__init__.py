"""repro — IntersectX (stream-intersection graph mining) on TPU, in JAX.

Layers:
  core/        the paper's stream ISA as composable JAX ops
  graph/       CSR graph substrate (padded, degree-bucketed, bitmaps)
  mining/      pattern-enumeration applications + baselines
  kernels/     Pallas TPU kernels (validated in interpret mode on CPU)
  sparse/      S_VINTER applications: SpMM, TTV
  models/      assigned LM architecture zoo
  train/       training / serving runtime
  distributed/ sharding rules, compression, fault tolerance
  configs/     architecture configs
  launch/      mesh / dryrun / train / serve / mine entry points
"""

__version__ = "0.1.0"
