"""Deterministic synthetic twins of the paper's Table IV datasets.

No network access in this container, so each of the ten real graphs is
replaced by a generator parameterised to match its (#V, #E, skew). Relative
trends the paper relies on (denser graph => longer streams => larger
speedups; heavy-tail graphs => long max streams) are reproduced; absolute
counts obviously differ from the real graphs and EXPERIMENTS.md marks every
affected number.

``get_dataset(name, scale=1.0)`` returns a CSRGraph; ``scale`` < 1 shrinks
#V/#E proportionally so the big twins (youtube/patent/livejournal) stay
CPU-benchable. Table IV:
    citeseer 3.3K/4.5K | email-eu-core 1.0K/16.1K | bitcoinalpha 3.8K/24K
    gnutella 6K/21K    | haverford 1.4K/60K       | wiki-vote 7K/104K
    mico 96.6K/1.1M    | youtube 1.1M/3.0M        | patent 3.8M/16.5M
    livejournal 4.8M/42.9M
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from .csr import CSRGraph, build_csr
from .generators import erdos_renyi, powerlaw_cluster, rmat

# name -> (V, E, kind, params)
DATASETS: dict[str, dict] = {
    # small, low-skew citation graph
    "citeseer":      dict(v=3300, e=4500, kind="er", tag="C"),
    # small dense email graph, high average degree
    "email-eu-core": dict(v=1000, e=16100, kind="plc", m=16, tag="E"),
    "bitcoinalpha":  dict(v=3800, e=24000, kind="plc", m=6, tag="B"),
    "gnutella":      dict(v=6000, e=21000, kind="er", tag="G"),
    # very dense facebook subgraph
    "haverford":     dict(v=1400, e=60000, kind="plc", m=42, tag="F"),
    "wiki-vote":     dict(v=7000, e=104000, kind="plc", m=15, tag="W"),
    "mico":          dict(v=96600, e=1100000, kind="plc", m=11, tag="M"),
    # large heavy-tail graphs: vectorised RMAT twins
    "youtube":       dict(v=1 << 20, e=3000000, kind="rmat", scale=20, ef=3, tag="Y"),
    "patent":        dict(v=1 << 22, e=16500000, kind="rmat", scale=22, ef=4, tag="P"),
    "livejournal":   dict(v=1 << 22, e=42900000, kind="rmat", scale=22, ef=10, tag="L"),
}


def _edges_for(name: str, scale: float, seed: int) -> tuple[np.ndarray, int]:
    spec = DATASETS[name]
    v = max(int(spec["v"] * scale), 64)
    e = max(int(spec["e"] * scale), 64)
    kind = spec["kind"]
    if kind == "er":
        return erdos_renyi(v, e, seed=seed), v
    if kind == "plc":
        m = max(1, int(round(e / v)))
        return powerlaw_cluster(v, m, seed=seed), v
    if kind == "rmat":
        # pick the RMAT scale whose 2**s is closest >= v
        s = max(8, int(np.ceil(np.log2(v))))
        ef = max(1, int(round(e / (1 << s))))
        return rmat(s, edge_factor=ef, seed=seed), 1 << s
    raise ValueError(kind)


@lru_cache(maxsize=16)
def get_dataset(name: str, scale: float = 1.0, seed: int = 0) -> CSRGraph:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(DATASETS)}")
    edges, v = _edges_for(name, scale, seed)
    return build_csr(edges, num_vertices=v, undirected=True)


def dataset_stats(g: CSRGraph) -> dict:
    deg = np.asarray(g.degrees)
    return dict(V=g.num_vertices, E=g.num_edges // 2,
                avg_deg=float(deg.mean()), max_deg=int(deg.max()))
