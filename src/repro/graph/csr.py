"""Padded CSR graph representation (the paper's S_CSR register file, §III-A).

The paper loads three registers with S_CSR: CSR index (indptr), CSR edge list
(indices) and *CSR offset* — for every vertex v, the position within N(v) of
the smallest neighbor larger than v. The offset register exists purely to
serve symmetry breaking (scan only the `< v` or `> v` half of a neighbor
list); we keep it with identical semantics.

TPU adaptations:
  * ``indices`` is sentinel-padded to a LANE multiple so any window gather is
    in-bounds and masked loads are branch-free.
  * Every neighbor list is sorted ascending (required by all ISA ops).
  * ``degree_buckets`` groups vertices by padded-degree capacity so batched
    kernels waste bounded work on padding (the S_NESTINTER translation buffer
    becomes a static schedule over buckets — see core/nested.py).

Value plane (the paper's SVPU, §IV-E): ``edge_values`` is an optional f32
array aligned index-for-index with ``indices`` — entry i is the weight of
the directed edge whose destination is ``indices[i]``. ``build_csr``
threads caller weights through the exact same self-loop-drop / mirror /
dedup / lexsort permutation the keys take, so a (key, value) pair never
separates; ``padded_value_rows`` is the value twin of ``padded_rows``
(0.0 where keys are SENTINEL). Weighted graphs are staged once per
session like keys — the value plane adds no per-query uploads.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stream import LANE, SENTINEL, Stream, round_capacity, stream_from_slice


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed sparse row graph; all neighbor lists sorted ascending."""

    indptr: jax.Array    # (V+1,) int32
    indices: jax.Array   # (E_pad,) int32, sentinel-padded to LANE multiple
    offsets: jax.Array   # (V,)   int32: first idx in N(v) with neighbor > v
    degrees: jax.Array   # (V,)   int32
    # optional value plane: (E_pad,) f32 aligned with ``indices`` (0.0 pad)
    edge_values: jax.Array | None = None
    num_vertices: int = dataclasses.field(metadata=dict(static=True), default=0)
    num_edges: int = dataclasses.field(metadata=dict(static=True), default=0)
    max_degree: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def padded_max_degree(self) -> int:
        return round_capacity(self.max_degree)

    @property
    def weighted(self) -> bool:
        return self.edge_values is not None


def build_csr(edges: np.ndarray, num_vertices: int | None = None,
              undirected: bool = True,
              edge_values: np.ndarray | None = None) -> CSRGraph:
    """Build a CSRGraph from an (M, 2) int edge array (host side).

    Self-loops and duplicate edges are removed; for ``undirected`` graphs both
    directions are materialised (the paper's datasets are undirected simple
    graphs for mining purposes).

    ``edge_values`` (optional, (M,) float) rides the exact same permutation
    the keys take — self-loop drop, mirroring (both directions inherit the
    undirected weight), dedup and the final lexsort — so value i always
    belongs to the directed edge ``edges[i]`` of the finished CSR.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    values = None
    if edge_values is not None:
        values = np.asarray(edge_values, dtype=np.float32).reshape(-1)
        if values.shape[0] != edges.shape[0]:
            raise ValueError(
                f"edge_values has {values.shape[0]} entries for "
                f"{edges.shape[0]} edges")
    if num_vertices is None:
        num_vertices = int(edges.max()) + 1 if edges.size else 0
    keep = edges[:, 0] != edges[:, 1]                          # drop self loops
    edges = edges[keep]
    if values is not None:
        values = values[keep]
    if undirected:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
        if values is not None:
            values = np.concatenate([values, values], axis=0)
    # dedup
    key = edges[:, 0] * np.int64(num_vertices) + edges[:, 1]
    _, uniq = np.unique(key, return_index=True)
    edges = edges[uniq]
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    edges = edges[order]
    if values is not None:
        values = values[uniq][order]

    src, dst = edges[:, 0], edges[:, 1]
    degrees = np.bincount(src, minlength=num_vertices).astype(np.int32)
    indptr = np.zeros(num_vertices + 1, dtype=np.int32)
    np.cumsum(degrees, out=indptr[1:])
    num_edges = int(edges.shape[0])

    e_pad = round_capacity(num_edges + 1)  # +1: a window starting at E stays in-bounds
    indices = np.full(e_pad, SENTINEL, dtype=np.int32)
    indices[:num_edges] = dst.astype(np.int32)
    vals_pad = None
    if values is not None:
        vals_pad = np.zeros(e_pad, dtype=np.float32)
        vals_pad[:num_edges] = values

    # CSR offset register: first index in N(v) strictly greater than v.
    # With no self-loops this equals |{w in N(v): w < v}| — one bincount.
    offsets = np.bincount(src[dst < src], minlength=num_vertices).astype(np.int32)
    max_degree = int(degrees.max()) if num_vertices else 0

    return CSRGraph(
        indptr=jnp.asarray(indptr), indices=jnp.asarray(indices),
        offsets=jnp.asarray(offsets), degrees=jnp.asarray(degrees),
        edge_values=None if vals_pad is None else jnp.asarray(vals_pad),
        num_vertices=int(num_vertices), num_edges=num_edges,
        max_degree=max_degree)


def with_edge_values(g: CSRGraph, values: np.ndarray) -> CSRGraph:
    """Attach a value plane to an existing graph.

    ``values`` is (num_edges,) float, aligned with ``edge_list(g)`` — i.e.
    value i belongs to the i-th directed edge in CSR order. Returns a new
    graph sharing every key array with ``g``.
    """
    values = np.asarray(values, dtype=np.float32).reshape(-1)
    if values.shape[0] != g.num_edges:
        raise ValueError(
            f"need {g.num_edges} edge values, got {values.shape[0]}")
    vals_pad = np.zeros(g.indices.shape[0], dtype=np.float32)
    vals_pad[: g.num_edges] = values
    return dataclasses.replace(g, edge_values=jnp.asarray(vals_pad))


def neighbors_stream(g: CSRGraph, v, cap: int | None = None) -> Stream:
    """N(v) as a Stream (S_READ of an edge list). ``cap`` static; defaults to
    the graph's padded max degree."""
    cap = round_capacity(cap if cap is not None else g.max_degree)
    start = g.indptr[v]
    length = g.indptr[v + 1] - start
    return stream_from_slice(g.indices, start, length, cap)


def padded_rows(g: CSRGraph, vs: jax.Array, cap: int):
    """Gather neighbor lists of a vertex batch into a (B, cap) padded matrix.

    Returns (keys, lengths): keys sentinel-padded/truncated to ``cap``.
    This is the data-movement core of S_NESTINTER (§IV-F): the nested
    translator's per-key stream loads become one vectorised gather.
    """
    vs = jnp.asarray(vs, jnp.int32)
    starts = g.indptr[vs]
    lens = g.indptr[vs + 1] - starts
    col = jnp.arange(cap, dtype=jnp.int32)
    idx = starts[:, None] + col[None, :]
    idx = jnp.clip(idx, 0, g.indices.shape[0] - 1)
    rows = g.indices[idx]
    rows = jnp.where(col[None, :] < lens[:, None], rows, SENTINEL)
    return rows, jnp.minimum(lens, cap).astype(jnp.int32)


def padded_value_rows(g: CSRGraph, vs: jax.Array, cap: int) -> jax.Array:
    """Value twin of ``padded_rows``: gather each vertex's edge values into
    a (B, cap) f32 matrix, 0.0 where the key row holds SENTINEL padding.
    Row i column k is the weight of edge (vs[i], padded_rows(...)[0][i, k]).
    """
    if g.edge_values is None:
        raise ValueError("graph has no edge_values (see with_edge_values)")
    vs = jnp.asarray(vs, jnp.int32)
    starts = g.indptr[vs]
    lens = g.indptr[vs + 1] - starts
    col = jnp.arange(cap, dtype=jnp.int32)
    idx = starts[:, None] + col[None, :]
    idx = jnp.clip(idx, 0, g.edge_values.shape[0] - 1)
    vals = g.edge_values[idx]
    return jnp.where(col[None, :] < lens[:, None], vals, 0.0)


def degree_buckets(g: CSRGraph, base: int = LANE) -> list[tuple[int, np.ndarray]]:
    """Host-side: group vertices into power-of-two capacity buckets.

    Returns [(cap, vertex_ids), ...] with cap ∈ {base, 2·base, 4·base, ...},
    covering every vertex with degree > 0. Padding waste per bucket ≤ 2×.
    """
    deg = np.asarray(g.degrees)
    out: list[tuple[int, np.ndarray]] = []
    cap = base
    lo = 1
    while lo <= max(int(deg.max()) if deg.size else 0, 1):
        sel = np.nonzero((deg >= lo) & (deg <= cap))[0]
        if sel.size:
            out.append((cap, sel.astype(np.int32)))
        lo = cap + 1
        cap *= 2
    return out


def edge_list(g: CSRGraph) -> np.ndarray:
    """(E, 2) directed edge array (host), in CSR order."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)[: g.num_edges]
    src = np.repeat(np.arange(g.num_vertices, dtype=np.int32),
                    np.diff(indptr).astype(np.int64))
    return np.stack([src, indices], axis=1)
