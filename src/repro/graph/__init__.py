from .csr import CSRGraph, build_csr, neighbors_stream, padded_rows, degree_buckets
from .generators import erdos_renyi, powerlaw_cluster, rmat
from .datasets import get_dataset, DATASETS

__all__ = [
    "CSRGraph", "build_csr", "neighbors_stream", "padded_rows", "degree_buckets",
    "erdos_renyi", "powerlaw_cluster", "rmat", "get_dataset", "DATASETS",
]
