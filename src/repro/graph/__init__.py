from .csr import (CSRGraph, build_csr, degree_buckets, neighbors_stream,
                  padded_rows, padded_value_rows, with_edge_values)
from .generators import edge_weights, erdos_renyi, powerlaw_cluster, rmat
from .datasets import get_dataset, DATASETS

__all__ = [
    "CSRGraph", "build_csr", "neighbors_stream", "padded_rows", "degree_buckets",
    "padded_value_rows", "with_edge_values", "edge_weights",
    "erdos_renyi", "powerlaw_cluster", "rmat", "get_dataset", "DATASETS",
]
