"""Deterministic synthetic graph generators (offline stand-ins for Table IV).

The container has no network access, so the paper's ten real graphs are
replaced by deterministic generators parameterised to match each dataset's
(#V, #E, degree skew) — see ``datasets.py``. All generators take an explicit
seed and return a host edge array for ``build_csr``.
"""
from __future__ import annotations

import numpy as np


def edge_weights(edges: np.ndarray, seed: int = 0) -> np.ndarray:
    """Deterministic symmetric edge weights for a host edge array.

    Weight of {u, v} is a pure function of (min(u,v), max(u,v), seed) —
    identical no matter which direction or duplicate of the edge is asked,
    so weights survive ``build_csr``'s mirror/dedup untouched. Values are
    dyadic rationals in {0.25, 0.5, 0.75, 1.0}: products over a pattern's
    edges and small-graph sums stay exactly representable in f32, which is
    what lets the CI gate demand engine == oracle bit-for-bit.
    """
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    h = (lo * np.int64(0x9E3779B1) + hi * np.int64(0x85EBCA77)
         + np.int64(seed) * np.int64(0xC2B2AE3D)) & np.int64(0x7FFFFFFF)
    h ^= h >> 15
    return ((1 + (h & 3)).astype(np.float32)) * np.float32(0.25)


def erdos_renyi(n: int, m: int, seed: int = 0) -> np.ndarray:
    """~m undirected edges sampled uniformly (G(n, m) without replacement)."""
    rng = np.random.default_rng(seed)
    # over-sample then dedup; expected duplicates are tiny for sparse graphs
    k = int(m * 1.3) + 16
    src = rng.integers(0, n, size=k, dtype=np.int64)
    dst = rng.integers(0, n, size=k, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lo, hi = np.minimum(src, dst), np.maximum(src, dst)
    key = lo * n + hi
    _, uniq = np.unique(key, return_index=True)
    uniq = uniq[:m]
    return np.stack([lo[uniq], hi[uniq]], axis=1)


def powerlaw_cluster(n: int, m_per_node: int, seed: int = 0,
                     tri_p: float = 0.3) -> np.ndarray:
    """Holme–Kim style preferential attachment with triangle closure.

    Produces the heavy-tailed degree distributions of the paper's social
    graphs (wiki-vote, livejournal, youtube) and non-trivial triangle counts.
    Vectorised preferential attachment via the repeated-endpoint trick.
    """
    rng = np.random.default_rng(seed)
    m_per_node = max(1, m_per_node)
    targets = list(range(m_per_node))
    repeated: list[int] = list(range(m_per_node))
    edges = []
    for v in range(m_per_node, n):
        chosen = rng.choice(len(repeated), size=m_per_node, replace=False)
        vs = {repeated[c] for c in chosen}
        for u in vs:
            edges.append((v, u))
            repeated.append(u)
            repeated.append(v)
            if rng.random() < tri_p and len(vs) > 1:
                # close a triangle through a random existing neighbor of u
                w = repeated[rng.integers(0, len(repeated))]
                if w != v and w != u:
                    edges.append((v, w))
                    repeated.append(w)
                    repeated.append(v)
    del targets
    return np.asarray(edges, dtype=np.int64)


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> np.ndarray:
    """Graph500-style RMAT generator, fully vectorised. n = 2**scale."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        src_bit = r > (a + b)
        r2 = rng.random(m)
        thresh = np.where(src_bit, c / (c + (1 - a - b - c)), a / (a + b))
        dst_bit = r2 > thresh
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    keep = src != dst
    return np.stack([src[keep], dst[keep]], axis=1)


def clique_planted(n: int, m_background: int, clique_sizes: tuple[int, ...],
                   seed: int = 0) -> np.ndarray:
    """ER background with planted cliques — ground truth for k-clique tests."""
    rng = np.random.default_rng(seed)
    edges = [erdos_renyi(n, m_background, seed)]
    used = 0
    for k in clique_sizes:
        vs = np.arange(used, used + k, dtype=np.int64)
        used += k
        ii, jj = np.triu_indices(k, 1)
        edges.append(np.stack([vs[ii], vs[jj]], axis=1))
    del rng
    return np.concatenate(edges, axis=0)
