from .analysis import (HW, collective_bytes, roofline_report, parse_cost,
                       model_flops_6nd)

__all__ = ["HW", "collective_bytes", "roofline_report", "parse_cost",
           "model_flops_6nd"]
