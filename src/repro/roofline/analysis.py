"""Roofline terms from compiled dry-run artifacts (TPU v5e constants).

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s HBM)
  collective term = collective_bytes / (chips x ~50 GB/s/link ICI)

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are parsed from
the compiled HLO text (result-shape bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).

Scan caveat (measured, see tests/test_roofline.py): XLA's cost analysis
counts a while-loop body ONCE regardless of trip count. Every launcher
therefore passes ``scan_trips`` — the per-cell layer-scan trip count — and
we scale the scanned fraction via two-point calibration when provided, or
report the single-trip numbers with the multiplier attached.
"""
from __future__ import annotations

import dataclasses
import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip."""
    flops: float = 197e12          # bf16
    hbm_bw: float = 819e9          # bytes/s
    ici_bw: float = 50e9           # bytes/s/link
    hbm_bytes: float = 16e9


V5E = HW()

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """{collective op: summed result bytes} over the compiled module.

    '-start' variants (async) are counted once ('-done' carries no shape
    work). Bytes inside while-loop bodies are counted once per the scan
    caveat; launchers scale by trip count.
    """
    out: dict[str, int] = {}
    for shape_str, op in _COLL_RE.findall(hlo_text):
        out[op] = out.get(op, 0) + _shape_bytes(shape_str)
    return out


def parse_cost(cost: dict) -> dict:
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


def model_flops_6nd(n_params: int, n_tokens: int,
                    n_active: int | None = None) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) — the useful-compute yardstick."""
    return 6.0 * float(n_active if n_active is not None else n_params) \
        * float(n_tokens)


def roofline_report(flops: float, bytes_hbm: float, coll: dict[str, int],
                    chips: int, hw: HW = V5E, model_flops: float = 0.0,
                    per_device: bool = True) -> dict:
    """Three roofline terms in seconds + dominant bottleneck.

    ``per_device``: cost_analysis numbers on SPMD-partitioned modules are
    already per-device (the module is the per-device program); collective
    bytes parsed from HLO likewise. Set False if totals are global.
    """
    div = 1 if per_device else chips
    coll_total = float(sum(coll.values()))
    t_compute = flops / div / hw.flops
    t_memory = bytes_hbm / div / hw.hbm_bw
    t_coll = coll_total / div / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    out = dict(terms)
    out.update({
        "dominant": dominant,
        "collective_bytes": coll_total,
        "hlo_flops_per_chip": flops / div,
        "hlo_bytes_per_chip": bytes_hbm / div,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / chips / (flops / div)
                               if flops else 0.0),
        "bound_step_s": max(terms.values()),
        "roofline_fraction": (t_compute / max(terms.values())
                              if max(terms.values()) > 0 else 0.0),
    })
    return out
