"""Mamba (S6 selective SSM) layer — the Jamba hybrid's dominant block.

Training path: chunked selective scan — ``lax.scan`` over sequence chunks
carrying the (B, d_inner, d_state) state, with an *associative* scan inside
each chunk (prefix products of the diagonal decays), so the sequential
depth is S/Q instead of S while chunk temporaries stay O(Q·d_inner·d_state).
``d_inner`` carries the 'd_ff' logical axis => tensor-parallel over 'model',
which also divides the chunk temporaries by the TP degree.

Decode path: single-step state update, O(1) in sequence length — this is
why jamba runs the long_500k cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Axes, constrain
from .layers import ParamBuilder


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int                  # expand * d_model
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0              # 0 => d_model // 16
    chunk: int = 128

    @property
    def rank(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


def mamba_init(key, cfg: MambaConfig):
    b = ParamBuilder(key)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    b.w("in_proj", (d, 2 * di), Axes("embed", "d_ff"), fan_in=d)
    b.w("conv", (cfg.d_conv, di), Axes("conv", "d_ff"), fan_in=cfg.d_conv)
    b.w("x_proj", (di, cfg.rank + 2 * n), Axes("d_ff", "state"), fan_in=di)
    b.w("dt_proj", (cfg.rank, di), Axes("state", "d_ff"), fan_in=cfg.rank)
    b.w("A_log", (di, n), Axes("d_ff", "state"), fan_in=1)
    b.w("D", (di,), Axes("d_ff"), zero=True)
    b.w("out_proj", (di, d), Axes("d_ff", "embed"), fan_in=di)
    return b.build()


def _ssm_inputs(params, xz, cfg: MambaConfig, conv_state=None):
    """Shared front end: conv + projections.

    xz: (B, S, 2*di) from in_proj. Returns (x, z, dt, Bm, Cm, new_conv_state)
    where x is post-conv/silu (B, S, di)."""
    di, n = cfg.d_inner, cfg.d_state
    x, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv along S
    k = cfg.d_conv
    if conv_state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = conv_state                                   # (B, k-1, di)
    xp = jnp.concatenate([pad, x], axis=1)
    new_conv_state = xp[:, -(k - 1):] if k > 1 else pad
    conv = sum(xp[:, i: xp.shape[1] - (k - 1 - i)] * params["conv"][i]
               for i in range(k))
    x = jax.nn.silu(conv)
    proj = jnp.einsum("bsd,dr->bsr", x, params["x_proj"].astype(x.dtype))
    dt, Bm, Cm = jnp.split(proj, [cfg.rank, cfg.rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt,
                                    params["dt_proj"].astype(x.dtype)))
    return x, z, dt, Bm, Cm, new_conv_state


def mamba_apply(params, u, cfg: MambaConfig):
    """Training/prefill path. u: (B, S, d_model) -> (y, final_state)."""
    B, S, d = u.shape
    di, n, Q = cfg.d_inner, cfg.d_state, cfg.chunk
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(u.dtype))
    x, z, dt, Bm, Cm, _ = _ssm_inputs(params, xz, cfg)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (di, n)

    nq = -(-S // Q)
    pad = nq * Q - S
    def padq(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
    xq = padq(x).reshape(B, nq, Q, di).transpose(1, 0, 2, 3)
    dtq = padq(dt).reshape(B, nq, Q, di).transpose(1, 0, 2, 3)
    Bq = padq(Bm).reshape(B, nq, Q, n).transpose(1, 0, 2, 3)
    Cq = padq(Cm).reshape(B, nq, Q, n).transpose(1, 0, 2, 3)

    def chunk_step(h, blk):
        xc, dtc, bc, cc = blk                              # (B,Q,di), (B,Q,n)
        dtf = dtc.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * A)                # (B,Q,di,n)
        inp = (dtf * xc.astype(jnp.float32))[..., None] * bc.astype(jnp.float32)[:, :, None, :]
        def comb(lhs, rhs):
            return (rhs[0] * lhs[0], rhs[0] * lhs[1] + rhs[1])
        a_cum, b_cum = jax.lax.associative_scan(comb, (decay, inp), axis=1)
        hs = a_cum * h[:, None] + b_cum                    # (B,Q,di,n)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cc.astype(jnp.float32))
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, n), jnp.float32)
    hT, yq = jax.lax.scan(chunk_step, h0, (xq, dtq, Bq, Cq))
    y = yq.transpose(1, 0, 2, 3).reshape(B, nq * Q, di)[:, :S]
    y = (y + x.astype(jnp.float32) * params["D"]).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "d_ff")
    return jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(u.dtype)), hT


def mamba_decode(params, u, state, cfg: MambaConfig):
    """Single-token step. u: (B, 1, d); state: (ssm (B,di,n), conv (B,k-1,di))."""
    ssm, conv_state = state
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(u.dtype))
    x, z, dt, Bm, Cm, new_conv = _ssm_inputs(params, xz, cfg, conv_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                     # (B, di)
    decay = jnp.exp(dtf[..., None] * A)                    # (B, di, n)
    inp = (dtf * x[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0].astype(jnp.float32)[:, None, :]
    new_ssm = decay * ssm + inp
    y = jnp.einsum("bdn,bn->bd", new_ssm, Cm[:, 0].astype(jnp.float32))
    y = (y + x[:, 0].astype(jnp.float32) * params["D"]).astype(u.dtype)
    y = (y * jax.nn.silu(z[:, 0]))[:, None]
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(u.dtype))
    return out, (new_ssm, new_conv)


def mamba_init_state(cfg: MambaConfig, batch: int, dtype=jnp.bfloat16):
    return (jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
            jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype))
