"""Mixture-of-Experts with expert parallelism and two dispatch paths.

``dispatch='einsum'``  — capacity-based one-hot dispatch/combine einsums
(Switch/MaxText style). SPMD-clean: experts shard over 'model', tokens over
('pod','data'); XLA inserts the all-to-alls.

``dispatch='sort'``    — *stream dispatch* (beyond-paper tie-in): the
(token, expert) assignment is treated exactly like the paper's sorted key
streams — sort token ids by expert key, segment the sorted stream, run
experts on contiguous slices, scatter back. Removes the O(T·E·C) one-hot
matmuls; evaluated against 'einsum' in EXPERIMENTS.md §Perf.

Both paths are capacity-bounded (tokens above capacity drop to the residual
stream, standard practice) and add the load-balancing auxiliary loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Axes, constrain
from .layers import ParamBuilder


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert intermediate
    num_shared: int = 0            # shared (always-on) experts, deepseek-v2
    shared_d_ff: int | None = None
    capacity_factor: float = 1.25
    dispatch: str = "einsum"       # 'einsum' | 'sort'
    num_groups: int = 32           # sort dispatch: token groups (DP shards)
    router_zloss: float = 1e-3
    aux_loss: float = 1e-2


def moe_init(key, d_model: int, cfg: MoEConfig):
    b = ParamBuilder(key)
    E, F = cfg.num_experts, cfg.d_ff
    b.w("router", (d_model, E), Axes("embed", "experts"), fan_in=d_model)
    b.w("w_gate", (E, d_model, F), Axes("experts", "embed", "d_ff"), fan_in=d_model)
    b.w("w_up", (E, d_model, F), Axes("experts", "embed", "d_ff"), fan_in=d_model)
    b.w("w_down", (E, F, d_model), Axes("experts", "d_ff", "embed"), fan_in=F)
    if cfg.num_shared:
        sf = cfg.shared_d_ff or cfg.d_ff * cfg.num_shared
        b.w("sh_gate", (d_model, sf), Axes("embed", "d_ff"), fan_in=d_model)
        b.w("sh_up", (d_model, sf), Axes("embed", "d_ff"), fan_in=d_model)
        b.w("sh_down", (sf, d_model), Axes("d_ff", "embed"), fan_in=sf)
    return b.build()


def _router(params, x, cfg: MoEConfig):
    """x: (T, D) -> (gates (T,k), idx (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load balance: mean prob * mean assignment per expert
    T = x.shape[0]
    me = probs.mean(0)
    ce = jnp.zeros((cfg.num_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * cfg.top_k))
    aux = cfg.aux_loss * cfg.num_experts * jnp.sum(me * ce)
    zloss = cfg.router_zloss * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates.astype(x.dtype), idx, aux + zloss


def _expert_ffn(params, h, act=jax.nn.silu):
    """h: (E, C, D) -> (E, C, D), batched over the expert dim."""
    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"].astype(h.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"].astype(h.dtype))
    z = act(g) * u
    z = constrain(z, "experts", None, "d_ff")
    return jnp.einsum("ecf,efd->ecd", z, params["w_down"].astype(h.dtype))


def _capacity(T: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * T * cfg.top_k / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def _moe_einsum(params, x, cfg: MoEConfig):
    T, D = x.shape
    C = _capacity(T, cfg)
    gates, idx, aux = _router(params, x, cfg)
    # position of each (t, k) assignment within its expert's capacity
    onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.int32)  # (T,k,E)
    pos = jnp.cumsum(onehot.reshape(T * cfg.top_k, cfg.num_experts), axis=0
                     ).reshape(T, cfg.top_k, cfg.num_experts) * onehot - 1
    within_cap = (pos >= 0) & (pos < C)
    disp = (jax.nn.one_hot(pos.clip(0), C, dtype=x.dtype)
            * within_cap[..., None].astype(x.dtype)
            * onehot[..., None].astype(x.dtype))          # (T,k,E,C)
    disp_te = disp.sum(1)                                  # (T,E,C)
    h = jnp.einsum("td,tec->ecd", x, disp_te)
    h = constrain(h, "experts", None, "embed")
    out_e = _expert_ffn(params, h)
    comb = jnp.einsum("tkec,tk->tec", disp, gates)
    y = jnp.einsum("ecd,tec->td", out_e, comb)
    return y, aux


def _moe_sort(params, x, cfg: MoEConfig):
    """Global stream dispatch: sort the (expert, token) key stream once.

    The assignment list is the paper's key stream — keys = expert ids,
    values = token ids; sorting materialises per-expert contiguous slices.
    The sort is distributed (XLA lowers it to a sorting network with
    collective-permutes): measured to be cheaper than the grouped variant
    below at every assigned MoE cell (§Perf hillclimb B iteration log).
    """
    T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = _capacity(T, cfg)
    gates, idx, aux = _router(params, x, cfg)
    flat_e = idx.reshape(-1)                               # (T*K,) expert keys
    flat_t = jnp.tile(jnp.arange(T, dtype=jnp.int32)[:, None], (1, K)).reshape(-1)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)               # stream sort
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    ranks = jnp.arange(T * K, dtype=jnp.int32)
    first = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
    pos = ranks - first[se]
    keep = pos < C
    eidx = jnp.where(keep, se, E)                          # OOB => dropped
    h = jnp.zeros((E, C, D), x.dtype).at[
        eidx, jnp.where(keep, pos, 0)].set(x[st], mode="drop")
    h = constrain(h, "experts", None, "embed")
    out_e = _expert_ffn(params, h)
    contrib = out_e[jnp.where(keep, se, 0), jnp.where(keep, pos, 0)]
    contrib = contrib * (sg * keep)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[st].add(contrib)
    return y, aux


def _moe_gsort(params, x, cfg: MoEConfig):
    """Group-local stream dispatch (the paper's sorted-key-stream idea).

    The (token, expert) assignment list is a key stream — keys = expert ids,
    values = token ids. We sort it *within DP-shard-local groups* (the group
    dim is sharded over ('pod','data'), so every sort, rank and scatter is
    device-local — no distributed sort network, unlike a global argsort),
    scatter each group's tokens into its (E, C_g) capacity slots, and cross
    the network at the (group-sharded -> expert-sharded) transpose.

    Hypothesis REFUTED (§Perf hillclimb B): intended to kill the
    distributed-sort permutes, but the measured HLO shows XLA re-gathering
    the grouped buffers across the model axis — 5.3x MORE collective bytes
    than the global sort at qwen3-moe train_4k. Kept selectable
    (dispatch='gsort') as the documented negative result.
    """
    T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    G = cfg.num_groups if T % cfg.num_groups == 0 else 1
    Tg = T // G
    Cg = max(8, -(-int(cfg.capacity_factor * Tg * K / E) // 8) * 8)
    gates, idx, aux = _router(params, x, cfg)

    eg = idx.reshape(G, Tg * K)                            # per-group keys
    gg = gates.reshape(G, Tg * K)
    order = jnp.argsort(eg, axis=1, stable=True)           # LOCAL stream sort
    se = jnp.take_along_axis(eg, order, axis=1)            # (G, TgK) sorted
    st = (order // K).astype(jnp.int32)                    # token within group
    sg = jnp.take_along_axis(gg, order, axis=1)
    ranks = jnp.arange(Tg * K, dtype=jnp.int32)[None, :]
    first = jax.vmap(jnp.searchsorted)(se, jnp.broadcast_to(
        jnp.arange(E, dtype=jnp.int32), (G, E)))           # (G, E)
    pos = ranks - jnp.take_along_axis(first, se, axis=1)
    keep = pos < Cg
    grp = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[:, None],
                           (G, Tg * K))
    xg = x.reshape(G, Tg, D)
    gathered = jnp.take_along_axis(
        xg, st[..., None], axis=1)                         # (G, TgK, D) local
    eidx = jnp.where(keep, se, E)                          # OOB => dropped
    h = jnp.zeros((G, E, Cg, D), x.dtype).at[
        grp, eidx, jnp.where(keep, pos, 0)].set(gathered, mode="drop")
    h = constrain(h, "moe_groups", "experts", None, "embed")
    # ---- the all-to-all boundary: groups-sharded -> experts-sharded ----
    ht = h.transpose(1, 0, 2, 3).reshape(E, G * Cg, D)
    ht = constrain(ht, "experts", "moe_cap", "embed")
    out_e = _expert_ffn(params, ht)
    back = out_e.reshape(E, G, Cg, D).transpose(1, 0, 2, 3)
    back = constrain(back, "moe_groups", "experts", None, "embed")
    # ---- combine: gather each assignment's expert output, weighted ----
    contrib = back[grp, eidx, jnp.where(keep, pos, 0)]     # (G, TgK, D)
    contrib = contrib * (sg * keep)[..., None]
    y = jnp.zeros((G, Tg, D), x.dtype).at[grp, st].add(contrib)
    return y.reshape(T, D), aux


def moe_apply(params, x, cfg: MoEConfig):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    flat = x.reshape(B * S, D)
    if cfg.dispatch == "sort":
        y, aux = _moe_sort(params, flat, cfg)
    elif cfg.dispatch == "gsort":
        y, aux = _moe_gsort(params, flat, cfg)
    else:
        y, aux = _moe_einsum(params, flat, cfg)
    if cfg.num_shared:
        g = jnp.einsum("td,df->tf", flat, params["sh_gate"].astype(x.dtype))
        u = jnp.einsum("td,df->tf", flat, params["sh_up"].astype(x.dtype))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u,
                           params["sh_down"].astype(x.dtype))
    return y.reshape(B, S, D), aux
