"""RWKV-6 "Finch" time-mix / channel-mix blocks (attention-free SSM family).

Data-dependent decay: w_t is produced per token through a low-rank path from
the token-shifted input (the defining RWKV6 feature); state is matrix-valued
per head, S ∈ R^{head x head}, updated S_t = diag(w_t) S_{t-1} + k_t v_tᵀ.

Baseline training path: sequential ``lax.scan`` over time (compile-size O(1),
runtime O(S) sequential — recorded as the §Perf baseline for the rwkv cells).
``rwkv_apply_chunked`` is the hillclimbed path: chunk-parallel prefix-decay
formulation that replaces S sequential steps with S/Q chunk steps of dense
matmuls (intra-chunk attention-like matmul + carried state), the standard
linear-attention chunking.

Decode: O(1) single-step update — rwkv runs the long_500k cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Axes, constrain
from .layers import ParamBuilder


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    d_ff: int
    head_size: int = 64
    decay_lora: int = 64
    chunk: int = 32

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_size


def rwkv_init(key, cfg: RWKVConfig):
    b = ParamBuilder(key)
    d, H, hs = cfg.d_model, cfg.num_heads, cfg.head_size
    for name in ("maa_r", "maa_k", "maa_v", "maa_w", "maa_g"):
        b.w(name, (d,), Axes("embed"), zero=True)          # token-shift mix
    b.w("w_r", (d, d), Axes("embed", "heads"), fan_in=d)
    b.w("w_k", (d, d), Axes("embed", "heads"), fan_in=d)
    b.w("w_v", (d, d), Axes("embed", "heads"), fan_in=d)
    b.w("w_g", (d, d), Axes("embed", "heads"), fan_in=d)
    b.w("w_o", (d, d), Axes("heads", "embed"), fan_in=d)
    b.w("decay_base", (d,), Axes("embed"), zero=True)
    b.w("decay_lora_a", (d, cfg.decay_lora), Axes("embed", "state"), fan_in=d)
    b.w("decay_lora_b", (cfg.decay_lora, d), Axes("state", "embed"),
        fan_in=cfg.decay_lora)
    b.w("bonus", (H, hs), Axes("heads", "head_dim"), zero=True)  # time_faaaa
    b.ones("ln_x", (d,), Axes("embed"))
    return b.build()


def _timeshift(x, last=None):
    """x_{t-1} with zero (or cache) at t=0."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _rkvwg(params, x, x_prev):
    """Token-shift mixing + projections; returns per-head r,k,v,w,g."""
    def mix(maa):
        m = params[maa].astype(x.dtype)
        return x + (x_prev - x) * m
    r = jnp.einsum("bsd,de->bse", mix("maa_r"), params["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", mix("maa_k"), params["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", mix("maa_v"), params["w_v"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix("maa_g"),
                               params["w_g"].astype(x.dtype)))
    xw = mix("maa_w")
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", xw, params["decay_lora_a"].astype(x.dtype))),
        params["decay_lora_b"].astype(x.dtype))
    w = jnp.exp(-jnp.exp((params["decay_base"] + lora).astype(jnp.float32)))
    return r, k, v, w, g                                   # w: (B,S,d) in (0,1)


def _heads(t, H, hs):
    return t.reshape(t.shape[0], t.shape[1], H, hs)


def rwkv_apply(params, x, cfg: RWKVConfig, chunked: bool = True):
    """Time-mix block. x: (B, S, d) -> (y, final_state (B,H,hs,hs))."""
    B, S, d = x.shape
    H, hs = cfg.num_heads, cfg.head_size
    r, k, v, w, g = _rkvwg(params, x, _timeshift(x))
    r, k, v = (_heads(t, H, hs) for t in (r, k, v))
    w = _heads(w, H, hs)
    bonus = params["bonus"].astype(jnp.float32)

    if chunked:
        y, state = _wkv_chunked(r, k, v, w, bonus, cfg.chunk)
    else:
        y, state = _wkv_sequential(r, k, v, w, bonus)
    y = y.reshape(B, S, d)
    # group-norm per head (ln_x) then gate + output proj
    yf = y.astype(jnp.float32).reshape(B, S, H, hs)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = ((yf - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d) * params["ln_x"]
    y = yf.astype(x.dtype) * g
    y = constrain(y, "batch", "seq", "heads")
    return jnp.einsum("bsd,de->bse", y, params["w_o"].astype(x.dtype)), state


def _wkv_sequential(r, k, v, w, bonus):
    """Reference/baseline: scan over time. r,k,v,w: (B,S,H,hs)."""
    B, S, H, hs = r.shape

    def step(Sm, t):
        rt, kt, vt, wt = t                                  # (B,H,hs)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,hs,hs)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         Sm + bonus[..., None] * kv)
        Sm = wt[..., None] * Sm + kv
        return Sm, out

    S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    xs = tuple(t.transpose(1, 0, 2, 3).astype(jnp.float32) for t in (r, k, v, w))
    Sf, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3).reshape(B, S, H * hs), Sf


def _wkv_chunked(r, k, v, w, bonus, Q):
    """Chunk-parallel WKV: intra-chunk 'attention' matmul + carried state.

    Within a chunk, out_t = r_t · (decay-weighted Σ_{s<t} k_s v_sᵀ + bonus
    kv_t) decomposes into (a) a causal (Q x Q) pairwise-decay contraction
    and (b) one state-carry matmul per chunk. Numerically safe: every
    exponent is a log-decay difference over a *forward* interval, hence
    <= 0 — no overflow regardless of decay magnitude (underflow -> 0 is
    exact behaviour for fully-decayed history).
    """
    B, S, H, hs = r.shape
    nq = -(-S // Q)
    pad = nq * Q - S
    def padq(t, value=0.0):
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=value)
    # merge (B, H) into one axis. Hillclimb C tried sharding it over
    # ('data','model') ("bh" rule) to split the 40 heads that don't divide
    # the 16-way model axis: REFUTED — the per-layer reshard cost 30s of
    # all-to-alls against a 5s memory saving (EXPERIMENTS.md §Perf). The
    # merged dim therefore keeps the batch sharding (heads local), which
    # still halves peak vs the unmerged baseline via better fusion.
    def prep(t, value=0.0):
        q = padq(t, value).reshape(B, nq, Q, H, hs).transpose(1, 0, 3, 2, 4)
        q = q.reshape(nq, B * H, Q, hs).astype(jnp.float32)
        return constrain(q, None, "batch", None, None)
    rq, kq, vq = prep(r), prep(k), prep(v)
    wq = prep(w, 1.0)    # pad decay with 1: phantom steps don't touch state
    logw = jnp.log(jnp.maximum(wq, 1e-38))                  # (nq,BH,Q,hs)
    cum = jnp.cumsum(logw, axis=2)                          # inclusive Σ_{u<=s}
    bonus_m = jnp.tile(bonus, (B, 1))                       # (BH, hs)

    causal = jnp.tril(jnp.ones((Q, Q), bool), -1)           # strict s < q

    def chunk(Sm, blk):
        rc, kc, vc, lw, cw = blk                            # (BH,Q,hs)
        excl = cw - lw                                      # Σ_{u<q} log w_u
        # pairwise coefficient: Π_{u in (s, q-1]} w_u = exp(excl_q - cum_s) <= 1
        diff = excl[:, :, None, :] - cw[:, None, :, :]      # (BH,Q,Q,hs)
        att = jnp.einsum("bqk,bsk,bqsk->bqs", rc, kc,
                         jnp.exp(jnp.minimum(diff, 0.0)))
        att = jnp.where(causal, att, 0.0)
        intra = jnp.einsum("bqs,bsv->bqv", att, vc)
        diag = jnp.einsum("bqk,bk,bqk,bqv->bqv", rc, bonus_m, kc, vc)
        r_dec = rc * jnp.exp(excl)                          # excl <= 0: safe
        carry = jnp.einsum("bqk,bkv->bqv", r_dec, Sm)
        out = intra + diag + carry
        # state: S' = diag(Π_chunk w) S + Σ_s (Π_{u>s} w_u) k_s v_sᵀ
        total = cw[:, -1:]                                  # (BH,1,hs)
        k_carry = kc * jnp.exp(total - cw)                  # total<=cw: safe
        Sm = jnp.exp(total[:, 0])[:, :, None] * Sm + \
            jnp.einsum("bqk,bqv->bkv", k_carry, vc)
        return constrain(Sm, "batch", None, None), out

    S0 = jnp.zeros((B * H, hs, hs), jnp.float32)
    Sf, yq = jax.lax.scan(chunk, S0, (rq, kq, vq, logw, cum))
    y = yq.reshape(nq, B, H, Q, hs).transpose(1, 0, 3, 2, 4)
    y = y.reshape(B, nq * Q, H * hs)[:, :S]
    return y, Sf.reshape(B, H, hs, hs)


def rwkv_decode(params, x, state, cfg: RWKVConfig):
    """Single-token step. state = (S (B,H,hs,hs), x_prev (B,1,d))."""
    Sm, x_prev = state
    B = x.shape[0]
    H, hs = cfg.num_heads, cfg.head_size
    r, k, v, w, g = _rkvwg(params, x, x_prev)
    rt, kt, vt = (t.reshape(B, H, hs) for t in (r[:, 0], k[:, 0], v[:, 0]))
    wt = w[:, 0].reshape(B, H, hs)
    bonus = params["bonus"].astype(jnp.float32)
    kv = kt[..., :, None].astype(jnp.float32) * vt[..., None, :].astype(jnp.float32)
    out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                     Sm + bonus[..., None] * kv)
    Sm = wt[..., None] * Sm + kv
    y = out.reshape(B, 1, H * hs)
    yf = y.reshape(B, 1, H, hs)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = ((yf - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, 1, H * hs) * params["ln_x"]
    y = yf.astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", y, params["w_o"].astype(x.dtype))
    return out, (Sm, x)


def rwkv_channel_init(key, cfg: RWKVConfig):
    b = ParamBuilder(key)
    d, f = cfg.d_model, cfg.d_ff
    b.w("maa_k", (d,), Axes("embed"), zero=True)
    b.w("maa_r", (d,), Axes("embed"), zero=True)
    b.w("w_k", (d, f), Axes("embed", "d_ff"), fan_in=d)
    b.w("w_v", (f, d), Axes("d_ff", "embed"), fan_in=f)
    b.w("w_r", (d, d), Axes("embed", "heads"), fan_in=d)
    return b.build()


def rwkv_channel_apply(params, x, x_prev=None):
    xs = _timeshift(x, x_prev)
    xk = x + (xs - x) * params["maa_k"].astype(x.dtype)
    xr = x + (xs - x) * params["maa_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, params["w_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_v"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                  params["w_r"].astype(x.dtype)))
    return r * kv
