"""Shared transformer building blocks for the assigned architecture fleet.

Pure-function style: every block has ``<block>_init(key, ...) ->
(params, axes)`` and ``<block>_apply(params, x, ...)``. ``axes`` trees mirror
params with ``Axes`` leaves (logical names resolved by
repro.distributed.sharding at jit boundary).

Covers the whole assigned-architecture surface:
  GQA attention with qk-norm (qwen3), logit softcapping (gemma2),
  sliding-window masks (gemma2 local layers), RoPE and M-RoPE (qwen2-vl),
  MLA compressed-KV attention (deepseek-v2), blocked/online-softmax
  attention for long contexts, SwiGLU/GELU MLPs, RMSNorm/LayerNorm.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Axes, constrain


class ParamBuilder:
    """Accumulates (params, axes) pairs with fan-in scaled gaussian init."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def w(self, name: str, shape, axes: Axes, fan_in: int | None = None,
          zero: bool = False):
        self.key, sub = jax.random.split(self.key)
        if zero:
            arr = jnp.zeros(shape, self.dtype)
        else:
            scale = 1.0 / math.sqrt(fan_in if fan_in else shape[0])
            arr = (jax.random.normal(sub, shape, jnp.float32) * scale
                   ).astype(self.dtype)
        self.params[name] = arr
        self.axes[name] = axes
        return arr

    def ones(self, name: str, shape, axes: Axes):
        self.params[name] = jnp.ones(shape, self.dtype)
        self.axes[name] = axes

    def sub(self, name: str, params, axes):
        self.params[name] = params
        self.axes[name] = axes

    def build(self):
        return self.params, self.axes


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(key, d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": Axes("embed")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
               sections: tuple[int, ...] | None = None) -> jax.Array:
    """Rotary embedding. x: (B, S, ..., head_dim); positions: (B, S) for
    standard RoPE or (3, B, S) for M-RoPE (qwen2-vl), where ``sections``
    gives the per-stream frequency split of head_dim//2 (t, h, w)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 3:                              # M-RoPE
        assert sections is not None and sum(sections) == hd // 2
        parts = []
        off = 0
        for s, sec in enumerate(sections):
            ang = positions[s].astype(jnp.float32)[..., None] * freqs[off: off + sec]
            parts.append(ang)
            off += sec
        angles = jnp.concatenate(parts, axis=-1)         # (B, S, hd/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,hd/2)
    while angles.ndim < x.ndim:
        angles = angles[..., None, :]                    # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA family)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False          # qwen3
    softcap: float | None = None   # gemma2 logit softcapping
    window: int | None = None      # sliding-window (gemma2 local layers)
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl
    block_k: int = 1024            # online-softmax KV block
    blocked_threshold: int = 8192  # use blocked path when S_k exceeds this
    #                                (§Perf hillclimb A tried 2048: REFUTED —
    #                                at S=4096 the q re-reads raise HLO bytes
    #                                and peak; blocked stays the >8k path)


def gqa_init(key, cfg: AttnConfig):
    b = ParamBuilder(key)
    d, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b.w("wq", (d, H, hd), Axes("embed", "heads", "head_dim"), fan_in=d)
    b.w("wk", (d, Hk, hd), Axes("embed", "kv_heads", "head_dim"), fan_in=d)
    b.w("wv", (d, Hk, hd), Axes("embed", "kv_heads", "head_dim"), fan_in=d)
    b.w("wo", (H, hd, d), Axes("heads", "head_dim", "embed"), fan_in=H * hd)
    if cfg.qk_norm:
        b.ones("q_norm", (hd,), Axes("head_dim"))
        b.ones("k_norm", (hd,), Axes("head_dim"))
    return b.build()


def _qk_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def _mask(pos_q, pos_k, causal: bool, window: int | None):
    """(B, Sq, Sk) boolean allow-mask from (B, Sq)/(B, Sk) position vectors."""
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    full = jnp.broadcast_shapes(pq.shape, pk.shape)
    m = (pk <= pq) if causal else jnp.ones(full, bool)
    m = jnp.broadcast_to(m, full)
    if window is not None:
        m = m & (pq - pk < window)
    return m


def _sdpa_full(q, k, v, pos_q, pos_k, causal, window, softcap):
    """q: (B,Sq,Hk,G,hd), k/v: (B,Sk,Hk,hd). Materialised-scores path."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = _mask(pos_q, pos_k, causal, window)           # (B,Sq,Sk)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _sdpa_blocked(q, k, v, pos_q, pos_k, causal, window, softcap, block_k):
    """Online-softmax over KV blocks: O(block) memory, long-context path."""
    B, Sq, Hk, G, hd = q.shape
    Sk = k.shape[1]
    nb = -(-Sk // block_k)
    pad = nb * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(B, nb, block_k, Hk, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_k, Hk, hd).transpose(1, 0, 2, 3, 4)
    pb = pos_k.reshape(B, nb, block_k).transpose(1, 0, 2)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    def step(carry, blk):
        m, lse, acc = carry
        kt, vt, pk = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kt.astype(jnp.float32))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _mask(pos_q, pk, causal, window)
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = lse * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vt.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, Sq, hd), jnp.float32)
    (m, lse, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # (B,Sq,Hk,G,hd)


def attention(q, k, v, pos_q, pos_k, cfg: AttnConfig, causal: bool = True):
    """q: (B,Sq,H,hd) flat heads; k/v: (B,Sk,Hk,hd). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, hd)
    qg = constrain(qg, "batch", "seq", "kv_heads", "heads", "head_dim")
    if k.shape[1] > cfg.blocked_threshold:
        out = _sdpa_blocked(qg, k, v, pos_q, pos_k, causal, cfg.window,
                            cfg.softcap, cfg.block_k)
    else:
        out = _sdpa_full(qg, k, v, pos_q, pos_k, causal, cfg.window,
                         cfg.softcap)
    return out.reshape(B, Sq, H, hd)


def gqa_apply(params, x, positions, cfg: AttnConfig, causal: bool = True,
              kv_override=None, pos_k=None):
    """Self-attention (kv_override=None) or cross/cached attention."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
        pos_kv = positions
    else:
        k, v = kv_override
        pos_kv = pos_k
    if cfg.qk_norm:
        q = _qk_norm(q, params["q_norm"])
        k = _qk_norm(k, params["k_norm"]) if kv_override is None else k
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    if kv_override is None:
        k = apply_rope(k, pos_kv, cfg.rope_theta, cfg.mrope_sections)
    out = attention(q, k, v, positions if positions.ndim == 2 else positions[0],
                    pos_kv if pos_kv.ndim == 2 else pos_kv[0], cfg, causal)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype)), (k, v)


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): compressed-KV attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    num_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 1e4
    block_k: int = 1024
    blocked_threshold: int = 8192


def mla_init(key, cfg: MLAConfig):
    b = ParamBuilder(key)
    d, H = cfg.d_model, cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    b.w("wq", (d, H, qd), Axes("embed", "heads", "head_dim"), fan_in=d)
    b.w("w_dkv", (d, cfg.kv_lora_rank), Axes("embed", "state"), fan_in=d)
    b.w("w_kr", (d, cfg.qk_rope_dim), Axes("embed", "head_dim"), fan_in=d)
    b.w("w_uk", (cfg.kv_lora_rank, H, cfg.qk_nope_dim),
        Axes("state", "heads", "head_dim"), fan_in=cfg.kv_lora_rank)
    b.w("w_uv", (cfg.kv_lora_rank, H, cfg.v_head_dim),
        Axes("state", "heads", "head_dim"), fan_in=cfg.kv_lora_rank)
    b.w("wo", (H, cfg.v_head_dim, d), Axes("heads", "head_dim", "embed"),
        fan_in=H * cfg.v_head_dim)
    n, na = rmsnorm_init(None, cfg.kv_lora_rank)
    b.sub("kv_norm", n, na)
    return b.build()


def mla_compress(params, x, positions, cfg: MLAConfig):
    """x -> (c_kv, k_rope): the decode cache content (B,S,lora), (B,S,rope)."""
    c = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(x.dtype))
    c = rmsnorm(params["kv_norm"], c)
    kr = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(x.dtype))
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, kr


def mla_apply(params, x, positions, cfg: MLAConfig, cache=None, pos_k=None):
    """Training path: decompress K/V per head; cache path: absorbed decode.

    Absorbed decode (beyond-paper-standard MLA trick): fold W_uk into the
    query and W_uv into the output so attention runs directly over the
    compressed c_kv — the cache never expands.
    """
    B, S, d = x.shape
    H = cfg.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    if cache is None:
        c, kr = mla_compress(params, x, positions, cfg)
        pos_k = positions
    else:
        c, kr = cache
    # absorbed: q' = q_nope @ W_uk  -> score space = lora rank
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(x.dtype))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32), c.astype(jnp.float32))
         + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
         ) * scale
    mask = _mask(positions, pos_k, True, None)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    attn_c = jnp.einsum("bhst,btr->bshr", p.astype(c.dtype), c)
    out = jnp.einsum("bshr,rhk->bshk", attn_c, params["w_uv"].astype(x.dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, (c, kr)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, gated: bool = True):
    b = ParamBuilder(key)
    if gated:
        b.w("w_gate", (d_model, d_ff), Axes("embed", "d_ff"), fan_in=d_model)
    b.w("w_up", (d_model, d_ff), Axes("embed", "d_ff"), fan_in=d_model)
    b.w("w_down", (d_ff, d_model), Axes("d_ff", "embed"), fan_in=d_ff)
    return b.build()


def mlp_apply(params, x, act: str = "silu"):
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    h = constrain(h, "batch", "seq", "d_ff")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
