from .transformer import Model, ModelConfig, MoEConfig
from . import layers, moe, mamba, rwkv

__all__ = ["Model", "ModelConfig", "MoEConfig", "layers", "moe", "mamba", "rwkv"]
