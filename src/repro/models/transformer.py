"""Unified model covering the 10 assigned architectures.

One decoder (or encoder-decoder) skeleton, specialised per arch by config:
layer *pattern units* (e.g. jamba's (m,m,m,a,m,m,m,m)) are scanned — params
are stacked per repeating unit so the HLO is O(unit), not O(depth) — with
heterogeneous kinds (attn/mamba/rwkv), per-position sliding windows
(gemma2 local/global), MoE periods (jamba every-other, deepseek all-but-
first), shared experts, MLA, qk-norm, softcap, M-RoPE, KV-head replication
for TP, and encoder-decoder wiring (seamless-m4t) all driven by ModelConfig.

Serving: attention layers hold (K, V) rings sharded over 'kv_seq' ('model'
axis) — XLA SPMD turns the masked softmax over the sharded KV length into
partial max/sum all-reduces, i.e. flash-decoding's log-sum-exp combine.
Mamba/rwkv layers hold O(1) recurrent state — which is why only those archs
run the long_500k cell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Axes, constrain
from . import layers as L
from .layers import AttnConfig, MLAConfig, ParamBuilder, apply_rope
from .mamba import MambaConfig, mamba_apply, mamba_decode, mamba_init
from .moe import MoEConfig, moe_apply, moe_init
from .rwkv import (RWKVConfig, rwkv_apply, rwkv_channel_apply,
                   rwkv_channel_init, rwkv_decode, rwkv_init)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # layer pattern: repeating unit of kinds; len must divide covered layers
    pattern: tuple[str, ...] = ("attn",)
    # attention flavour
    attention: str = "gqa"                 # 'gqa' | 'mla'
    qk_norm: bool = False
    softcap: float | None = None
    # per-position-in-unit sliding windows (None = global); len == len(pattern)
    windows: tuple | None = None
    use_rope: bool = True
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] | None = None
    kv_repeat: int = 1                     # replicate KV heads for TP
    # MoE
    moe: MoEConfig | None = None
    moe_period: int = 1                    # MoE every Nth layer
    first_dense: int = 0                   # leading dense layers (deepseek)
    first_dense_ff: int | None = None
    # alternative blocks
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    rwkv: RWKVConfig | None = None
    # encoder-decoder (seamless): encoder shares d_model/heads
    encoder_layers: int = 0
    frontend: str | None = None            # 'audio' | 'vision' stub marker
    # numerics / runtime
    norm: str = "rmsnorm"                  # 'rmsnorm' | 'layernorm'
    gated_mlp: bool = True                 # False: classic 2-matrix FFN
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False
    remat: str = "minimal"                 # 'none' | 'minimal' | 'dots'
    blocked_threshold: int = 8192
    block_k: int = 1024
    logit_softcap: float | None = None     # gemma2 final softcap
    loss_chunk: int = 512                  # fused/chunked cross-entropy: the
    #                                        (B,S,V) logits tensor is never
    #                                        materialised (see Model.loss)
    unroll_units: bool = False             # roofline calibration: unroll the
    #                                        layer scan (cost_analysis counts
    #                                        a while body once)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def attn_cfg(self, window=None) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads, head_dim=self.hd,
            qk_norm=self.qk_norm, softcap=self.softcap, window=window,
            rope_theta=self.rope_theta, mrope_sections=self.mrope_sections,
            block_k=self.block_k, blocked_threshold=self.blocked_threshold)

    @property
    def unit(self) -> tuple[str, ...]:
        return self.pattern

    @property
    def num_units(self) -> int:
        n = self.num_layers - self.first_dense
        assert n % len(self.unit) == 0, \
            f"{self.name}: {n} layers not divisible by unit {self.unit}"
        return n // len(self.unit)

    def is_moe_layer(self, layer_idx: int) -> bool:
        return (self.moe is not None and layer_idx >= self.first_dense
                and (layer_idx - self.first_dense) % self.moe_period == 0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": Axes("embed")}


def _layer_init(key, cfg: ModelConfig, kind: str, layer_idx: int,
                cross: bool = False):
    b = ParamBuilder(key)
    k1, k2, k3 = jax.random.split(key, 3)
    n1, a1 = _norm_init(cfg.d_model)
    b.sub("norm1", n1, a1)
    if kind == "attn":
        if cfg.attention == "mla":
            p, a = L.mla_init(k1, cfg.mla)
        else:
            p, a = L.gqa_init(k1, cfg.attn_cfg())
        b.sub("attn", p, a)
    elif kind == "mamba":
        p, a = mamba_init(k1, cfg.mamba)
        b.sub("mamba", p, a)
    elif kind == "rwkv":
        p, a = rwkv_init(k1, cfg.rwkv)
        b.sub("rwkv", p, a)
    else:
        raise ValueError(kind)
    if cross:
        nc, ac = _norm_init(cfg.d_model)
        b.sub("norm_cross", nc, ac)
        pc, axc = L.gqa_init(k3, cfg.attn_cfg())
        b.sub("cross", pc, axc)
    n2, a2 = _norm_init(cfg.d_model)
    b.sub("norm2", n2, a2)
    if kind == "rwkv":
        p, a = rwkv_channel_init(k2, cfg.rwkv)
    elif cfg.is_moe_layer(layer_idx):
        p, a = moe_init(k2, cfg.d_model, cfg.moe)
    else:
        p, a = L.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp)
    b.sub("ffn", p, a)
    return b.build()


def _stack_units(key, cfg: ModelConfig, num_units: int, unit_offset: int,
                 cross: bool = False):
    """Init each unit then tree-stack: leaves get a leading (G,) axis."""
    keys = jax.random.split(key, num_units)

    def unit_init(k):
        ks = jax.random.split(k, len(cfg.unit))
        ps, axs = {}, {}
        for i, kind in enumerate(cfg.unit):
            p, a = _layer_init(ks[i], cfg, kind, unit_offset + i, cross=cross)
            ps[f"l{i}"] = p
            axs[f"l{i}"] = a
        return ps, axs

    stacked = [unit_init(k)[0] for k in keys]
    _, axes = unit_init(keys[0])
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    axes = jax.tree.map(lambda a: Axes(None, *a), axes,
                        is_leaf=lambda x: isinstance(x, Axes))
    return params, axes


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- init ----------------
    def init(self, key):
        cfg = self.cfg
        b = ParamBuilder(key)
        b.w("embed", (cfg.vocab_size, cfg.d_model), Axes("vocab", "embed"),
            fan_in=cfg.d_model)
        if not cfg.tie_embeddings:
            b.w("unembed", (cfg.d_model, cfg.vocab_size),
                Axes("embed", "vocab"), fan_in=cfg.d_model)
        kf, kd, ke = jax.random.split(b.key, 3)
        if cfg.first_dense:
            dense_cfg = dataclasses.replace(
                cfg, moe=None, d_ff=cfg.first_dense_ff or cfg.d_ff)
            for i in range(cfg.first_dense):
                p, a = _layer_init(jax.random.fold_in(kd, i), dense_cfg,
                                   "attn", i)
                b.sub(f"dense{i}", p, a)
        p, a = _stack_units(kf, cfg, cfg.num_units, cfg.first_dense,
                            cross=bool(cfg.encoder_layers))
        b.sub("units", p, a)
        if cfg.encoder_layers:
            enc_cfg = dataclasses.replace(cfg, moe=None, pattern=("attn",),
                                          windows=None)
            pe, ae = _stack_units(ke, enc_cfg, cfg.encoder_layers, 0)
            b.sub("encoder", pe, ae)
            ne, nea = _norm_init(cfg.d_model)
            b.sub("enc_norm", ne, nea)
        nf, nfa = _norm_init(cfg.d_model)
        b.sub("final_norm", nf, nfa)
        return b.build()

    # ---------------- shared pieces ----------------
    def _norm(self, p, x):
        if self.cfg.norm == "layernorm":
            dt = x.dtype
            xf = x.astype(jnp.float32)
            mu = xf.mean(-1, keepdims=True)
            var = xf.var(-1, keepdims=True)
            return (((xf - mu) * jax.lax.rsqrt(var + self.cfg.norm_eps))
                    * p["scale"]).astype(dt)
        return L.rmsnorm(p, x, self.cfg.norm_eps)

    def _embed(self, params, tokens):
        emb = params["embed"].astype(self.cfg.dtype)
        return jnp.take(emb, tokens, axis=0) * math.sqrt(self.cfg.d_model)

    def _logits(self, params, x):
        w = (params["embed"].astype(self.cfg.dtype).T
             if self.cfg.tie_embeddings
             else params["unembed"].astype(self.cfg.dtype))
        logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
        if self.cfg.logit_softcap:
            c = self.cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        return constrain(logits, "batch", "seq", "vocab")

    def _window_for(self, pos_in_unit: int):
        return None if self.cfg.windows is None else self.cfg.windows[pos_in_unit]

    def _self_attn(self, p, h, positions, window, causal=True):
        cfg = self.cfg
        if cfg.attention == "mla":
            y, kv = L.mla_apply(p, h, positions, cfg.mla)
            return y, kv
        acfg = cfg.attn_cfg(window)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
        if acfg.qk_norm:
            q = L._qk_norm(q, p["q_norm"])
            k = L._qk_norm(k, p["k_norm"])
        if cfg.use_rope:
            q = apply_rope(q, positions, acfg.rope_theta, acfg.mrope_sections)
            k = apply_rope(k, positions, acfg.rope_theta, acfg.mrope_sections)
        if cfg.kv_repeat > 1:
            k = jnp.repeat(k, cfg.kv_repeat, axis=2)
            v = jnp.repeat(v, cfg.kv_repeat, axis=2)
        pos2d = positions if positions.ndim == 2 else positions[0]
        out = L.attention(q, k, v, pos2d, pos2d, acfg, causal=causal)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(h.dtype))
        return y, (k, v)

    def _cross_attn(self, p, h, positions, enc_states, enc_pos):
        cfg = self.cfg
        acfg = cfg.attn_cfg()
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", enc_states, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_states, p["wv"].astype(h.dtype))
        if cfg.kv_repeat > 1:
            k = jnp.repeat(k, cfg.kv_repeat, axis=2)
            v = jnp.repeat(v, cfg.kv_repeat, axis=2)
        pos2d = positions if positions.ndim == 2 else positions[0]
        out = L.attention(q, k, v, pos2d, enc_pos, acfg, causal=False)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(h.dtype))

    def _ffn(self, p, h, aux):
        cfg = self.cfg
        if "router" in p:
            y, a = moe_apply(p, h, cfg.moe)
            return y, aux + a
        if "maa_k" in p:                                   # rwkv channel mix
            return rwkv_channel_apply(p, h), aux
        return L.mlp_apply(p, h, cfg.act), aux

    # ---------------- training forward ----------------
    def _unit_fwd(self, uparams, x, positions, enc_states=None, enc_pos=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.unit):
            p = uparams[f"l{i}"]
            h = self._norm(p["norm1"], x)
            if kind == "attn":
                y, _ = self._self_attn(p["attn"], h, positions,
                                       self._window_for(i))
            elif kind == "mamba":
                y, _ = mamba_apply(p["mamba"], h, cfg.mamba)
            else:
                y, _ = rwkv_apply(p["rwkv"], h, cfg.rwkv)
            x = x + y
            if enc_states is not None:
                hc = self._norm(p["norm_cross"], x)
                x = x + self._cross_attn(p["cross"], hc, positions,
                                         enc_states, enc_pos)
            h = self._norm(p["norm2"], x)
            y, aux = self._ffn(p["ffn"], h, aux)
            x = x + y
            x = constrain(x, "batch", "seq", "embed")
        return x, aux

    def _maybe_remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    def _encode(self, params, batch):
        cfg = self.cfg
        x = batch["frames"].astype(cfg.dtype)              # (B, Se, d) stub
        B, Se, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

        def body(carry, up):
            x = carry
            p = up["l0"]
            h = self._norm(p["norm1"], x)
            y, _ = self._self_attn(p["attn"], h, pos, None, causal=False)
            x = x + y
            h = self._norm(p["norm2"], x)
            y, _ = self._ffn(p["ffn"], h, jnp.zeros((), jnp.float32))
            return constrain(x + y, "batch", "seq", "embed"), None

        if cfg.unroll_units:
            rb = self._maybe_remat(body)
            for g in range(cfg.encoder_layers):
                x, _ = rb(x, jax.tree.map(lambda t: t[g], params["encoder"]))
        else:
            x, _ = jax.lax.scan(self._maybe_remat(body), x, params["encoder"])
        return self._norm(params["enc_norm"], x), pos

    def _positions(self, batch, tokens):
        B, S = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if self.cfg.mrope_sections is not None and "mrope_positions" in batch:
            positions = batch["mrope_positions"]
        return positions

    def apply(self, params, batch):
        x, aux = self.apply_hidden(params, batch)
        return self._logits(params, x), aux

    def _dense_layer_fwd(self, p, x, positions):
        h = self._norm(p["norm1"], x)
        y, _ = self._self_attn(p["attn"], h, positions, None)
        x = x + y
        h = self._norm(p["norm2"], x)
        y, aux = self._ffn(p["ffn"], h, jnp.zeros((), jnp.float32))
        return constrain(x + y, "batch", "seq", "embed"), aux

    def apply_hidden(self, params, batch):
        """Forward up to the final norm (no logits) — shared by loss()."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if "input_embeds" in batch:
            ie = batch["input_embeds"].astype(x.dtype)
            x = jnp.concatenate([ie, x[:, ie.shape[1]:]], axis=1)
        positions = self._positions(batch, tokens)
        enc_states = enc_pos = None
        if cfg.encoder_layers:
            enc_states, enc_pos = self._encode(params, batch)
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.first_dense):
            x, a = self._dense_layer_fwd(params[f"dense{i}"], x, positions)
            aux += a

        def body(carry, up):
            x, aux = carry
            x, a = self._unit_fwd(up, x, positions, enc_states, enc_pos)
            return (x, aux + a), None

        if cfg.unroll_units:
            rb = self._maybe_remat(body)
            for g in range(cfg.num_units):
                up = jax.tree.map(lambda t: t[g], params["units"])
                (x, aux), _ = rb((x, aux), up)
        else:
            (x, aux), _ = jax.lax.scan(self._maybe_remat(body), (x, aux),
                                       params["units"])
        return self._norm(params["final_norm"], x), aux

    def loss(self, params, batch):
        """Chunked (fused) cross-entropy: logits are produced and reduced one
        sequence chunk at a time inside a remat'd scan, so the (B, S, V)
        tensor never exists — the train-cell memory spike of big-vocab archs
        disappears (EXPERIMENTS.md §Perf, hillclimb A)."""
        cfg = self.cfg
        x, aux = self.apply_hidden(params, batch)
        targets = batch["targets"]
        B, S, D = x.shape
        C = min(cfg.loss_chunk, S)
        if S % C != 0:
            C = S                                   # irregular: single chunk
        nc = S // C
        w = (params["embed"].astype(cfg.dtype).T if cfg.tie_embeddings
             else params["unembed"].astype(cfg.dtype))

        def chunk_nll(xc, tc):
            logits = jnp.einsum("bsd,dv->bsv", xc, w).astype(jnp.float32)
            if cfg.logit_softcap:
                logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
            logits = constrain(logits, "batch", "seq", "vocab")
            valid = tc >= 0
            tgt = jnp.where(valid, tc, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
            return jnp.sum((logz - gold) * valid), jnp.sum(valid)

        chunk_nll = jax.checkpoint(chunk_nll)
        # python loop (not lax.scan): keeps every chunk's FLOPs visible to
        # the roofline cost analysis (a scan body is counted once) while the
        # accumulation chain + remat keep only one chunk's logits live.
        tot = jnp.zeros((), jnp.float32)
        n = jnp.zeros((), jnp.int32)
        for i in range(nc):
            s, k = chunk_nll(x[:, i * C:(i + 1) * C], targets[:, i * C:(i + 1) * C])
            tot = tot + s
            n = n + k
        return tot / jnp.maximum(n, 1) + aux

    # ---------------- serving: cache / prefill / decode ----------------
    def init_cache(self, batch: int, max_len: int):
        """Cache pytree + axes for one decode stream batch."""
        cfg = self.cfg
        G = cfg.num_units
        caches, axes = {}, {}
        Hk = cfg.num_kv_heads * cfg.kv_repeat
        for i, kind in enumerate(cfg.unit):
            if kind == "attn" and cfg.attention == "mla":
                m = cfg.mla
                caches[f"l{i}"] = {
                    "c": jnp.zeros((G, batch, max_len, m.kv_lora_rank), cfg.dtype),
                    "kr": jnp.zeros((G, batch, max_len, m.qk_rope_dim), cfg.dtype)}
                axes[f"l{i}"] = {
                    "c": Axes(None, "batch", "kv_seq", None),
                    "kr": Axes(None, "batch", "kv_seq", None)}
            elif kind == "attn":
                # NOTE: caches hold the UNREPEATED kv heads — kv_repeat only
                # exists so training activations shard over 'model'; decode
                # shards the cache over 'kv_seq' instead, and GQA grouping
                # attends to raw kv heads directly (4x less cache for
                # kv_repeat=4 archs).
                hkc = cfg.num_kv_heads
                caches[f"l{i}"] = {
                    "k": jnp.zeros((G, batch, max_len, hkc, cfg.hd), cfg.dtype),
                    "v": jnp.zeros((G, batch, max_len, hkc, cfg.hd), cfg.dtype)}
                axes[f"l{i}"] = {
                    "k": Axes(None, "batch", "kv_seq", "kv_heads", None),
                    "v": Axes(None, "batch", "kv_seq", "kv_heads", None)}
            elif kind == "mamba":
                mc = cfg.mamba
                caches[f"l{i}"] = {
                    "ssm": jnp.zeros((G, batch, mc.d_inner, mc.d_state), jnp.float32),
                    "conv": jnp.zeros((G, batch, mc.d_conv - 1, mc.d_inner), cfg.dtype)}
                axes[f"l{i}"] = {
                    "ssm": Axes(None, "batch", "d_ff", None),
                    "conv": Axes(None, "batch", None, "d_ff")}
            else:  # rwkv
                rc = cfg.rwkv
                caches[f"l{i}"] = {
                    "S": jnp.zeros((G, batch, rc.num_heads, rc.head_size,
                                    rc.head_size), jnp.float32),
                    "x_prev": jnp.zeros((G, batch, 1, cfg.d_model), cfg.dtype)}
                axes[f"l{i}"] = {
                    "S": Axes(None, "batch", None, None, None),
                    "x_prev": Axes(None, "batch", None, "embed")}
        return caches, axes

    def _attn_decode(self, p, h, pos, cache, window):
        """One-token attention against the (seq-sharded) cache."""
        cfg = self.cfg
        acfg = cfg.attn_cfg(window)
        B = h.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        if cfg.attention == "mla":
            c_t, kr_t = L.mla_compress(p, h, positions, cfg.mla)
            c = jax.lax.dynamic_update_slice(cache["c"], c_t, (0, pos, 0))
            kr = jax.lax.dynamic_update_slice(cache["kr"], kr_t, (0, pos, 0))
            pos_k = jnp.broadcast_to(
                jnp.arange(c.shape[1], dtype=jnp.int32), (B, c.shape[1]))
            y, _ = L.mla_apply(p, h, positions, cfg.mla, cache=(c, kr),
                               pos_k=pos_k)
            return y, {"c": c, "kr": kr}
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
        if acfg.qk_norm:
            q = L._qk_norm(q, p["q_norm"])
            k = L._qk_norm(k, p["k_norm"])
        if cfg.use_rope:
            mp = (jnp.broadcast_to(positions, (3, B, 1))
                  if cfg.mrope_sections is not None else positions)
            q = apply_rope(q, mp, acfg.rope_theta, acfg.mrope_sections)
            k = apply_rope(k, mp, acfg.rope_theta, acfg.mrope_sections)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        kc = constrain(kc, "batch", "kv_seq", "kv_heads", None)
        vc = constrain(vc, "batch", "kv_seq", "kv_heads", None)
        S = kc.shape[1]
        pos_k = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out = L.attention(q, kc, vc, positions, pos_k,
                          dataclasses.replace(acfg, blocked_threshold=1 << 30),
                          causal=True)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(h.dtype))
        return y, {"k": kc, "v": vc}

    def decode_step(self, params, token, pos, caches, enc_states=None,
                    enc_pos=None):
        """token: (B, 1) int32; pos: scalar int32 — returns (logits, caches)."""
        cfg = self.cfg
        x = self._embed(params, token)
        B = token.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        # first_dense layers (deepseek layer 0) carry their own cache entries
        # under caches["dense"] (see init_dense_cache).
        dense_caches = caches.get("dense", {})
        new_dense = {}
        for i in range(cfg.first_dense):
            p = params[f"dense{i}"]
            h = self._norm(p["norm1"], x)
            y, c = self._attn_decode(p["attn"], h, pos, dense_caches[f"d{i}"],
                                     None)
            x = x + y
            h = self._norm(p["norm2"], x)
            y, _ = self._ffn(p["ffn"], h, jnp.zeros((), jnp.float32))
            x = x + y
            new_dense[f"d{i}"] = c

        def body(x, scanned):
            up, cache = scanned
            new_cache = {}
            for i, kind in enumerate(cfg.unit):
                p = up[f"l{i}"]
                h = self._norm(p["norm1"], x)
                if kind == "attn":
                    y, c = self._attn_decode(p["attn"], h, pos, cache[f"l{i}"],
                                             self._window_for(i))
                elif kind == "mamba":
                    y, st = mamba_decode(p["mamba"], h,
                                         (cache[f"l{i}"]["ssm"],
                                          cache[f"l{i}"]["conv"]),
                                         cfg.mamba)
                    c = {"ssm": st[0], "conv": st[1]}
                else:
                    y, st = rwkv_decode(p["rwkv"], h,
                                        (cache[f"l{i}"]["S"],
                                         cache[f"l{i}"]["x_prev"]),
                                        cfg.rwkv)
                    c = {"S": st[0], "x_prev": st[1]}
                x = x + y
                if enc_states is not None:
                    hc = self._norm(p["norm_cross"], x)
                    x = x + self._cross_attn(p["cross"], hc, positions,
                                             enc_states, enc_pos)
                h = self._norm(p["norm2"], x)
                y, _ = self._ffn(p["ffn"], h, jnp.zeros((), jnp.float32))
                x = x + y
                new_cache[f"l{i}"] = c
            return x, new_cache

        unit_caches = {k: v for k, v in caches.items() if k != "dense"}
        if cfg.unroll_units:
            outs = []
            for g in range(cfg.num_units):
                sl = jax.tree.map(lambda t: t[g],
                                  (params["units"], unit_caches))
                x, nc = body(x, sl)
                outs.append(nc)
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_caches = jax.lax.scan(body, x,
                                         (params["units"], unit_caches))
        x = self._norm(params["final_norm"], x)
        logits = self._logits(params, x)
        if cfg.first_dense:
            new_caches = dict(new_caches)
            new_caches["dense"] = new_dense
        return logits, new_caches

    def init_dense_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        out, axes = {}, {}
        Hk = cfg.num_kv_heads
        for i in range(cfg.first_dense):
            if cfg.attention == "mla":
                m = cfg.mla
                out[f"d{i}"] = {
                    "c": jnp.zeros((batch, max_len, m.kv_lora_rank), cfg.dtype),
                    "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), cfg.dtype)}
                axes[f"d{i}"] = {"c": Axes("batch", "kv_seq", None),
                                 "kr": Axes("batch", "kv_seq", None)}
            else:
                out[f"d{i}"] = {
                    "k": jnp.zeros((batch, max_len, Hk, cfg.hd), cfg.dtype),
                    "v": jnp.zeros((batch, max_len, Hk, cfg.hd), cfg.dtype)}
                axes[f"d{i}"] = {"k": Axes("batch", "kv_seq", "kv_heads", None),
                                 "v": Axes("batch", "kv_seq", "kv_heads", None)}
        return out, axes


def shapes_and_axes(model: Model):
    """(ShapeDtypeStruct tree, Axes tree) without allocating parameters.

    Axes are plain Python objects, so they can't ride through eval_shape's
    return value — capture them during the trace instead."""
    box = {}

    def only_params(k):
        p, a = model.init(k)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def param_count(model: Model) -> int:
    shapes, _ = shapes_and_axes(model)
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


__all__ = ["Model", "ModelConfig", "MoEConfig", "MLAConfig", "MambaConfig",
           "RWKVConfig", "shapes_and_axes", "param_count"]
